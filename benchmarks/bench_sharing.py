"""Paper Table 3 — concurrent Gauss-Seidel + STREAM under DLB policies.

Configurations: Single (each app alone on one NUMA node = 24 CPUs),
Concurrent (no sharing), Concurrent + DLB {LeWI, Hybrid, Prediction}.
Reported per app: time, EDP, #DLB calls.
"""

from __future__ import annotations

from repro.core import ResourceBroker
from repro.runtime import MN4, SimCluster, SimExecutor, SimJobSpec
from repro.workloads import build_gauss_seidel, build_stream

from .common import emit

GS_KW = dict(steps=40, bi=12, bj=12, block_elems=1_500_000, seed=0)
ST_KW = dict(rounds=25, blocks=900, seed=1)


def _emit(rows, config, name, rep, calls):
    rows.append({
        "bench": "sharing", "config": config, "app": name,
        "time_s": round(rep.makespan, 4),
        "edp": round(rep.edp, 4),
        "dlb_calls": calls,
    })
    emit(rows[-1])


SMOKE_GS_KW = dict(steps=6, bi=6, bj=6, block_elems=300_000, seed=0)
SMOKE_ST_KW = dict(rounds=5, blocks=200, seed=1)


def run(smoke: bool = False) -> list[dict]:
    rows = []
    gs_kw = SMOKE_GS_KW if smoke else GS_KW
    st_kw = SMOKE_ST_KW if smoke else ST_KW
    # Single: each app alone on half the node, idle policy (paper: the
    # Single policy idles CPUs when unused).
    for name, graph in (("gauss", build_gauss_seidel(**gs_kw)),
                        ("stream", build_stream(**st_kw))):
        rep = SimExecutor(MN4, policy="idle", n_cpus=24,
                          monitoring=True).run(graph)
        _emit(rows, "single", name, rep, 0)

    # Concurrent without DLB: both apps pinned to their half, busy.
    cl = SimCluster(MN4)
    cl.add_job(SimJobSpec(name="gauss", graph=build_gauss_seidel(**gs_kw),
                          policy="busy", cpus=list(range(24))))
    cl.add_job(SimJobSpec(name="stream", graph=build_stream(**st_kw),
                          policy="busy", cpus=list(range(24, 48))))
    for name, rep in cl.run().items():
        _emit(rows, "concurrent", name, rep, 0)

    # Concurrent + DLB variants.
    variants = ((("dlb-prediction", "dlb_prediction"),) if smoke else
                (("dlb-lewi", "dlb_lewi"),
                 ("dlb-hybrid", "dlb_hybrid"),
                 ("dlb-prediction", "dlb_prediction")))
    for policy, label in variants:
        broker = ResourceBroker()
        cl = SimCluster(MN4, broker=broker)
        cl.add_job(SimJobSpec(name="gauss",
                              graph=build_gauss_seidel(**gs_kw),
                              policy=policy, cpus=list(range(24))))
        cl.add_job(SimJobSpec(name="stream", graph=build_stream(**st_kw),
                              policy=policy, cpus=list(range(24, 48))))
        reps = cl.run()
        for name, rep in reps.items():
            _emit(rows, label, name, rep, rep.dlb_calls)
    return rows


if __name__ == "__main__":
    run()
