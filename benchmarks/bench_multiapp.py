"""Multi-application co-scheduling sweep (beyond-paper: Table 3 scaled
from 2 jobs to true multiprogramming).

N ∈ {2, 3, 4} applications — an imbalanced Gauss-Seidel, a fine-grained
STREAM, a MultiSAXPY generation chain and an HPCCG CG loop — co-scheduled
through the :class:`~repro.core.arbiter.ClusterArbiter` on an even CPU
partition of MN4 (homogeneous) and HYBRID-PE (8P+16E), under the three
DLB policies.  Per app: time, EDP, DLB calls, slowdown vs. a solo run on
the same partition; per configuration: aggregate EDP, Jain fairness and
total broker traffic.

The headline this pins (see ``tests/test_benchjson.py`` style checks in
the acceptance criteria): with N ≥ 3 claimants, prediction-driven
arbitration beats LeWI on aggregate EDP at comparable makespan — eager
per-thread acquisition pays for its broker storm exactly when the pool
is contested.
"""

from __future__ import annotations

from repro.runtime import HYBRID_PE, MN4, SimJobSpec, run_multi_app
from repro.workloads import (build_gauss_seidel, build_hpccg,
                             build_multisaxpy, build_stream)

from .common import emit

POLICIES = ("dlb-lewi", "dlb-hybrid", "dlb-prediction")

#: app roster in join order: N=k co-schedules the first k builders
APP_KW = {
    "gauss": dict(steps=8, bi=8, bj=8, block_elems=600_000, seed=0),
    "stream": dict(rounds=6, blocks=500, block_elems=40_000, seed=1),
    "saxpy": dict(grain="fine", generations=10, blocks=60,
                  block_elems=200_000, seed=2),
    "hpccg": dict(iterations=6, blocks=24, rows_per_block=16_384, seed=3),
}
SMOKE_KW = {
    "gauss": dict(steps=4, bi=6, bj=6, block_elems=300_000, seed=0),
    "stream": dict(rounds=3, blocks=200, block_elems=40_000, seed=1),
    "saxpy": dict(grain="fine", generations=4, blocks=30,
                  block_elems=200_000, seed=2),
    "hpccg": dict(iterations=3, blocks=16, rows_per_block=16_384, seed=3),
}
_BUILDERS = {"gauss": build_gauss_seidel, "stream": build_stream,
             "saxpy": build_multisaxpy, "hpccg": build_hpccg}


def _build(name: str, kw: dict):
    return _BUILDERS[name](**kw)


def _partition(n_cores: int, n_apps: int) -> list[list[int]]:
    per = n_cores // n_apps
    return [list(range(i * per, (i + 1) * per)) for i in range(n_apps)]


def run(smoke: bool = False) -> list[dict]:
    rows: list[dict] = []
    app_kw = SMOKE_KW if smoke else APP_KW
    machines = (MN4,) if smoke else (MN4, HYBRID_PE)
    ns = (3,) if smoke else (2, 3, 4)
    policies = (("dlb-lewi", "dlb-prediction") if smoke else POLICIES)
    for machine in machines:
        for n in ns:
            names = list(app_kw)[:n]
            parts = _partition(machine.n_cores, n)
            for policy in policies:
                specs = [SimJobSpec(name=name,
                                    graph=_build(name, app_kw[name]),
                                    policy=policy, cpus=parts[i])
                         for i, name in enumerate(names)]
                solo_graphs = {name: _build(name, app_kw[name])
                               for name in names}
                rep = run_multi_app(machine, specs,
                                    solo_graphs=solo_graphs)
                for name in names:
                    r = rep.apps[name]
                    rows.append({
                        "bench": "multiapp", "machine": machine.name,
                        "n_apps": n, "policy": policy, "app": name,
                        "time_s": round(r.makespan, 4),
                        "edp": round(r.edp, 4),
                        "dlb_calls": r.dlb_calls,
                        "slowdown": round(rep.slowdown[name], 4),
                        "lends": r.sharing["lends"],
                        "acquired": r.sharing["acquired"],
                    })
                    emit(rows[-1])
                rows.append({
                    "bench": "multiapp", "machine": machine.name,
                    "n_apps": n, "policy": policy, "app": "ALL",
                    "time_s": round(rep.makespan, 4),
                    "edp": round(rep.aggregate_edp, 4),
                    "dlb_calls": rep.total_dlb_calls,
                    "fairness": round(rep.fairness, 4),
                    "energy_j": round(rep.aggregate_energy, 4),
                })
                emit(rows[-1])
    return rows


if __name__ == "__main__":
    run()
