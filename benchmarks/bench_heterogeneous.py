"""Beyond-paper: heterogeneous-core machines (P+E hybrid, per-socket
DVFS) — busy/idle/hybrid/prediction vs the frequency-aware
``hetero-prediction`` policy, on a symmetric preset (MN4) as the control
and the two asymmetric presets.

Acceptance property tracked by ``BENCH_heterogeneous.json``: on the
asymmetric presets, ``hetero-prediction`` reaches lower EDP than busy at
no more than 10% makespan cost (``edp_vs_busy`` < 1, ``makespan_vs_busy``
≤ 1.10).
"""

from __future__ import annotations

from repro.core import GovernorSpec
from repro.runtime import DVFS2, HYBRID_PE, MN4, SimExecutor, Task, TaskGraph
from repro.workloads import WORKLOADS
from repro.workloads.arrivals import PoissonArrivals

from .common import SCALED, emit

POLICIES = ["busy", "idle", "hybrid", "prediction", "hetero-prediction"]
MACHINES = [MN4, HYBRID_PE, DVFS2]
#: ``micro-poisson`` is the partial-load scenario where the DVFS
#: stretch pays off: independent 20 µs tasks arriving at ~30% of the
#: machine's capacity — sockets widen-and-downclock instead of racing.
BENCHES = ["cholesky-fine", "multisaxpy-fine", "gauss-seidel",
           "micro-poisson"]


def _micro_poisson(machine, n=12_000, svc=2e-5, util=0.3):
    g = TaskGraph()
    for _ in range(n):
        g.add(Task(type_name="micro", cost=1.0, service_time=svc))
    # true capacity weighs each core by its speed (an E-core drains
    # 0.55 tasks for every P-core task) — n_cores/svc would overload
    # speed-asymmetric presets to ~43% instead of the advertised util
    speed_sum = sum(t.count * t.speed
                    for t in machine.topology().types)
    capacity = machine.core_speed * speed_sum / svc   # tasks/s full tilt
    return g, PoissonArrivals(rate=util * capacity, seed=1)


def run(smoke: bool = False) -> list[dict]:
    policies = ["busy", "hetero-prediction"] if smoke else POLICIES
    machines = [HYBRID_PE, DVFS2] if smoke else MACHINES
    benches = ["micro-poisson"] if smoke else BENCHES
    rows = []
    for machine in machines:
        for name in benches:
            reports = {}
            for policy in policies:
                arrivals = None
                if name == "micro-poisson":
                    g, arrivals = _micro_poisson(machine)
                else:
                    g = WORKLOADS[name](seed=0, **SCALED.get(name, {}))
                spec = GovernorSpec(resources=machine.n_cores,
                                    policy=policy, monitoring=True)
                reports[policy] = SimExecutor(machine, spec=spec).run(
                    g, arrivals=arrivals)
            busy_r = reports["busy"]
            for policy, r in reports.items():
                row = {
                    "bench": "heterogeneous", "machine": machine.name,
                    "asymmetric": machine.core_types is not None,
                    "workload": name, "policy": policy,
                    "makespan_ms": round(r.makespan * 1e3, 3),
                    "energy": round(r.energy, 4),
                    "edp": round(r.edp, 6),
                    "edp_vs_busy": round(r.edp / busy_r.edp, 4),
                    "makespan_vs_busy": round(
                        r.makespan / busy_r.makespan, 4),
                    "resumes": r.resumes,
                    "predictions": r.predictions,
                }
                for ct, acc in sorted(r.state_seconds_by_type.items()):
                    row[f"active_s_{ct}"] = round(acc["active"], 4)
                    row[f"idle_s_{ct}"] = round(acc["idle"], 4)
                for ct, q in sorted(r.freq_by_type.items()):
                    row[f"freq_{ct}"] = q
                rows.append(row)
                emit(row)
    return rows


if __name__ == "__main__":
    run()
