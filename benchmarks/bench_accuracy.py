"""Paper Table 2 — prediction accuracy per benchmark × machine.

Runs every workload under the prediction policy on the MN4 and KNL
machine models and reports instance counts + average timing-prediction
accuracy (the paper's |pred − real| / max(pred, real) metric).  Coarse
Cholesky reports NA (too few instances per type — the count-based
fallback engages), exactly as in the paper.
"""

from __future__ import annotations

from repro.runtime import KNL, MN4, SimExecutor
from repro.workloads import WORKLOADS

from .common import PAPER_BENCHES, SCALED, emit


def run(smoke: bool = False) -> list[dict]:
    rows = []
    machines = (MN4,) if smoke else (MN4, KNL)
    benches = PAPER_BENCHES[:2] if smoke else PAPER_BENCHES
    for machine in machines:
        for name in benches:
            g = WORKLOADS[name](seed=0, **SCALED.get(name, {}))
            # Coarse Cholesky: too few instances per type for timing
            # predictions (paper: "NA" — count-based fallback only).
            coarse_chol = name == "cholesky-coarse"
            rep = SimExecutor(machine, policy="prediction",
                              monitoring=True,
                              min_samples=1000 if coarse_chol else 4
                              ).run(g)
            acc = rep.accuracy
            rows.append({
                "bench": "accuracy", "machine": machine.name,
                "workload": name, "tasks": rep.tasks_completed,
                "instances_predicted": acc.instances if acc else 0,
                "avg_accuracy_pct": (round(acc.average_pct, 2)
                                     if acc and acc.average_pct is not None
                                     else "NA"),
            })
            emit(rows[-1])
    return rows


if __name__ == "__main__":
    run()
