"""Shared benchmark plumbing: CSV output + workload catalog."""

from __future__ import annotations

import sys


def emit(row: dict) -> None:
    """One CSV-ish line: key=value pairs, stable order."""
    print(",".join(f"{k}={v}" for k, v in row.items()))
    sys.stdout.flush()


#: benchmarks × machines used across the paper reproductions
PAPER_BENCHES = ["cholesky-fine", "cholesky-coarse", "hpccg",
                 "gauss-seidel", "multisaxpy-fine", "multisaxpy-coarse"]

#: smaller builder kwargs so the full sweep stays minutes, not hours —
#: granularity ratios (task length vs f) preserved
SCALED = {
    "cholesky-fine": dict(p=24),
    "cholesky-coarse": dict(),
    "hpccg": dict(iterations=25),
    "gauss-seidel": dict(steps=30),
    "multisaxpy-fine": dict(generations=60),
    "multisaxpy-coarse": dict(generations=15),
    "stream": dict(rounds=15),
}
