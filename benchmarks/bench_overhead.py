"""Paper §5 — monitoring overhead (≤3% in the fine-grained worst case).

Two measurements:
1. virtual-time: busy policy with vs without monitoring in the simulator
   (the per-event overhead is charged explicitly);
2. wall-clock: the *real* Python bookkeeping cost of the monitor, by
   driving a million-event stream through TaskMonitor directly.
"""

from __future__ import annotations

import time

from repro.core import GovernorSpec, ResourceGovernor
from repro.runtime import MN4, SimExecutor
from repro.workloads import WORKLOADS

from .common import emit


def run(smoke: bool = False) -> list[dict]:
    rows = []
    pairs = ((("multisaxpy-fine", dict(generations=8)),) if smoke else
             (("multisaxpy-fine", dict(generations=40)),
              ("cholesky-fine", dict(p=20))))
    for name, kw in pairs:
        g1 = WORKLOADS[name](seed=0, **kw)
        g2 = WORKLOADS[name](seed=0, **kw)
        t_off = SimExecutor(MN4, policy="busy",
                            monitoring=False).run(g1).makespan
        t_on = SimExecutor(MN4, policy="busy",
                           monitoring=True).run(g2).makespan
        rows.append({
            "bench": "overhead", "mode": "sim", "workload": name,
            "t_off_ms": round(t_off * 1e3, 3),
            "t_on_ms": round(t_on * 1e3, 3),
            "overhead_pct": round(100 * (t_on / t_off - 1), 3),
        })
        emit(rows[-1])

    # real bookkeeping cost per event (monitoring-only governor stack)
    m = ResourceGovernor(GovernorSpec(resources=1, monitoring=True)).monitor
    n = 20_000 if smoke else 200_000
    t0 = time.perf_counter()
    for i in range(n):
        m.on_task_ready(i, "t", 1.0)
        m.on_task_execute(i, "t", 1.0)
        m.on_task_completed(i, "t", 1.0, 1e-3)
    per_task_us = (time.perf_counter() - t0) / n * 1e6
    rows.append({
        "bench": "overhead", "mode": "wallclock",
        "events": 3 * n,
        "us_per_task": round(per_task_us, 3),
        # a fine-grained 1 ms task sees ~3 events:
        "pct_of_1ms_task": round(100 * per_task_us / 1e3, 3),
    })
    emit(rows[-1])
    return rows


if __name__ == "__main__":
    run()
