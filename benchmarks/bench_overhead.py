"""Paper §5 — monitoring overhead (≤3% in the fine-grained worst case).

Three measurements:
1. virtual-time: busy policy with vs without monitoring in the simulator
   (the per-event overhead is charged explicitly);
2. wall-clock: the *real* Python bookkeeping cost of the monitor, by
   driving a million-event stream through TaskMonitor directly;
3. real threads: ``ThreadExecutor`` monitoring on vs off on the 8-worker
   closed chain graph — the end-to-end cost of live monitoring on the
   fast lane, compared against the same A/B recorded at the
   pre-fast-lane commit (per-event monitor locking).
"""

from __future__ import annotations

import time

from repro.core import GovernorSpec, ResourceGovernor
from repro.runtime import MN4, SimExecutor, ThreadExecutor
from repro.workloads import WORKLOADS

from .bench_threadperf import chain_graph
from .common import emit

#: pre-fast-lane ThreadExecutor monitoring A/B (commit 0a8c20a): best-of-3
#: wall seconds for the 8-worker busy closed chain graph (32 × 200 no-op
#: tasks), monitoring off vs on, measured back-to-back against the fast
#: lane on the same host at matched load (calibration 0.199 old side vs
#: 0.201 new side).  The old executor was scheduler-lock-bound, so most
#: of the per-event monitor-lock cost hid inside lock waits — its *extra*
#: wall cost was ~4.1 µs/task; the batched fast lane pays ~3.1 µs/task
#: with both the on and off absolute times ~1.4x faster.
BASELINE_THREADS = {"t_off_s": 0.0801, "t_on_s": 0.1063}


def _measure_threads(n_workers: int, monitoring: bool, n_chains: int,
                     depth: int, reps: int) -> float:
    """Best-of-``reps`` wall seconds for one closed ThreadExecutor run."""
    best = None
    for _ in range(reps):
        g = chain_graph(n_chains, depth)
        ex = ThreadExecutor(n_workers, policy="busy", monitoring=monitoring)
        t0 = time.perf_counter()
        ex.run(g)
        wall = time.perf_counter() - t0
        if best is None or wall < best:
            best = wall
    assert best is not None
    return best


def run(smoke: bool = False) -> list[dict]:
    rows = []
    pairs = ((("multisaxpy-fine", dict(generations=8)),) if smoke else
             (("multisaxpy-fine", dict(generations=40)),
              ("cholesky-fine", dict(p=20))))
    for name, kw in pairs:
        g1 = WORKLOADS[name](seed=0, **kw)
        g2 = WORKLOADS[name](seed=0, **kw)
        t_off = SimExecutor(MN4, policy="busy",
                            monitoring=False).run(g1).makespan
        t_on = SimExecutor(MN4, policy="busy",
                           monitoring=True).run(g2).makespan
        rows.append({
            "bench": "overhead", "mode": "sim", "workload": name,
            "t_off_ms": round(t_off * 1e3, 3),
            "t_on_ms": round(t_on * 1e3, 3),
            "overhead_pct": round(100 * (t_on / t_off - 1), 3),
        })
        emit(rows[-1])

    # real bookkeeping cost per event (monitoring-only governor stack)
    m = ResourceGovernor(GovernorSpec(resources=1, monitoring=True)).monitor
    n = 20_000 if smoke else 200_000
    t0 = time.perf_counter()
    for i in range(n):
        m.on_task_ready(i, "t", 1.0)
        m.on_task_execute(i, "t", 1.0)
        m.on_task_completed(i, "t", 1.0, 1e-3)
    per_task_us = (time.perf_counter() - t0) / n * 1e6
    rows.append({
        "bench": "overhead", "mode": "wallclock",
        "events": 3 * n,
        "us_per_task": round(per_task_us, 3),
        # a fine-grained 1 ms task sees ~3 events:
        "pct_of_1ms_task": round(100 * per_task_us / 1e3, 3),
    })
    emit(rows[-1])

    # real threads: end-to-end monitoring cost on the fast lane
    n_chains, depth = (8, 50) if smoke else (32, 200)
    reps = 1 if smoke else 3
    n_workers = 2 if smoke else 8
    t_off = _measure_threads(n_workers, False, n_chains, depth, reps)
    t_on = _measure_threads(n_workers, True, n_chains, depth, reps)
    rows.append({
        "bench": "overhead", "mode": "threads", "workers": n_workers,
        "tasks": n_chains * depth,
        "t_off_s": round(t_off, 4), "t_on_s": round(t_on, 4),
        "overhead_pct": round(100 * (t_on / t_off - 1), 1),
        "monitor_us_per_task": round(
            (t_on - t_off) / (n_chains * depth) * 1e6, 2),
    })
    emit(rows[-1])
    if not smoke:
        b_off, b_on = BASELINE_THREADS["t_off_s"], BASELINE_THREADS["t_on_s"]
        rows.append({
            "bench": "overhead", "mode": "threads-baseline",
            "workers": 8, "tasks": 32 * 200,
            "t_off_s": b_off, "t_on_s": b_on,
            "overhead_pct": round(100 * (b_on / b_off - 1), 1),
            "monitor_us_per_task": round((b_on - b_off) / 6400 * 1e6, 2),
            "note": "pre-fast-lane (commit 0a8c20a), recorded constant",
        })
        emit(rows[-1])
    return rows


if __name__ == "__main__":
    run()
