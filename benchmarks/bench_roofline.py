"""Roofline table — reads the dry-run artifacts produced by
``python -m repro.launch.dryrun --all`` and prints the per-cell terms
(EXPERIMENTS.md §Roofline is generated from this).

If no artifacts exist yet this benchmark reports that fact rather than
recomputing them (the 512-device lower+compile sweep is the dry-run
driver's job, and must not run inside the 1-device benchmark process).
"""

from __future__ import annotations

import json
import pathlib

from .common import emit

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run(smoke: bool = False) -> list[dict]:
    # reads precomputed artifacts — already seconds-scale, smoke == full
    rows = []
    files = sorted(ART.glob("*.json")) if ART.exists() else []
    if not files:
        emit({"bench": "roofline",
              "status": "no artifacts — run python -m repro.launch.dryrun --all"})
        return rows
    for f in files:
        r = json.loads(f.read_text())
        if r.get("tag"):
            continue          # hillclimb variants reported in §Perf
        t = r["terms"]
        rows.append({
            "bench": "roofline", "arch": r["arch"], "shape": r["shape"],
            "mesh": r["mesh"],
            "compute_ms": round(t["compute_s"] * 1e3, 3),
            "memory_ms": round(t["memory_s"] * 1e3, 3),
            "collective_ms": round(t["collective_s"] * 1e3, 3),
            "dominant": t["dominant"],
            "useful_ratio": round(t["useful_ratio"], 3),
            "fits_16GB": r["fits_16GB"],
            "adj_peak_GB": round(
                r["memory"].get("adjusted_peak_bytes",
                                r["memory"]["peak_estimate_bytes"]) / 1e9,
                2),
        })
        emit(rows[-1])
    return rows


if __name__ == "__main__":
    run()
