"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from the
dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.make_tables >> EXPERIMENTS.md
"""

from __future__ import annotations

import json
import pathlib

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

ARCH_ORDER = ["internvl2-1b", "gemma2-9b", "deepseek-coder-33b",
              "llama3.2-1b", "qwen1.5-110b", "mixtral-8x22b",
              "llama4-maverick-400b-a17b", "musicgen-medium",
              "recurrentgemma-2b", "rwkv6-7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str) -> dict:
    out = {}
    for f in ART.glob(f"*_{mesh}.json"):
        r = json.loads(f.read_text())
        if r.get("tag"):
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def roofline_table() -> None:
    recs = load("16x16")
    print("\n| arch | shape | compute ms | memory ms | coll ms | dominant"
          " | w/kernels mem ms | dom (kernels) | useful | adj peak GB |"
          " fits |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                print(f"| {arch} | {shape} | — | — | — | skip | — | — |"
                      " — | — | — |")
                continue
            t, kt = r["terms"], r["kernel_terms"]
            print(f"| {arch} | {shape} "
                  f"| {t['compute_s']*1e3:.1f} "
                  f"| {t['memory_s']*1e3:.1f} "
                  f"| {t['collective_s']*1e3:.1f} "
                  f"| {t['dominant']} "
                  f"| {kt['memory_s']*1e3:.1f} "
                  f"| {kt['dominant']} "
                  f"| {t['useful_ratio']:.2f} "
                  f"| {r['memory']['adjusted_peak_bytes']/1e9:.2f} "
                  f"| {'Y' if r['fits_16GB'] else 'N'} |")


def dryrun_table() -> None:
    for mesh in ("16x16", "2x16x16"):
        recs = load(mesh)
        ok = sum(1 for _ in recs)
        fits = sum(1 for r in recs.values() if r["fits_16GB"])
        print(f"\n**{mesh}**: {ok} cells lower+compile OK; "
              f"{fits}/{ok} fit 16 GB/chip (adjusted).")
        print("\n| arch | shape | args GB | temp GB | adj peak GB | "
              "colls | wire GB | compile s |")
        print("|---|---|---|---|---|---|---|---|")
        for arch in ARCH_ORDER:
            for shape in SHAPE_ORDER:
                r = recs.get((arch, shape))
                if r is None:
                    continue
                m, h = r["memory"], r["hlo_analysis"]
                print(f"| {arch} | {shape} "
                      f"| {m['argument_bytes']/1e9:.2f} "
                      f"| {m['temp_bytes']/1e9:.2f} "
                      f"| {m['adjusted_peak_bytes']/1e9:.2f} "
                      f"| {h['collective_count']} "
                      f"| {h['collective_bytes']/1e9:.1f} "
                      f"| {r['compile_s']:.0f} |")


def cluster_table() -> None:
    """Placement-policy and locality-guard tables from the committed
    ``BENCH_cluster.json`` (see ``benchmarks/bench_cluster.py``)."""
    bench = pathlib.Path(__file__).resolve().parents[1] \
        / "BENCH_cluster.json"
    if not bench.exists():
        print("\n(BENCH_cluster.json not found — run "
              "`python -m benchmarks.run --only cluster` first)")
        return
    rows = json.loads(bench.read_text())["rows"]
    print("\n| machine | nodes | placement | makespan s | aggregate EDP"
          " | transfers |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        if r["scenario"] != "placement" or r["app"] != "ALL":
            continue
        print(f"| {r['machine']} | {r['n_nodes']} | {r['placement']} "
              f"| {r['time_s']:.4f} | {r['edp']:.4f} "
              f"| {r['transfers']} |")
    print("\n| fabric penalty | guard | makespan s | aggregate EDP "
          "| transfers | refused borrows |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        if r["scenario"] != "hetero-guard":
            continue
        print(f"| {r['remote_penalty']} | {r['guard']} "
              f"| {r['time_s']:.4f} | {r['edp']:.4f} "
              f"| {r['transfers']} | {r['guard_refusals']} |")


def faults_table() -> None:
    """Dynamic-conditions tables from the committed
    ``BENCH_faults.json`` (see ``benchmarks/bench_faults.py``)."""
    bench = pathlib.Path(__file__).resolve().parents[1] \
        / "BENCH_faults.json"
    if not bench.exists():
        print("\n(BENCH_faults.json not found — run "
              "`python -m benchmarks.run --only faults` first)")
        return
    rows = json.loads(bench.read_text())["rows"]
    print("\n| machine | policy | cap W | cap at s | makespan s "
          "| aggregate EDP | violation s |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        if r["scenario"] != "power-cap":
            continue
        print(f"| {r['machine']} | {r['policy']} | {r['cap_w']} "
              f"| {r['cap_at_s']:.4f} | {r['time_s']:.4f} "
              f"| {r['edp']:.4f} | {r['cap_violation_s']:.4f} |")
    print("\n| scenario | machine | policy | makespan s | healthy s "
          "| slowdown % | EDP | healthy EDP |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["scenario"] not in ("faults", "thermal"):
            continue
        print(f"| {r['scenario']} | {r['machine']} | {r['policy']} "
              f"| {r['time_s']:.4f} | {r['healthy_time_s']:.4f} "
              f"| {r['slowdown_pct']:.1f} | {r['edp']:.6f} "
              f"| {r['healthy_edp']:.6f} |")


def serving_table() -> None:
    """Overload-robustness tables from the committed
    ``BENCH_serving.json`` (see ``benchmarks/bench_serving.py``)."""
    bench = pathlib.Path(__file__).resolve().parents[1] \
        / "BENCH_serving.json"
    if not bench.exists():
        print("\n(BENCH_serving.json not found — run "
              "`python -m benchmarks.run --only serving` first)")
        return
    rows = json.loads(bench.read_text())["rows"]
    print("\n| scenario | machine | stack | attainment | p50 ms "
          "| p99 ms | goodput r/s | shed | retries | hedges "
          "| aggregate EDP | violation s |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        stack = r["policy"] + ("+protect" if r["protection"] else "")
        print(f"| {r['scenario']} | {r['machine']} | {stack} "
              f"| {r['attainment']:.3f} | {r['p50_ms']:.0f} "
              f"| {r['p99_ms']:.0f} | {r['goodput_rps']:.1f} "
              f"| {r['shed']} | {r['retries']} | {r['hedges']} "
              f"| {r['edp']:.0f} | {r['cap_violation_s']:.2f} |")


if __name__ == "__main__":
    print("## Generated tables (from artifacts/dryrun)")
    print("\n### §Dry-run")
    dryrun_table()
    print("\n### §Roofline (single-pod 16×16, per-device terms)")
    roofline_table()
    print("\n### §Cluster (multi-node placement + locality guard)")
    cluster_table()
    print("\n### §Faults (power caps, core faults, thermal throttling)")
    faults_table()
    print("\n### §Serving under overload (SLO admission, retries, "
          "brownout)")
    serving_table()
