"""Beyond-paper: policies under *open* workloads (arrival-driven load).

The paper's experiments submit a whole graph at t=0; this benchmark
streams the same task mix through seeded arrival processes — memoryless
(Poisson), bursty (on/off) and a diurnal ramp — so the busy/idle/hybrid/
prediction trade-off is measured through empty-then-bursty phases, the
load shape a serving deployment actually sees.  Reported through the
unified :class:`~repro.core.governor.GovernorReport` schema.
"""

from __future__ import annotations

from repro.core import GovernorSpec
from repro.runtime import MN4, SimExecutor
from repro.workloads import (BurstArrivals, DiurnalArrivals,
                             PoissonArrivals, WORKLOADS)

from .common import SCALED, emit

POLICIES = ["busy", "idle", "hybrid", "prediction"]
WORKLOAD = "multisaxpy-fine"


def _arrival_menu(n_tasks: int, mean_service: float, n_cores: int) -> dict:
    """Arrival processes scaled to the workload so utilization is
    moderate (~70 % for Poisson) with real lulls for the bursty shapes."""
    svc_rate = n_cores / mean_service          # tasks/s the machine drains
    burst = max(2, n_tasks // 8)
    return {
        "poisson": PoissonArrivals(rate=0.7 * svc_rate, seed=0),
        "burst": BurstArrivals(burst_size=burst,
                               gap=2.0 * burst * mean_service / n_cores,
                               seed=0),
        "diurnal": DiurnalArrivals(period=n_tasks / svc_rate,
                                   low_rate=0.1 * svc_rate,
                                   high_rate=1.5 * svc_rate, seed=0),
    }


def run(smoke: bool = False) -> list[dict]:
    rows = []
    machine = MN4
    probe = WORKLOADS[WORKLOAD](seed=0, **SCALED.get(WORKLOAD, {}))
    services = [t.service_time for t in probe.tasks
                if t.service_time is not None]
    mean_service = sum(services) / max(1, len(services))
    menu = _arrival_menu(len(probe.tasks), mean_service, machine.n_cores)
    if smoke:
        menu = {"poisson": menu["poisson"]}
    policies = ["busy", "prediction"] if smoke else POLICIES
    for arrival_name, process in menu.items():
        reports = {}
        for policy in policies:
            g = WORKLOADS[WORKLOAD](seed=0, **SCALED.get(WORKLOAD, {}))
            spec = GovernorSpec(resources=machine.n_cores, policy=policy,
                                monitoring=True)
            reports[policy] = SimExecutor(machine, spec=spec).run(
                g, arrivals=process)
        best_t = min(r.makespan for r in reports.values())
        best_edp = min(r.edp for r in reports.values())
        for policy, r in reports.items():
            rows.append({
                "bench": "open_workloads", "machine": machine.name,
                "workload": WORKLOAD, "arrivals": arrival_name,
                "policy": policy,
                "makespan_ms": round(r.makespan * 1e3, 3),
                "norm_perf": round(best_t / r.makespan, 4),
                "energy": round(r.energy, 4),
                "edp": round(r.edp, 6),
                "norm_edp": round(r.edp / best_edp, 3),
                "resumes": r.resumes,
                "idles": r.idles,
                "predictions": r.predictions,
            })
            emit(rows[-1])
    return rows


if __name__ == "__main__":
    run()
