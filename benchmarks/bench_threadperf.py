"""Real-thread executor throughput (the PR-7 ThreadExecutor fast lane).

Measures tasks/second of ``ThreadExecutor`` across worker counts and
execution modes with near-zero-work tasks, so the number is the *pure
scheduling overhead* of the poll/complete/monitor/wake machinery — the
measured-side twin of ``bench_simperf.py``:

* ``closed`` — a dependency-rich graph (independent chains: every
  completion readies exactly one successor) submitted whole at t=0;
* ``open``   — independent tasks submitted one-by-one from the driver
  thread while workers run (``start()``/``submit()``/``close()``).

Both modes run under ``busy`` (spin-heavy: polls vastly outnumber
completions) and ``prediction`` (idle/resume churn + the 1 ms ticker).

Every scenario also emits a ``baseline`` row: tasks/sec of the same
scenario measured with this same harness at the pre-fast-lane commit
(0a8c20a, PR 6) — the single global Scheduler lock + condition-variable
``notify_all`` + per-event TaskMonitor locking.  Those numbers are
frozen constants (the old code no longer exists in the tree) and are
what the acceptance speedups are computed against.

Cross-machine comparability: rows carry ``calibration`` — the wall
seconds this interpreter needs for a fixed pure-Python loop — so a
re-run on different silicon compares *normalized* throughput
(tasks/sec × calibration).  ``tests/test_threadperf.py`` pins the
floors with exactly that ratio.
"""

from __future__ import annotations

import time

from repro.runtime import Task, TaskGraph, ThreadExecutor

from .common import emit

#: pre-fast-lane tasks/sec (commit 0a8c20a, PR 6) — same scenarios,
#: same harness (perf_counter wall time, best-of-3), measured on the
#: machine that produced the committed BENCH_threadperf.json
BASELINE_TASKS_PER_SEC: dict[str, float] = {
    # measured in the same session (back-to-back, same machine load) as
    # the committed fastlane numbers, from a worktree pinned at commit
    # 0a8c20a running this same harness; baseline-side calibration was
    # 0.120 (vs the fastlane run's — see BENCH_threadperf.json rows).
    # Same-session A/B is the honest comparison on a shared host: run-
    # to-run machine-load swings exceed the effect being measured.
    "closed/2w/busy": 97740.8,
    "closed/2w/prediction": 58665.2,
    "closed/4w/busy": 87763.7,
    "closed/4w/prediction": 65531.7,
    "closed/8w/busy": 68927.4,
    "closed/8w/prediction": 58350.0,
    "open/2w/busy": 90198.8,
    "open/2w/prediction": 62668.1,
    "open/4w/busy": 70830.0,
    "open/4w/prediction": 58234.9,
    "open/8w/busy": 30682.4,
    "open/8w/prediction": 55348.0,
}


def calibrate() -> float:
    """Seconds of wall time for a fixed pure-Python workload — the
    machine speed yardstick that makes committed tasks/sec portable."""
    t0 = time.perf_counter()
    acc = 0
    for i in range(2_000_000):
        acc += i * i
    return time.perf_counter() - t0


def chain_graph(n_chains: int, depth: int) -> TaskGraph:
    """``n_chains`` independent chains of ``depth`` no-op tasks: every
    completion readies exactly one successor (the local-shard handoff
    path), while the chain roots exercise the cross-thread queue."""
    g = TaskGraph()
    for _ in range(n_chains):
        prev = None
        for _ in range(depth):
            t = Task("link", cost=1.0, fn=_noop)
            if prev is not None:
                t.depends_on(prev)
            g.add(t)
            prev = t
    return g


def _noop() -> None:
    return None


def _measure_closed(n_workers: int, policy: str, n_chains: int,
                    depth: int, reps: int) -> tuple[int, float]:
    """Best-of-``reps`` (tasks, wall_seconds) for one closed run."""
    best = None
    n_tasks = n_chains * depth
    for _ in range(reps):
        g = chain_graph(n_chains, depth)
        ex = ThreadExecutor(n_workers, policy=policy)
        t0 = time.perf_counter()
        ex.run(g)
        wall = time.perf_counter() - t0
        if best is None or wall < best:
            best = wall
    assert best is not None
    return n_tasks, best


def _measure_open(n_workers: int, policy: str, n_tasks: int,
                  reps: int) -> tuple[int, float]:
    """Best-of-``reps`` for driver-thread one-by-one submission."""
    best = None
    for _ in range(reps):
        ex = ThreadExecutor(n_workers, policy=policy).start()
        t0 = time.perf_counter()
        for _i in range(n_tasks):
            ex.submit(Task("w", cost=1.0, fn=_noop))
        ex.close()
        wall = time.perf_counter() - t0
        if best is None or wall < best:
            best = wall
    assert best is not None
    return n_tasks, best


def run(smoke: bool = False) -> list[dict]:
    reps = 1 if smoke else 3
    workers = (2,) if smoke else (2, 4, 8)
    n_chains = 8 if smoke else 32
    depth = 50 if smoke else 200
    n_open = 400 if smoke else 3200
    calibration = calibrate()
    rows = []
    for w in workers:
        for policy in ("busy", "prediction"):
            for mode in ("closed", "open"):
                name = f"{mode}/{w}w/{policy}"
                if not smoke and BASELINE_TASKS_PER_SEC.get(name):
                    # Baseline rows/ratios only make sense at full
                    # scale: the recorded constants were measured on
                    # the full scenarios.
                    rows.append({
                        "bench": "threadperf", "scenario": name,
                        "mode": "baseline",
                        "tasks_per_sec": BASELINE_TASKS_PER_SEC[name],
                        "note": "pre-fast-lane (commit 0a8c20a), "
                                "recorded constant",
                    })
                    emit(rows[-1])
                if mode == "closed":
                    tasks, wall = _measure_closed(w, policy, n_chains,
                                                  depth, reps)
                else:
                    tasks, wall = _measure_open(w, policy, n_open, reps)
                tps = tasks / wall if wall > 0 else float("inf")
                rows.append({
                    "bench": "threadperf", "scenario": name,
                    "mode": "fastlane", "workers": w, "tasks": tasks,
                    "wall_s": round(wall, 4),
                    "tasks_per_sec": round(tps, 1),
                    "calibration": round(calibration, 4),
                })
                base = BASELINE_TASKS_PER_SEC.get(name)
                if not smoke and base:
                    rows[-1]["speedup_vs_baseline"] = round(tps / base, 2)
                emit(rows[-1])
    return rows


if __name__ == "__main__":
    run()
