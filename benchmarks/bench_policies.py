"""Paper Fig. 3 (normalized performance) + Fig. 4 (EDP) — all
workloads × policies × machines on the simulator."""

from __future__ import annotations

from repro.core import GovernorSpec
from repro.runtime import KNL, MN4, SimExecutor
from repro.workloads import WORKLOADS

from .common import PAPER_BENCHES, SCALED, emit

POLICIES = ["busy", "idle", "hybrid", "prediction"]


def run(smoke: bool = False) -> list[dict]:
    rows = []
    machines = (MN4,) if smoke else (MN4, KNL)
    benches = ["multisaxpy-fine"] if smoke else PAPER_BENCHES
    policies = ["busy", "prediction"] if smoke else POLICIES
    for machine in machines:
        for name in benches:
            reports = {}
            for policy in policies:
                g = WORKLOADS[name](seed=0, **SCALED.get(name, {}))
                spec = GovernorSpec(resources=machine.n_cores,
                                    policy=policy, monitoring=True)
                reports[policy] = SimExecutor(machine, spec=spec).run(g)
            best_t = min(r.makespan for r in reports.values())
            best_edp = min(r.edp for r in reports.values())
            for policy, r in reports.items():
                rows.append({
                    "bench": "policies", "machine": machine.name,
                    "workload": name, "policy": policy,
                    "makespan_ms": round(r.makespan * 1e3, 3),
                    "norm_perf": round(best_t / r.makespan, 4),
                    "energy": round(r.energy, 4),
                    "edp": round(r.edp, 6),
                    "norm_edp": round(r.edp / best_edp, 3),
                    "resumes": r.resumes,
                    "predictions": r.predictions,
                })
                emit(rows[-1])
    return rows


if __name__ == "__main__":
    run()
