"""Beyond-paper: prediction-based autoscaling for LM serving.

A bursty arrival trace drives the continuous-batching engine (real tiny
model); the AutoScaler's Δ trace is compared across policies, and a
replica-energy proxy (active replicas integrated over ticks) yields the
EDP-style trade-off — the paper's Fig. 4 story at serving granularity.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import AutoScaler, Request, ServingEngine

from .common import emit


def run(smoke: bool = False) -> list[dict]:
    rows = []
    cfg = get_smoke_config("llama3.2-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    # bursty trace: 3 bursts of 6 requests with idle gaps (in ticks)
    bursts = {0: 2} if smoke else {0: 6, 40: 6, 80: 6}
    policies = ("prediction",) if smoke else ("busy", "idle", "prediction")

    for policy in policies:
        engine = ServingEngine(cfg, params, max_batch=4, max_len=96)
        scaler = AutoScaler(engine.monitor, max_replicas=4, policy=policy,
                            bus=engine.bus)
        reqs = []
        replica_ticks = 0
        tick = 0
        max_ticks, min_ticks = (60, 30) if smoke else (200, 100)
        t0 = time.perf_counter()
        while tick < max_ticks and (tick < min_ticks or engine.load):
            for _ in range(bursts.get(tick, 0)):
                p = rng.integers(0, cfg.vocab, size=8).tolist()
                reqs.append(engine.submit(
                    Request(prompt=p, max_new_tokens=12)))
            target = scaler.target(len(engine.queue),
                                   sum(r is not None
                                       for r in engine.active))
            replica_ticks += target
            engine.tick()
            tick += 1
        wall = time.perf_counter() - t0
        lat = [r.done_at - r.submitted_at for r in reqs if r.done]
        rows.append({
            "bench": "serving", "policy": policy,
            "requests": len(reqs),
            "completed": sum(r.done for r in reqs),
            "tokens": engine.tokens_out,
            "tok_per_s": round(engine.tokens_out / wall, 1),
            "p50_latency_ms": round(float(np.percentile(lat, 50)) * 1e3, 1)
            if lat else "NA",
            "replica_ticks": replica_ticks,      # energy proxy
        })
        emit(rows[-1])
    return rows


if __name__ == "__main__":
    run()
