"""Serving under overload — SLO attainment, tail latency and energy at
10⁵-request scale.

The discrete-event :class:`~repro.serving.simserving.SimServing`
frontend drives the full robustness surface in virtual time: admission
control, deadline shedding, seeded retries, hedged tails, circuit
breakers, power-cap brownout — 10⁵ requests per scenario in seconds of
wall clock.

Three arrival shapes × two machines, each under four stacks:

* **poisson** — steady open load at ~75 % of capacity;
* **burst** — on/off bursts at ~2× capacity with idle gaps;
* **diurnal** — the headline: a sinusoidal ramp whose peak overshoots
  capacity, with a facility power cap landing mid-run and lifting
  later.  The protected prediction stack sheds what cannot meet its
  deadline, brownouts best-effort traffic, shrinks the hot-replica
  allowance to the cap (zero violation seconds) — and still beats the
  unprotected reactive baseline on p99, attainment and aggregate EDP.

Stacks: ``policy`` × ``protection`` — ``prediction+protect`` (the
paper's stack), ``idle+protect``, ``prediction`` bare, and ``idle``
bare (the unprotected reactive baseline).  SLO timeouts/retries are the
client's contract and stay on everywhere.

Headline artifact: ``BENCH_serving.json`` (``python -m benchmarks.run
--only serving``).
"""

from __future__ import annotations

import time

from repro.core.conditions import ConditionTimeline, power_cap
from repro.runtime import HYBRID_PE, MN4
from repro.serving import ServingModel, SimServing, build_requests
from repro.workloads.arrivals import (ArrivalProcess, BurstArrivals,
                                      DiurnalArrivals, PoissonArrivals)

from .common import emit

#: (policy, protection) stacks; protection=False disables admission,
#: hedging, breakers and cap enforcement — the reactive baseline
STACKS = (("prediction", True), ("idle", True),
          ("prediction", False), ("idle", False))

#: per-machine scenario constants: sustainable capacity in requests/s
#: (slots / mean service seconds at the default token mix) and the
#: mid-run facility cap in watts (MN4: 48×(1.0 active, 0.1 idle) ⇒
#: 28 hot replicas; HYBRID-PE: 8 P + 16 E ⇒ 17 hot replicas)
CAPACITY = {MN4.name: 395.0, HYBRID_PE.name: 138.0}
CAP_W = {MN4.name: 30.0, HYBRID_PE.name: 12.0}


def _arrivals(scenario: str, machine, n: int,
              seed: int) -> tuple[ArrivalProcess, ConditionTimeline]:
    cap = CAPACITY[machine.name]
    if scenario == "poisson":
        return PoissonArrivals(rate=0.75 * cap, seed=seed), \
            ConditionTimeline()
    if scenario == "burst":
        # bursts at 2× capacity, then a gap about as long as the burst:
        # mean load ~65 % of capacity, front-loaded
        burst = max(50, n // 40)
        return BurstArrivals(burst_size=burst, spacing=1.0 / (2.0 * cap),
                             gap=burst / (2.0 * cap), seed=seed,
                             jitter=0.2), ConditionTimeline()
    if scenario == "diurnal":
        # sinusoidal ramp whose peak overshoots capacity by 60 %; a
        # power cap lands during the first peak and lifts on the
        # second climb
        low, high = 0.25 * cap, 1.60 * cap
        mean = (low + high) / 2.0
        span = n / mean                  # expected run length
        period = span / 2.0             # two day/night cycles
        tl = ConditionTimeline([
            power_cap(0.35 * span, CAP_W[machine.name]),
            power_cap(0.70 * span, None),
        ])
        return DiurnalArrivals(period=period, low_rate=low,
                               high_rate=high, seed=seed), tl
    raise ValueError(scenario)


def _row(scenario: str, machine, policy: str, protection: bool,
         n: int, seed: int) -> dict:
    process, timeline = _arrivals(scenario, machine, n, seed)
    reqs = build_requests(process, n, seed=seed)
    model = ServingModel(machine=machine)
    t0 = time.perf_counter()
    sim = SimServing(model, reqs, policy=policy, protection=protection,
                     conditions=timeline, seed=seed).run()
    wall = time.perf_counter() - t0
    rep = sim.report(f"{scenario}/{machine.name}")
    s = rep.serving
    return {
        "bench": "serving", "scenario": scenario,
        "machine": machine.name, "policy": policy,
        "protection": protection,
        "requests": s["requests"],
        "completed": s["completed"],
        "shed": s["shed"], "timed_out": s["timed_out"],
        "retries": s["retries"], "hedges": s["hedges"],
        "hedge_wins": s["hedge_wins"], "degrades": s["degrades"],
        "truncated_tokens": s["truncated_tokens"],
        "p50_ms": round(s["p50_ms"], 2),
        "p99_ms": round(s["p99_ms"], 2),
        "attainment": round(s["attainment"], 4),
        "goodput_rps": round(s["goodput_rps"], 2),
        "time_s": round(rep.makespan, 4),
        "energy_j": round(rep.energy, 4),
        "edp": round(rep.edp, 4),
        "cap_violation_s": round(rep.cap_violation_s, 4),
        "wall_s": round(wall, 2),
    }


def run(smoke: bool = False) -> list[dict]:
    n = 2_000 if smoke else 100_000
    machines = (MN4,) if smoke else (MN4, HYBRID_PE)
    stacks = STACKS[::3] if smoke else STACKS   # endpoints only
    rows: list[dict] = []
    for machine in machines:
        for scenario in ("poisson", "burst", "diurnal"):
            for policy, protection in stacks:
                rows.append(_row(scenario, machine, policy, protection,
                                 n, seed=42))
                emit(rows[-1])
    return rows


if __name__ == "__main__":
    run()
