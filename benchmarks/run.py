"""Benchmark driver — one section per paper table/figure plus the
beyond-paper serving, roofline and open-workload benchmarks.

    PYTHONPATH=src python -m benchmarks.run [--only accuracy,...]
                                            [--json-dir DIR]

Sections whose ``run()`` returns rows also write a machine-readable
``BENCH_<section>.json`` (``--json-dir``, default cwd) so the perf
trajectory is tracked across PRs.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

SECTIONS = ["accuracy", "policies", "sharing", "overhead", "serving",
            "roofline", "open_workloads"]

CAPTIONS = {
    "accuracy": "(paper Table 2)",
    "policies": "(paper Figs 3-4)",
    "sharing": "(paper Table 3)",
    "overhead": "(paper §5)",
    "open_workloads": "(beyond-paper: arrival-driven load)",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of "
                    + ",".join(SECTIONS))
    ap.add_argument("--json-dir", default=".",
                    help="where BENCH_<section>.json files are written")
    args = ap.parse_args()
    wanted = args.only.split(",") if args.only else SECTIONS
    json_dir = Path(args.json_dir)
    json_dir.mkdir(parents=True, exist_ok=True)

    for name in wanted:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        print(f"### bench_{name} {CAPTIONS.get(name, '')}")
        t0 = time.time()
        rows = mod.run()
        elapsed = time.time() - t0
        if isinstance(rows, list) and rows:
            out = json_dir / f"BENCH_{name}.json"
            out.write_text(json.dumps(
                {"section": name, "elapsed_s": round(elapsed, 2),
                 "rows": rows}, indent=1))
            print(f"### wrote {out}")
        print(f"### bench_{name} done in {elapsed:.1f}s\n")


if __name__ == "__main__":
    main()
