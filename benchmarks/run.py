"""Benchmark driver — one section per paper table/figure plus the
beyond-paper serving, roofline, open-workload and heterogeneous
benchmarks.

    PYTHONPATH=src python -m benchmarks.run [--only accuracy,...]
                                            [--json-dir DIR] [--smoke]

Sections whose ``run()`` returns rows also write a machine-readable
``BENCH_<section>.json`` (``--json-dir``, default cwd) so the perf
trajectory is tracked across PRs.

``--smoke`` runs every section in a seconds-scale configuration — CI
exercises all BENCH-emitting code paths on each push so the drivers
cannot silently rot.  Smoke rows are *not* written over the committed
BENCH files unless ``--json-dir`` is given explicitly.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

SECTIONS = ["accuracy", "policies", "sharing", "overhead", "serving",
            "roofline", "open_workloads", "heterogeneous", "multiapp",
            "cluster", "simperf", "threadperf", "faults"]

CAPTIONS = {
    "accuracy": "(paper Table 2)",
    "policies": "(paper Figs 3-4)",
    "sharing": "(paper Table 3)",
    "overhead": "(paper §5)",
    "open_workloads": "(beyond-paper: arrival-driven load)",
    "heterogeneous": "(beyond-paper: asymmetric cores + DVFS)",
    "multiapp": "(beyond-paper: N-app co-scheduling arbiter)",
    "cluster": "(beyond-paper: multi-node placement + locality guard)",
    "simperf": "(simulator event-loop throughput)",
    "threadperf": "(real-thread executor throughput)",
    "faults": "(beyond-paper: power caps, core faults, thermal)",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of "
                    + ",".join(SECTIONS))
    ap.add_argument("--json-dir", default=None,
                    help="where BENCH_<section>.json files are written "
                    "(default: cwd; in --smoke mode JSON is skipped "
                    "unless this is given)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run of every section (CI)")
    args = ap.parse_args()
    wanted = args.only.split(",") if args.only else SECTIONS
    write_json = args.json_dir is not None or not args.smoke
    json_dir = Path(args.json_dir) if args.json_dir is not None \
        else Path(".")
    if write_json:
        json_dir.mkdir(parents=True, exist_ok=True)

    # A failing section must not abort the others, but it MUST fail the
    # run: CI used to go green when an early section raised (the later
    # sections never ran) or would have gone green had we swallowed
    # errors here.  Run everything, report per section, exit nonzero if
    # anything failed.
    failed: list[str] = []
    for name in wanted:
        print(f"### bench_{name} {CAPTIONS.get(name, '')}"
              + (" [smoke]" if args.smoke else ""))
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
            rows = mod.run(smoke=args.smoke)
        except Exception:
            traceback.print_exc()
            failed.append(name)
            print(f"### bench_{name} FAILED after "
                  f"{time.time() - t0:.1f}s\n")
            continue
        elapsed = time.time() - t0
        if write_json and isinstance(rows, list) and rows:
            out = json_dir / f"BENCH_{name}.json"
            out.write_text(json.dumps(
                {"section": name, "elapsed_s": round(elapsed, 2),
                 "smoke": args.smoke, "rows": rows}, indent=1))
            print(f"### wrote {out}")
        print(f"### bench_{name} done in {elapsed:.1f}s\n")
    if failed:
        print(f"### {len(failed)} section(s) failed: {', '.join(failed)}")
        sys.exit(1)


if __name__ == "__main__":
    main()
