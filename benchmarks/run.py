"""Benchmark driver — one section per paper table/figure plus the
beyond-paper serving benchmark and the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--only accuracy,...]
"""

from __future__ import annotations

import argparse
import time

SECTIONS = ["accuracy", "policies", "sharing", "overhead", "serving",
            "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of "
                    + ",".join(SECTIONS))
    args = ap.parse_args()
    wanted = args.only.split(",") if args.only else SECTIONS

    for name in wanted:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        print(f"### bench_{name} "
              f"{'(paper Table 2)' if name == 'accuracy' else ''}"
              f"{'(paper Figs 3-4)' if name == 'policies' else ''}"
              f"{'(paper Table 3)' if name == 'sharing' else ''}"
              f"{'(paper §5)' if name == 'overhead' else ''}")
        t0 = time.time()
        mod.run()
        print(f"### bench_{name} done in {time.time() - t0:.1f}s\n")


if __name__ == "__main__":
    main()
