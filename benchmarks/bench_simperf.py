"""Simulator event-loop throughput (the PR-5 hot-path overhaul).

Measures events/second of ``SimCluster.run`` across the four scenario
families the repo sweeps at cluster scale — {closed 100k-task Cholesky,
Poisson open workload, N=4 multi-app co-schedule, HYBRID-PE
heterogeneous} × {busy, prediction, dlb-prediction, hetero-prediction}
— in both scheduler modes:

* ``fast``       — the default lock-free sequential scheduler path;
* ``threadsafe`` — the locked reference scheduler
  (``SimCluster(..., threadsafe=True)``), pinned observationally
  identical by ``tests/test_simperf.py``.

Every scenario also emits a ``baseline`` row: events/sec of the same
scenario measured with this same harness (``time.process_time``,
best-of-N) at the pre-overhaul commit (bc6f732, PR 4).  Those numbers
are frozen constants — the old code no longer exists in the tree — and
they are what the acceptance speedups are computed against.

Cross-machine comparability: rows carry ``calibration`` — the wall
seconds this interpreter needs for a fixed pure-Python loop — so a
re-run on different silicon compares *normalized* throughput
(events/sec × calibration), not absolute times.  The throughput-floor
pin test in ``tests/test_simperf.py`` uses exactly that ratio.
"""

from __future__ import annotations

import time

from repro.core.sharing import ResourceBroker
from repro.runtime import HYBRID_PE, MN4, SimCluster, SimJobSpec
from repro.workloads.arrivals import PoissonArrivals
from repro.workloads.cholesky import build_cholesky

from .common import emit

#: pre-overhaul events/sec (commit bc6f732) — same scenarios, same
#: harness (process_time, best-of-3), measured on the machine that
#: produced the committed BENCH_simperf.json (calibration ≈ 0.09 s)
BASELINE_EVENTS_PER_SEC = {
    "closed-cholesky-100k/busy": 70_095.9,
    "closed-cholesky-100k/prediction": 31_103.7,
    "open-poisson/prediction": 42_351.4,
    "multiapp-n4/dlb-prediction": 42_869.5,
    "hetero-hybridpe/hetero-prediction": 20_216.8,
}


def calibrate() -> float:
    """Seconds of CPU for a fixed pure-Python workload — the machine
    speed yardstick that makes committed events/sec portable."""
    t0 = time.process_time()
    acc = 0
    for i in range(2_000_000):
        acc += i * i
    return time.process_time() - t0


def _scenarios(smoke: bool):
    """(name, machine, spec-builder) per scenario; builders return fresh
    specs each call (schedulers mutate task state)."""
    p_closed = 20 if smoke else 84          # 1 540 vs 102 340 tasks
    p_open = 14 if smoke else 42
    p_app = 10 if smoke else 28

    def closed(policy):
        def mk():
            return [SimJobSpec(
                name="job0", policy=policy,
                graph=build_cholesky("fine", p=p_closed, seed=0))]
        return mk

    def open_poisson():
        return [SimJobSpec(
            name="job0", policy="prediction",
            graph=build_cholesky("fine", p=p_open, seed=0),
            arrivals=PoissonArrivals(rate=200_000.0, seed=1))]

    def multi():
        return [SimJobSpec(
            name=f"app{i}", policy="dlb-prediction",
            graph=build_cholesky("fine", p=p_app, seed=i),
            cpus=list(range(i * 12, (i + 1) * 12))) for i in range(4)]

    def hetero():
        return [SimJobSpec(
            name="job0", policy="hetero-prediction",
            graph=build_cholesky("fine", p=p_open, seed=0))]

    return [
        ("closed-cholesky-100k/busy", MN4, closed("busy")),
        ("closed-cholesky-100k/prediction", MN4, closed("prediction")),
        ("open-poisson/prediction", MN4, open_poisson),
        ("multiapp-n4/dlb-prediction", MN4, multi),
        ("hetero-hybridpe/hetero-prediction", HYBRID_PE, hetero),
    ]


def _measure(machine, mk_specs, threadsafe: bool, reps: int,
             ) -> tuple[int, float]:
    """Best-of-``reps`` (events, cpu_seconds) for one scenario/mode."""
    best: tuple[float, int] | None = None
    for _ in range(reps):
        specs = mk_specs()
        broker = ResourceBroker() if len(specs) > 1 else None
        cluster = SimCluster(machine, broker=broker,
                             threadsafe=threadsafe)
        for spec in specs:
            cluster.add_job(spec)
        t0 = time.process_time()
        cluster.run()
        cpu = time.process_time() - t0
        if best is None or cpu < best[0]:
            best = (cpu, cluster.events_processed)
    assert best is not None
    return best[1], best[0]


def run(smoke: bool = False) -> list[dict]:
    reps = 1 if smoke else 3
    calibration = calibrate()
    rows = []
    for name, machine, mk_specs in _scenarios(smoke):
        if not smoke:
            # Baseline rows/ratios only make sense at full scale: the
            # recorded constants were measured on the full scenarios,
            # and smoke shrinks the graphs to seconds-scale stand-ins.
            rows.append({
                "bench": "simperf", "scenario": name, "mode": "baseline",
                "events_per_sec": BASELINE_EVENTS_PER_SEC[name],
                "note": "pre-overhaul (commit bc6f732), recorded "
                        "constant",
            })
            emit(rows[-1])
        per_mode: dict[str, float] = {}
        for mode, threadsafe in (("threadsafe", True), ("fast", False)):
            events, cpu = _measure(machine, mk_specs, threadsafe, reps)
            eps = events / cpu if cpu > 0 else float("inf")
            per_mode[mode] = eps
            rows.append({
                "bench": "simperf", "scenario": name, "mode": mode,
                "events": events, "cpu_s": round(cpu, 3),
                "events_per_sec": round(eps, 1),
                "calibration": round(calibration, 4),
            })
            if not smoke:
                rows[-1]["speedup_vs_baseline"] = round(
                    eps / BASELINE_EVENTS_PER_SEC[name], 2)
            emit(rows[-1])
        rows[-1]["speedup_vs_threadsafe"] = round(
            per_mode["fast"] / per_mode["threadsafe"], 2)
    return rows


if __name__ == "__main__":
    run()
