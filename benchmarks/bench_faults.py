"""Dynamic machine conditions — power caps, faults, thermal throttling.

Three scenarios over the conditions subsystem
(:mod:`repro.core.conditions`), each run for {busy, dlb-lewi,
prediction, hetero-prediction}:

**power-cap** — a facility power cap lands mid-run (after the
predictor's warmup) on {MN4, HYBRID-PE} split between two co-tenants
(Gauss-Seidel + STREAM, the paper's Table-3 pairing).  Compliance is
*machine-wide*: :class:`~repro.runtime.SimCluster` integrates the
summed draw of every live job against the cap, so two individually
modest tenants can still blow the budget together.  Busy keeps every
core spinning and violates the cap for the rest of the run; the
prediction policies have already parked the surplus cores, so their
draw sits under the cap with *zero* violation seconds — and their
aggregate EDP beats both busy (spin energy) and LeWI (reactive
shedding arrives late).  The broker-lending variant
(``dlb-prediction``) is the honest foil: on MN4 lending is also
cap-compliant, but on HYBRID-PE the co-tenant runs every borrowed
core hot, so lending *trades* cap compliance for makespan.

**faults** — two cores die mid-run, one recovers later.  In-flight
tasks are re-queued, so every policy completes the workload; the
interesting column is *graceful degradation*: perturbed vs. healthy
makespan/EDP for the same policy.

**thermal** — HYBRID-PE's P-cores are pinned to half frequency mid-run.
The frequency-aware predictors re-plan against the throttled speeds;
busy just runs slower.

Headline artifact: ``BENCH_faults.json`` (``python -m benchmarks.run
--only faults``).
"""

from __future__ import annotations

import random

from repro.core import GovernorSpec, ResourceBroker
from repro.core.conditions import (ConditionTimeline, core_fail,
                                   core_recover, power_cap,
                                   thermal_throttle)
from repro.runtime import HYBRID_PE, MN4, SimCluster, SimJobSpec, Task, \
    TaskGraph
from repro.workloads import build_gauss_seidel, build_stream

from .common import emit

POLICIES = ("busy", "dlb-lewi", "prediction", "hetero-prediction")
POWER_POLICIES = POLICIES + ("dlb-prediction",)

#: power-cap scenario per machine: (co-tenant core split, cap watts,
#: cap instant as a fraction of busy's healthy makespan).  The cap
#: sits between the prediction policies' parked draw and busy's
#: all-cores-hot draw: MN4 busy spins 48 W (48 × 1.0) while the
#: predictors settle under 18 W once the surplus is parked/lent;
#: HYBRID-PE busy draws 14.4 W (8 P + 16 E × 0.4) while prediction's
#: parked wavefront sits ≈ 13 W.
POWER_SCENARIO = {MN4.name: (24, 18.0, 0.55),
                  HYBRID_PE.name: (12, 13.0, 0.35)}


def wave_graph(seed: int = 0, n_waves: int = 40, width: int = 8,
               service: tuple[float, float] = (5e-5, 2e-4)):
    """Narrow barrier-separated waves: enough repetition for the
    predictor to learn the width, narrow enough that most of the
    machine is surplus — the power-cap scenario's whole point."""
    rng = random.Random(seed)
    lo, hi = service
    g = TaskGraph()
    prev = None
    for _ in range(n_waves):
        wave = [Task("wave", cost=1.0,
                     service_time=rng.uniform(lo, hi))
                for _ in range(width)]
        for t in wave:
            if prev is not None:
                t.depends_on(prev)
            g.add(t)
        bar = Task("barrier", cost=0.1, service_time=1e-5)
        for t in wave:
            bar.depends_on(t)
        g.add(bar)
        prev = bar
    return g


def _run(machine, policy: str, graph,
         timeline: ConditionTimeline | None = None):
    spec = GovernorSpec(
        resources=machine.n_cores, policy=policy, monitoring=True,
        topology=machine.topology() if machine.core_types else None)
    broker = ResourceBroker() if policy.startswith("dlb-") else None
    cl = SimCluster(machine, broker=broker, conditions=timeline)
    cl.add_job(SimJobSpec(name="app", graph=graph, governor=spec,
                          cpus=list(range(machine.n_cores))))
    return cl.run()["app"]


def _two_app_run(machine, policy: str, split: int, smoke: bool,
                 timeline: ConditionTimeline | None = None):
    """Gauss-Seidel + STREAM co-tenants, each on half the machine.
    Returns ``(makespan, energy, machine_cap_violation_s)`` where
    makespan is the *cluster* makespan and energy the summed draw."""
    gs_kw = dict(steps=6, bi=6, bj=6, block_elems=100_000, seed=0) \
        if smoke else dict(steps=12, bi=8, bj=8, block_elems=300_000,
                           seed=0)
    st_kw = dict(rounds=5, blocks=120, seed=1) if smoke \
        else dict(rounds=10, blocks=300, seed=1)
    broker = ResourceBroker() if policy.startswith("dlb-") else None
    cl = SimCluster(machine, broker=broker, conditions=timeline)
    cl.add_job(SimJobSpec(name="gauss", graph=build_gauss_seidel(**gs_kw),
                          policy=policy, cpus=list(range(split))))
    cl.add_job(SimJobSpec(name="stream", graph=build_stream(**st_kw),
                          policy=policy,
                          cpus=list(range(split, machine.n_cores))))
    reports = cl.run()
    makespan = max(r.makespan for r in reports.values())
    energy = sum(r.energy for r in reports.values())
    return makespan, energy, cl.machine_cap_violation_s


def _power_rows(smoke: bool) -> list[dict]:
    rows: list[dict] = []
    for machine in (MN4, HYBRID_PE):
        split, cap, frac = POWER_SCENARIO[machine.name]
        # the cap lands after the predictor's warmup — a facility
        # curtailment order mid-run, not a boot-time constraint; the
        # instant is the same for every policy (anchored to busy's
        # healthy makespan, so it falls while both tenants are live)
        t_ref, _, _ = _two_app_run(machine, "busy", split, smoke)
        tl = ConditionTimeline([power_cap(frac * t_ref, cap)])
        for policy in POWER_POLICIES:
            mk, energy, violation = _two_app_run(machine, policy, split,
                                                 smoke, tl)
            rows.append({
                "bench": "faults", "scenario": "power-cap",
                "machine": machine.name, "policy": policy,
                "cap_w": cap,
                "cap_at_s": round(frac * t_ref, 6),
                "time_s": round(mk, 6),
                "energy_j": round(energy, 6),
                "edp": round(energy * mk, 6),
                "cap_violation_s": round(violation, 6),
            })
            emit(rows[-1])
    return rows


def _fault_rows(n_waves: int) -> list[dict]:
    rows: list[dict] = []
    for machine in (MN4, HYBRID_PE):
        t_ref = _run(machine, "busy", wave_graph(n_waves=n_waves)) \
            .makespan
        # two cores in the working set die mid-run; one comes back
        tl = ConditionTimeline([
            core_fail(0.20 * t_ref, 0),
            core_fail(0.30 * t_ref, 1),
            core_recover(0.70 * t_ref, 0),
        ])
        for policy in POLICIES:
            healthy = _run(machine, policy, wave_graph(n_waves=n_waves))
            hurt = _run(machine, policy, wave_graph(n_waves=n_waves), tl)
            rows.append({
                "bench": "faults", "scenario": "faults",
                "machine": machine.name, "policy": policy,
                "tasks": hurt.tasks_completed,
                "time_s": round(hurt.makespan, 6),
                "healthy_time_s": round(healthy.makespan, 6),
                "slowdown_pct": round(
                    100.0 * (hurt.makespan / healthy.makespan - 1.0), 2),
                "edp": round(hurt.edp, 6),
                "healthy_edp": round(healthy.edp, 6),
            })
            emit(rows[-1])
    return rows


def _thermal_rows(n_waves: int) -> list[dict]:
    rows: list[dict] = []
    machine = HYBRID_PE
    t_ref = _run(machine, "busy", wave_graph(n_waves=n_waves)).makespan
    tl = ConditionTimeline([thermal_throttle(0.25 * t_ref, "P", 0.5)])
    for policy in POLICIES:
        healthy = _run(machine, policy, wave_graph(n_waves=n_waves))
        hot = _run(machine, policy, wave_graph(n_waves=n_waves), tl)
        rows.append({
            "bench": "faults", "scenario": "thermal",
            "machine": machine.name, "policy": policy,
            "time_s": round(hot.makespan, 6),
            "healthy_time_s": round(healthy.makespan, 6),
            "slowdown_pct": round(
                100.0 * (hot.makespan / healthy.makespan - 1.0), 2),
            "edp": round(hot.edp, 6),
            "healthy_edp": round(healthy.edp, 6),
        })
        emit(rows[-1])
    return rows


def run(smoke: bool = False) -> list[dict]:
    n_waves = 6 if smoke else 40
    rows = _power_rows(smoke)
    rows += _fault_rows(n_waves)
    rows += _thermal_rows(n_waves)
    return rows


if __name__ == "__main__":
    run()
