"""Multi-node cluster sweep (beyond-paper: placement + locality guard).

Two scenarios over :class:`~repro.runtime.cluster.ClusterModel`:

**placement** — four apps (two heavy coarse MultiSAXPYs, a wavefronted
Gauss-Seidel, an HPCCG loop) co-scheduled on {MN4, HYBRID-PE} × N ∈
{1, 2, 3} nodes, comparing static round-robin placement against the
arbiter's prediction-driven best-fit-decreasing (each app's own
predictor supplies its demand estimate).  Submission order is chosen so
round-robin lands both heavy apps on node 0 at N=2 — the co-location
mistake demand-blind placement cannot see.  Once a light app drains,
its cores flow to its co-tenant through the broker (local lends, no
remote penalty), so separating the heavies compounds.

**hetero-guard** — 2 × HYBRID-PE with the saturated SAXPY borrowing
across nodes, guard-on (``min_borrow_speed`` default: remote E cores
deliver 0.55/(1+p) < 0.55 of an own core and are refused; remote P
cores still pay) vs guard-off (borrow anything), swept over the
fabric's ``remote_penalty``.  On a fast fabric extra slow silicon still
wins aggregate EDP; past the crossover the guard's refusals win — the
count of refused losing borrows is reported either way.
"""

from __future__ import annotations

from repro.core.governor import GovernorSpec
from repro.runtime import HYBRID_PE, MN4, ClusterModel, SimJobSpec, \
    run_multi_node
from repro.workloads import (build_gauss_seidel, build_hpccg,
                             build_multisaxpy)

from .common import emit

PLACEMENTS = ("round-robin", "predicted")

#: submission order matters: round-robin is order-blind, so the two
#: heavy SAXPYs (first and third) co-locate on node 0 at N=2
APP_KW = {
    "saxpyA": ("saxpy", dict(grain="coarse", generations=12, blocks=120,
                             block_elems=400_000, seed=0)),
    "gauss": ("gauss", dict(steps=4, bi=8, bj=8, block_elems=150_000,
                            seed=1)),
    "saxpyB": ("saxpy", dict(grain="coarse", generations=12, blocks=120,
                             block_elems=400_000, seed=2)),
    "hpccg": ("hpccg", dict(iterations=4, blocks=24,
                            rows_per_block=16_384, seed=3)),
}
SMOKE_KW = {
    "saxpyA": ("saxpy", dict(grain="coarse", generations=6, blocks=60,
                             block_elems=400_000, seed=0)),
    "gauss": ("gauss", dict(steps=3, bi=6, bj=6, block_elems=150_000,
                            seed=1)),
    "saxpyB": ("saxpy", dict(grain="coarse", generations=6, blocks=60,
                             block_elems=400_000, seed=2)),
    "hpccg": ("hpccg", dict(iterations=3, blocks=16,
                            rows_per_block=16_384, seed=3)),
}
_BUILDERS = {"saxpy": build_multisaxpy, "gauss": build_gauss_seidel,
             "hpccg": build_hpccg}

#: fabric dilation sweep for the guard scenario: 0.15 is the default
#: (fast fabric — extra remote silicon still pays), 0.8 is past the
#: crossover where refusing sub-own-speed borrows wins aggregate EDP
GUARD_PENALTIES = (0.15, 0.8)


def _specs(app_kw: dict, spec_of) -> list[SimJobSpec]:
    return [SimJobSpec(name=name,
                       graph=_BUILDERS[kind](**kw),
                       governor=spec_of(name))
            for name, (kind, kw) in app_kw.items()]


def _placement_rows(app_kw: dict, machines, ns) -> list[dict]:
    rows: list[dict] = []
    gov = GovernorSpec(resources=48, policy="dlb-prediction")
    for machine in machines:
        for n in ns:
            for placement in PLACEMENTS:
                cm = ClusterModel.symmetric(machine, n)
                rep = run_multi_node(cm, _specs(app_kw, lambda _: gov),
                                     placement=placement)
                for name in app_kw:
                    r = rep.apps[name]
                    rows.append({
                        "bench": "cluster", "scenario": "placement",
                        "machine": machine.name, "n_nodes": n,
                        "placement": placement, "app": name,
                        "node": r.node,
                        "time_s": round(r.makespan, 4),
                        "edp": round(r.edp, 4),
                        "transfers": r.transfers,
                    })
                    emit(rows[-1])
                rows.append({
                    "bench": "cluster", "scenario": "placement",
                    "machine": machine.name, "n_nodes": n,
                    "placement": placement, "app": "ALL",
                    "time_s": round(rep.makespan, 4),
                    "edp": round(rep.aggregate_edp, 4),
                    "energy_j": round(rep.aggregate_energy, 4),
                    "transfers": sum(r.transfers
                                     for r in rep.apps.values()),
                    "guard_refusals": sum(
                        r.sharing.get("guard_refusals", 0)
                        for r in rep.apps.values()),
                })
                emit(rows[-1])
    return rows


def _guard_rows(app_kw: dict) -> list[dict]:
    """2 × HYBRID-PE: the guard's refused remote-E borrows vs taking
    every core the broker offers, across the fabric-penalty sweep."""
    rows: list[dict] = []
    duo = {k: app_kw[k] for k in ("saxpyA", "hpccg")}
    for penalty in GUARD_PENALTIES:
        for guard, msb in (("on", 1.0), ("off", 0.0)):
            cm = ClusterModel.symmetric(HYBRID_PE, 2,
                                        remote_penalty=penalty)
            gov = GovernorSpec(resources=24, policy="dlb-prediction",
                               min_borrow_speed=msb)
            rep = run_multi_node(cm, _specs(duo, lambda _: gov),
                                 placement="predicted")
            rows.append({
                "bench": "cluster", "scenario": "hetero-guard",
                "machine": "HYBRID-PEx2", "remote_penalty": penalty,
                "guard": guard, "app": "ALL",
                "time_s": round(rep.makespan, 4),
                "edp": round(rep.aggregate_edp, 4),
                "transfers": sum(r.transfers for r in rep.apps.values()),
                "guard_refusals": sum(r.sharing.get("guard_refusals", 0)
                                      for r in rep.apps.values()),
            })
            emit(rows[-1])
    return rows


def run(smoke: bool = False) -> list[dict]:
    app_kw = SMOKE_KW if smoke else APP_KW
    machines = (MN4,) if smoke else (MN4, HYBRID_PE)
    ns = (2,) if smoke else (1, 2, 3)
    rows = _placement_rows(app_kw, machines, ns)
    rows += _guard_rows(app_kw)
    return rows


if __name__ == "__main__":
    run()
