"""Import shim: make property-based tests degrade gracefully when
``hypothesis`` is not installed.

Test modules do ``from _hypothesis_compat import given, settings, st``
instead of importing hypothesis directly.  With hypothesis present this
re-exports the real objects; without it, ``@given(...)`` marks the test
as skipped (the deterministic tests in the same module still collect and
run), ``@settings(...)`` is a no-op, and ``st.<anything>(...)`` returns
inert placeholders.
"""

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Any ``st.xxx(...)`` call yields an inert placeholder."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _StrategyStub()

__all__ = ["given", "settings", "st", "HAS_HYPOTHESIS"]
