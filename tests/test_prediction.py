"""Algorithm 1 — Δ prediction."""

from _hypothesis_compat import given, settings, st

from repro.core.monitoring import TaskMonitor
from repro.core.prediction import CPUPredictor, PredictionConfig


def _seed_alpha(m: TaskMonitor, type_name: str, unitary: float,
                n: int = 6, cost: float = 1.0) -> None:
    for i in range(n):
        tid = hash((type_name, i)) % 10**9
        m.on_task_ready(tid, type_name, cost)
        m.on_task_execute(tid, type_name, cost)
        m.on_task_completed(tid, type_name, cost, unitary * cost)


class TestAlgorithm1:
    def test_delta_matches_workload(self):
        """48 tasks of 50 µs with f = 50 µs ⇒ γ = 48 ⇒ Δ = 48."""
        m = TaskMonitor(min_samples=3)
        _seed_alpha(m, "t", 50e-6)
        for i in range(48):
            m.on_task_ready(1000 + i, "t", 1.0)
        p = CPUPredictor(m, n_cpus=48,
                         config=PredictionConfig(rate_s=50e-6,
                                                 min_samples=3))
        assert p.compute_delta() == 48

    def test_delta_scales_with_granularity(self):
        """Half the work per window ⇒ half the CPUs (the adaptiveness
        to granularity of §3.2)."""
        m = TaskMonitor(min_samples=3)
        _seed_alpha(m, "t", 25e-6)           # 25 µs tasks
        for i in range(48):
            m.on_task_ready(1000 + i, "t", 1.0)
        p = CPUPredictor(m, n_cpus=48,
                         config=PredictionConfig(rate_s=50e-6,
                                                 min_samples=3))
        assert p.compute_delta() == 24

    def test_count_fallback_when_unreliable(self):
        """Too few samples ⇒ count-based Δ (coarse Cholesky behaviour)."""
        m = TaskMonitor(min_samples=100)
        for i in range(5):
            m.on_task_ready(i, "t", 123.0)
        p = CPUPredictor(m, n_cpus=48,
                         config=PredictionConfig(min_samples=100))
        assert p.compute_delta() == 5

    def test_delta_at_least_one_when_idle(self):
        m = TaskMonitor()
        p = CPUPredictor(m, n_cpus=8)
        assert p.compute_delta() == 1        # Alg 1: 0 < Δ

    def test_oversubscription_allowed_in_dlb_mode(self):
        m = TaskMonitor(min_samples=3)
        _seed_alpha(m, "t", 50e-6)
        for i in range(100):
            m.on_task_ready(1000 + i, "t", 1.0)
        p_local = CPUPredictor(m, n_cpus=8,
                               config=PredictionConfig(rate_s=50e-6,
                                                       min_samples=3))
        p_dlb = CPUPredictor(m, n_cpus=8, config=PredictionConfig(
            rate_s=50e-6, min_samples=3, allow_oversubscription=True))
        assert p_local.compute_delta() == 8
        assert p_dlb.compute_delta() > 8     # paper §3.3

    @given(n_cpus=st.integers(1, 256),
           tasks=st.lists(st.tuples(st.floats(1e-6, 1.0),
                                    st.integers(1, 50)),
                          min_size=0, max_size=10))
    @settings(max_examples=150, deadline=None)
    def test_invariant_bounds(self, n_cpus, tasks):
        """Property (Alg 1 Ensure): 1 ≤ Δ ≤ min(N_CPUs, ΣM_j) when work
        exists; Δ = 1 when idle."""
        m = TaskMonitor(min_samples=2)
        total = 0
        for j, (unitary, count) in enumerate(tasks):
            _seed_alpha(m, f"t{j}", unitary, n=3)
            for i in range(count):
                m.on_task_ready(10_000 + 100 * j + i, f"t{j}", 1.0)
            total += count
        p = CPUPredictor(m, n_cpus=n_cpus,
                         config=PredictionConfig(min_samples=2))
        d = p.compute_delta()
        if total == 0:
            assert d == 1
        else:
            assert 1 <= d <= min(n_cpus, total)

    def test_tick_publishes_atomically(self):
        m = TaskMonitor(min_samples=1)
        _seed_alpha(m, "t", 1e-3)
        for i in range(4):
            m.on_task_ready(100 + i, "t", 1.0)
        p = CPUPredictor(m, n_cpus=16)
        before = p.delta
        assert before == 16                  # optimistic start
        p.tick()
        assert p.delta == p.compute_delta()
        assert p.predictions_made == 1
