"""Multi-node ClusterModel: hierarchy invariants, flat ≡ 1-node parity
(byte-identical traces, equal reports, every registered policy),
multi-node placement/migration/locality guards, and the byte-exact
multi-node sim→sim replay round trip."""

import itertools

import pytest
from _hypothesis_compat import given, settings, st

import repro.runtime.task as task_mod
from repro.core import (EventBus, GovernorSpec, ResourceBroker,
                        jain_fairness)
from repro.core.arbiter import ClusterArbiter
from repro.core.governor import registered_policies
from repro.core.topology import CoreTopology, CoreType
from repro.runtime import (DVFS2, HYBRID_PE, ClusterModel, MachineModel,
                           SimCluster, SimJobSpec, predicted_demand,
                           run_multi_node)
from repro.trace import TraceRecorder, TraceReplayer
from repro.workloads import build_gauss_seidel, build_stream

M8 = MachineModel(name="M8", n_cores=8)

GS_KW = dict(steps=3, bi=4, bj=4, block_elems=300_000, seed=0)
ST_KW = dict(rounds=2, blocks=40, block_elems=40_000, seed=1)


def _fresh_graphs():
    """Deterministic task ids: byte-identical traces require identical
    ids, so every build resets the global counter first."""
    task_mod._ids = itertools.count()
    return build_gauss_seidel(**GS_KW), build_stream(**ST_KW)


# ---------------------------------------------------------------------------
# ClusterModel invariants


class TestClusterModel:
    def test_validation(self):
        with pytest.raises(ValueError, match="at least one node"):
            ClusterModel(nodes=())
        with pytest.raises(ValueError, match="must be 2x2"):
            ClusterModel(nodes=(M8, M8), distance=((0.0,),))
        with pytest.raises(ValueError, match="must be 0"):
            ClusterModel(nodes=(M8, M8),
                         distance=((1.0, 1.0), (1.0, 0.0)))
        with pytest.raises(ValueError, match="symmetric"):
            ClusterModel(nodes=(M8, M8),
                         distance=((0.0, 1.0), (2.0, 0.0)))
        with pytest.raises(ValueError, match=">= 0"):
            ClusterModel(nodes=(M8, M8),
                         distance=((0.0, -1.0), (-1.0, 0.0)))

    def test_global_id_space(self):
        cm = ClusterModel(nodes=(M8, HYBRID_PE, M8))
        assert cm.n_nodes == 3
        assert cm.n_cores == 8 + 24 + 8
        seen = []
        for node in range(cm.n_nodes):
            for c in cm.cores_of(node):
                assert cm.node_of(c) == node
                assert cm.base_of(node) + cm.local_id(c) == c
                assert cm.machine_of(c) is cm.nodes[node]
                seen.append(c)
        assert seen == list(range(cm.n_cores))   # exact partition
        with pytest.raises(IndexError):
            cm.node_of(cm.n_cores)
        with pytest.raises(IndexError):
            cm.node_of(-1)

    def test_locality_costs(self):
        cm = ClusterModel(nodes=(M8, M8, M8),
                          distance=((0.0, 1.0, 2.0),
                                    (1.0, 0.0, 1.0),
                                    (2.0, 1.0, 0.0)),
                          remote_penalty=0.25, transfer_latency=10e-6)
        assert cm.penalty(0, 0) == 1.0
        assert cm.penalty(0, 2) == pytest.approx(1.5)
        assert cm.penalty(2, 0) == cm.penalty(0, 2)
        assert cm.transfer_time(0, 1) == pytest.approx(10e-6)
        assert cm.transfer_time(0, 2) == pytest.approx(20e-6)
        assert cm.transfer_time(1, 1) == 0.0

    def test_type_and_speed_cross_node(self):
        cm = ClusterModel(nodes=(M8, HYBRID_PE))
        assert cm.type_of(0) == "core"
        assert cm.type_of(8) == "P"           # first HYBRID_PE core
        assert cm.type_of(8 + 23) == "E"
        assert cm.speed_of(8 + 23) == pytest.approx(0.55)
        assert cm.socket_of(0) == 0

    def test_round_trip(self):
        cm = ClusterModel(nodes=(M8, HYBRID_PE),
                          distance=((0.0, 2.0), (2.0, 0.0)),
                          transfer_latency=5e-6, remote_penalty=0.3,
                          migration_latency=1e-4, name="mix")
        assert ClusterModel.from_dict(cm.to_dict()) == cm

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(min_value=1, max_value=16),
                    min_size=1, max_size=5),
           st.floats(min_value=0.0, max_value=4.0))
    def test_partition_property(self, core_counts, d):
        nodes = tuple(MachineModel(name=f"n{i}", n_cores=k)
                      for i, k in enumerate(core_counts))
        n = len(nodes)
        dist = tuple(tuple(0.0 if i == j else d for j in range(n))
                     for i in range(n))
        cm = ClusterModel(nodes=nodes, distance=dist)
        # every global core id maps to exactly one node, and the
        # per-node ranges partition [0, n_cores)
        owners = [cm.node_of(c) for c in range(cm.n_cores)]
        for node in range(n):
            assert [c for c in range(cm.n_cores)
                    if owners[c] == node] == list(cm.cores_of(node))
            assert cm.penalty(node, node) == 1.0
        for i in range(n):
            for j in range(n):
                assert cm.penalty(i, j) == cm.penalty(j, i)
                assert cm.transfer_time(i, j) == cm.transfer_time(j, i)


# ---------------------------------------------------------------------------
# flat MachineModel ≡ 1-node ClusterModel, byte-for-byte


def _run_solo(machine, graph, gov, cpus, tmp_path, tag):
    cluster = SimCluster(machine)
    job = cluster.add_job(SimJobSpec(name="app", graph=graph,
                                     governor=gov, cpus=list(cpus)))
    rec = TraceRecorder()
    rec.attach(job.bus)
    report = cluster.run()["app"]
    path = tmp_path / f"{tag}.jsonl"
    rec.to_jsonl(path)
    return report, path.read_bytes()


def _run_pair(machine, gov, tmp_path, tag):
    """Two co-tenant apps through one broker (sharing policies need a
    co-tenant to trade CPUs with)."""
    task_mod._ids = itertools.count()
    g1 = build_gauss_seidel(**GS_KW)
    g2 = build_stream(**ST_KW)
    broker = ResourceBroker()
    cluster = SimCluster(machine, broker=broker)
    n = (machine.n_cores if isinstance(machine, MachineModel)
         else machine.n_cores)
    half = n // 2
    ja = cluster.add_job(SimJobSpec(name="a", graph=g1, governor=gov,
                                    cpus=list(range(half))))
    jb = cluster.add_job(SimJobSpec(name="b", graph=g2, governor=gov,
                                    cpus=list(range(half, n))))
    rec = TraceRecorder()
    rec.attach(ja.bus)
    rec.attach(jb.bus)
    reports = cluster.run()
    path = tmp_path / f"{tag}.jsonl"
    rec.to_jsonl(path)
    return reports, path.read_bytes()


class TestSingleNodeParity:
    """``ClusterModel.single(m)`` is byte-identical to the flat ``m``
    for every registered policy: same trace JSONL, equal reports."""

    @pytest.mark.parametrize("policy", registered_policies())
    def test_parity_m8(self, policy, tmp_path):
        machine = HYBRID_PE if policy == "hetero-prediction" else M8
        gov = GovernorSpec(resources=machine.n_cores, policy=policy)
        if policy in ("dlb-lewi", "dlb-hybrid", "dlb-prediction"):
            flat_rep, flat_bytes = _run_pair(machine, gov, tmp_path, "f")
            cl_rep, cl_bytes = _run_pair(
                ClusterModel.single(machine), gov, tmp_path, "c")
            assert flat_rep == cl_rep
        else:
            task_mod._ids = itertools.count()
            g = build_gauss_seidel(**GS_KW)
            flat_rep, flat_bytes = _run_solo(
                machine, g, gov, range(machine.n_cores), tmp_path, "f")
            task_mod._ids = itertools.count()
            g = build_gauss_seidel(**GS_KW)
            cl_rep, cl_bytes = _run_solo(
                ClusterModel.single(machine), g, gov,
                range(machine.n_cores), tmp_path, "c")
            assert flat_rep == cl_rep
        assert flat_bytes == cl_bytes
        assert len(flat_bytes) > 0

    def test_parity_dvfs2(self, tmp_path):
        """Frequency-planning machine: the per-socket DVFS path is also
        byte-identical through the 1-node cluster."""
        gov = GovernorSpec(resources=DVFS2.n_cores, policy="prediction")
        task_mod._ids = itertools.count()
        g = build_gauss_seidel(**GS_KW)
        flat_rep, flat_bytes = _run_solo(
            DVFS2, g, gov, range(DVFS2.n_cores), tmp_path, "f")
        task_mod._ids = itertools.count()
        g = build_gauss_seidel(**GS_KW)
        cl_rep, cl_bytes = _run_solo(
            ClusterModel.single(DVFS2), g, gov,
            range(DVFS2.n_cores), tmp_path, "c")
        assert flat_rep == cl_rep
        assert flat_bytes == cl_bytes

    def test_single_node_report_has_no_node_stamp(self):
        task_mod._ids = itertools.count()
        g = build_gauss_seidel(**GS_KW)
        cluster = SimCluster(ClusterModel.single(M8))
        cluster.add_job(SimJobSpec(name="app", graph=g,
                                   governor=GovernorSpec(
                                       resources=8, policy="busy")))
        rep = cluster.run()["app"]
        assert rep.node is None
        assert rep.transfers == 0


# ---------------------------------------------------------------------------
# multi-node runs: placement, locality guards, transfers


def _specs(gov):
    g1, g2 = _fresh_graphs()
    return [SimJobSpec(name="a", graph=g1, governor=gov),
            SimJobSpec(name="b", graph=g2, governor=gov)]


class TestPlacement:
    def test_round_robin(self):
        homes = ClusterArbiter.place({"a": 9.0, "b": 1.0, "c": 5.0},
                                     [8, 8], policy="round-robin")
        assert homes == {"a": 0, "b": 1, "c": 0}

    def test_predicted_is_best_fit_decreasing(self):
        homes = ClusterArbiter.place({"a": 10.0, "b": 9.0, "c": 1.0},
                                     [16, 16], policy="predicted")
        # heaviest to node 0, next to the now-emptier node 1, then the
        # light app back onto node 0 (most remaining: 6 vs 7 → node 1)
        assert homes["a"] == 0
        assert homes["b"] == 1
        assert homes["c"] == 1
    def test_unknown_policy(self):
        with pytest.raises(ValueError, match="unknown placement"):
            ClusterArbiter.place({"a": 1.0}, [8], policy="nope")

    def test_predicted_demand_orders_apps(self):
        g1, g2 = _fresh_graphs()
        d_gs = predicted_demand(SimJobSpec(name="a", graph=g1,
                                           policy="busy"))
        d_st = predicted_demand(SimJobSpec(name="b", graph=g2,
                                           policy="busy"))
        # stream is embarrassingly parallel, gauss-seidel wavefronted
        assert d_st > d_gs > 0.0

    def test_predicted_demand_empty_graph(self):
        from repro.runtime.task import TaskGraph

        assert predicted_demand(
            SimJobSpec(name="a", graph=TaskGraph(), policy="busy")) == 0.0

    def test_run_multi_node_places_heavy_apart(self):
        cm = ClusterModel.symmetric(M8, 2)
        gov = GovernorSpec(resources=8, policy="dlb-prediction",
                           min_borrow_speed=0.0)
        rep = run_multi_node(cm, _specs(gov), placement="predicted")
        assert set(rep.placement.values()) == {0, 1}   # one app per node
        assert rep.apps["a"].node == rep.placement["a"]
        assert rep.apps["b"].node == rep.placement["b"]

    def test_explicit_placement_mapping(self):
        cm = ClusterModel.symmetric(M8, 2)
        gov = GovernorSpec(resources=8, policy="busy")
        rep = run_multi_node(cm, _specs(gov),
                             placement={"a": 1, "b": 1})
        assert rep.placement == {"a": 1, "b": 1}
        # both apps split node 1's eight cores
        assert rep.apps["a"].makespan > 0
        assert rep.apps["b"].makespan > 0


class TestLocalityGuards:
    CM = ClusterModel.symmetric(M8, 2)

    def test_default_guard_refuses_remote_borrows(self):
        # min_borrow_speed defaults to 1.0: a remote core runs at
        # 1/penalty < 1.0 of an own core, so every remote borrow is a
        # losing borrow and must be refused — and counted.
        gov = GovernorSpec(resources=8, policy="dlb-prediction")
        rep = run_multi_node(self.CM, _specs(gov), placement="predicted")
        total_refusals = sum(r.sharing.get("guard_refusals", 0)
                             for r in rep.apps.values())
        assert total_refusals >= 1
        assert all(r.transfers == 0 for r in rep.apps.values())

    def test_relaxed_guard_allows_remote_borrows(self):
        gov = GovernorSpec(resources=8, policy="dlb-prediction",
                           min_borrow_speed=0.0)
        rep = run_multi_node(self.CM, _specs(gov), placement="predicted")
        assert sum(r.transfers for r in rep.apps.values()) > 0
        assert sum(r.transfer_seconds for r in rep.apps.values()) > 0

    def test_max_borrow_distance_refuses_far_nodes(self):
        # speed guard disabled, distance guard alone: unit distance
        # exceeds 0.5, so remote borrowing is still refused.
        gov = GovernorSpec(resources=8, policy="dlb-prediction",
                           min_borrow_speed=0.0, max_borrow_distance=0.5)
        rep = run_multi_node(self.CM, _specs(gov), placement="predicted")
        assert sum(r.sharing.get("guard_refusals", 0)
                   for r in rep.apps.values()) >= 1
        assert all(r.transfers == 0 for r in rep.apps.values())


# ---------------------------------------------------------------------------
# migration


class TestMigration:
    def test_flat_cluster_rejects_migration(self):
        cluster = SimCluster(M8)
        with pytest.raises(ValueError, match="multi-node"):
            cluster.migrate_job("app", 1)

    def test_migrate_before_run(self):
        cm = ClusterModel.symmetric(M8, 2)
        task_mod._ids = itertools.count()
        g = build_gauss_seidel(**GS_KW)
        cluster = SimCluster(cm)
        cluster.add_job(SimJobSpec(name="a", graph=g,
                                   governor=GovernorSpec(
                                       resources=8, policy="busy"),
                                   node=0))
        cluster.migrate_job("a", 1)
        rep = cluster.run()["a"]
        assert rep.node == 1
        assert rep.migrations == 1
        assert rep.makespan > 0

    def test_migrate_same_node_is_noop(self):
        cm = ClusterModel.symmetric(M8, 2)
        task_mod._ids = itertools.count()
        g = build_gauss_seidel(**GS_KW)
        cluster = SimCluster(cm)
        cluster.add_job(SimJobSpec(name="a", graph=g,
                                   governor=GovernorSpec(
                                       resources=8, policy="busy"),
                                   node=0))
        cluster.migrate_job("a", 0)
        rep = cluster.run()["a"]
        assert rep.node == 0
        assert rep.migrations == 0

    def test_migrate_rejects_full_destination(self):
        cm = ClusterModel.symmetric(M8, 2)
        g1, g2 = _fresh_graphs()
        gov = GovernorSpec(resources=8, policy="busy")
        cluster = SimCluster(cm, broker=ResourceBroker())
        cluster.add_job(SimJobSpec(name="a", graph=g1, governor=gov,
                                   node=0))
        cluster.add_job(SimJobSpec(name="b", graph=g2, governor=gov,
                                   node=1))
        with pytest.raises(ValueError, match="free core"):
            cluster.migrate_job("a", 1)
        with pytest.raises(ValueError, match="out of range"):
            cluster.migrate_job("a", 2)


# ---------------------------------------------------------------------------
# multi-node sim→sim replay: byte-exact round trip


class TestMultiNodeReplay:
    def _record(self, cm, g1, g2, tmp_path, tag):
        gov = GovernorSpec(resources=8, policy="dlb-prediction",
                           min_borrow_speed=0.0)
        broker = ResourceBroker()
        cluster = SimCluster(cm, broker=broker)
        ja = cluster.add_job(SimJobSpec(name="a", graph=g1,
                                        governor=gov, node=0))
        jb = cluster.add_job(SimJobSpec(name="b", graph=g2,
                                        governor=gov, node=1))
        rec = TraceRecorder()
        rec.attach(ja.bus)
        rec.attach(jb.bus)
        reports = cluster.run()
        path = tmp_path / f"{tag}.jsonl"
        rec.to_jsonl(path)
        return reports, path

    def test_round_trip_is_byte_exact(self, tmp_path):
        cm = ClusterModel.symmetric(M8, 2)
        task_mod._ids = itertools.count()
        g1 = build_gauss_seidel(**GS_KW)
        g2 = build_stream(**ST_KW)
        live_reports, live_path = self._record(cm, g1, g2, tmp_path,
                                               "live")
        # the scenario must actually exercise cross-node locality
        assert sum(r.transfers for r in live_reports.values()) > 0

        replayer = TraceReplayer(live_path)
        task_mod._ids = itertools.count()
        ga, _ = replayer.for_app("a").build()
        gb, _ = replayer.for_app("b").build()
        replay_reports, replay_path = self._record(
            cm.replay_model(), ga, gb, tmp_path, "replay")

        assert live_path.read_bytes() == replay_path.read_bytes()
        for app in ("a", "b"):
            assert (replay_reports[app].makespan
                    == live_reports[app].makespan)
            assert (replay_reports[app].transfers
                    == live_reports[app].transfers)

    def test_for_app_unknown_raises_keyerror(self, tmp_path):
        cm = ClusterModel.symmetric(M8, 2)
        task_mod._ids = itertools.count()
        g1 = build_gauss_seidel(**GS_KW)
        g2 = build_stream(**ST_KW)
        _, path = self._record(cm, g1, g2, tmp_path, "t")
        replayer = TraceReplayer(path)
        with pytest.raises(KeyError) as exc:
            replayer.for_app("nope")
        assert "'a'" in str(exc.value) and "'b'" in str(exc.value)


# ---------------------------------------------------------------------------
# satellites: fairness, sockets, spec round trips


class TestJainFairness:
    def test_empty_is_perfectly_fair(self):
        assert jain_fairness({}) == 1.0

    def test_all_zero_is_perfectly_fair(self):
        assert jain_fairness({"a": 0.0, "b": 0.0}) == 1.0

    def test_unequal_is_below_one(self):
        assert jain_fairness({"a": 1.0, "b": 3.0}) < 1.0


class TestSocketTier:
    S2 = MachineModel(
        name="S2", n_cores=8,
        core_types=(CoreType(name="L", count=4, socket=0),
                    CoreType(name="R", count=4, socket=1)),
        remote_socket_penalty=1.5)

    def test_topology_socket_accessors(self):
        topo = self.S2.topology()
        assert topo.n_sockets == 2
        assert [topo.socket_of(i) for i in range(8)] == [0] * 4 + [1] * 4
        assert topo.fastest_first()[0].socket == 0

    def test_cross_socket_penalty_stretches_makespan(self):
        from dataclasses import replace

        from repro.runtime.task import Task, TaskGraph

        def makespan(machine):
            # a root fanning out to one task per core: half the
            # children consume the root's output from the other socket
            task_mod._ids = itertools.count()
            g = TaskGraph()
            root = g.add(Task(type_name="t", cost=1.0,
                              service_time=1e-3))
            for _ in range(8):
                g.add(Task(type_name="t", cost=1.0, service_time=1e-3,
                           deps=[root]))
            cluster = SimCluster(machine)
            cluster.add_job(SimJobSpec(
                name="a", graph=g,
                governor=GovernorSpec(resources=8, policy="busy")))
            return cluster.run()["a"].makespan

        no_penalty = replace(self.S2, remote_socket_penalty=1.0)
        assert makespan(self.S2) > makespan(no_penalty)

    def test_core_type_socket_round_trip(self):
        ct = CoreType(name="R", count=4, socket=1)
        d = ct.to_dict()
        assert d["socket"] == 1
        assert CoreType.from_dict(d) == ct
        # socket 0 stays implicit: pre-hierarchy dicts parse unchanged
        assert "socket" not in CoreType(name="L", count=4).to_dict()

    def test_topology_round_trip(self):
        topo = self.S2.topology()
        assert CoreTopology.from_dict(topo.to_dict()) == topo

    def test_machine_round_trip(self):
        d = self.S2.to_dict()
        assert d["remote_socket_penalty"] == 1.5
        assert MachineModel.from_dict(d) == self.S2
        assert "remote_socket_penalty" not in M8.to_dict()

    def test_governor_spec_round_trip(self):
        spec = GovernorSpec(resources=8, policy="busy",
                            max_borrow_distance=1.5)
        d = spec.to_dict()
        assert d["max_borrow_distance"] == 1.5
        assert GovernorSpec.from_dict(d) == spec
        assert "max_borrow_distance" not in GovernorSpec(
            resources=8, policy="busy").to_dict()

    def test_invalid_socket_rejected(self):
        with pytest.raises(ValueError, match="socket"):
            CoreType(name="X", count=1, socket=-1)
        with pytest.raises(ValueError, match="remote_socket_penalty"):
            MachineModel(name="bad", n_cores=2,
                         remote_socket_penalty=0.5)
