"""SLO-aware overload protection: admission, retries, hedging, breakers,
brownout, and the discrete-event serving frontend.

Everything here runs in virtual time — no jax, no wall clock — except
the ServingEngine satellite tests at the bottom, which build the real
engine (smoke config) but never decode.
"""

import json

import pytest

from _hypothesis_compat import given, settings, st
from repro.core.conditions import (ConditionTimeline, core_fail,
                                   core_recover, power_cap, straggler,
                                   thermal_throttle)
from repro.core.events import EventBus, EventKind
from repro.runtime.machine import HYBRID_PE, MachineModel
from repro.serving import (AdmissionController, CircuitBreaker,
                           SLOClass, ServingModel, SimRequest, SimServing,
                           build_requests, cap_allowance)
from repro.serving.slo import BATCH, INTERACTIVE, STANDARD
from repro.trace import TraceRecorder
from repro.workloads.arrivals import PoissonArrivals

TINY = MachineModel(name="tiny", n_cores=4)


def _model(machine=TINY, **kw):
    kw.setdefault("slots_per_replica", 2)
    return ServingModel(machine=machine, **kw)


# ---------------------------------------------------------------------------
# Admission controller
# ---------------------------------------------------------------------------


def test_admission_queue_bound():
    adm = AdmissionController(max_queue_depth=3)
    assert adm.shed_reason(now=0.0, queue_depth=2, slo=None,
                           submitted_at=0.0) is None
    assert adm.shed_reason(now=0.0, queue_depth=3, slo=None,
                           submitted_at=0.0) == "queue"


def test_admission_deadline_infeasibility():
    adm = AdmissionController()
    slo = SLOClass("t", deadline_s=1.0)
    # eta = now + wait + service vs submitted_at + deadline * slack
    assert adm.shed_reason(now=0.0, queue_depth=0, slo=slo,
                           submitted_at=0.0, est_wait_s=0.3,
                           est_service_s=0.3) is None
    assert adm.shed_reason(now=0.0, queue_depth=0, slo=slo,
                           submitted_at=0.0, est_wait_s=0.8,
                           est_service_s=0.3) == "deadline"
    # slack > 1 tolerates the same overshoot
    loose = AdmissionController(slack=1.5)
    assert loose.shed_reason(now=0.0, queue_depth=0, slo=slo,
                             submitted_at=0.0, est_wait_s=0.8,
                             est_service_s=0.3) is None
    # no SLO / no deadline: only the queue bound can shed
    assert adm.shed_reason(now=0.0, queue_depth=10 ** 6, slo=None,
                           submitted_at=0.0, est_wait_s=1e9) is None


def test_admission_validates():
    with pytest.raises(ValueError):
        AdmissionController(max_queue_depth=0)
    with pytest.raises(ValueError):
        AdmissionController(slack=0.0)


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------


def test_breaker_closed_to_open_to_half_open_to_closed():
    brk = CircuitBreaker(failure_threshold=2, reset_after_s=1.0,
                         probe_successes=2)
    assert brk.state(0.0) == CircuitBreaker.CLOSED
    brk.record_failure(0.1)
    assert brk.state(0.1) == CircuitBreaker.CLOSED
    brk.record_failure(0.2)
    assert brk.state(0.2) == CircuitBreaker.OPEN
    assert not brk.allow(0.5)
    # cooldown elapses: asking advances OPEN -> HALF_OPEN
    assert brk.state(1.2) == CircuitBreaker.HALF_OPEN
    assert brk.allow(1.2)
    brk.record_success(1.3)
    assert brk.state(1.3) == CircuitBreaker.HALF_OPEN  # 1 of 2 probes
    brk.record_success(1.4)
    assert brk.state(1.4) == CircuitBreaker.CLOSED


def test_breaker_half_open_failure_reopens():
    brk = CircuitBreaker(failure_threshold=1, reset_after_s=1.0)
    brk.record_failure(0.0)
    assert brk.state(1.5) == CircuitBreaker.HALF_OPEN
    brk.record_failure(1.6)
    assert brk.state(1.6) == CircuitBreaker.OPEN
    # the reopen restarts the cooldown from the failure instant
    assert brk.state(2.5) == CircuitBreaker.OPEN
    assert brk.state(2.7) == CircuitBreaker.HALF_OPEN


def test_breaker_success_resets_failure_streak():
    brk = CircuitBreaker(failure_threshold=2)
    brk.record_failure(0.0)
    brk.record_success(0.1)   # streak broken
    brk.record_failure(0.2)
    assert brk.state(0.2) == CircuitBreaker.CLOSED


def test_breaker_force_open():
    brk = CircuitBreaker(failure_threshold=100, reset_after_s=2.0)
    brk.force_open(5.0)
    assert brk.state(6.9) == CircuitBreaker.OPEN
    assert brk.state(7.0) == CircuitBreaker.HALF_OPEN


# ---------------------------------------------------------------------------
# Power-cap allowance
# ---------------------------------------------------------------------------


def test_cap_allowance_homogeneous():
    # 48 replicas at (1.0 active, 0.1 idle) under a 30 W cap:
    # budget = 30 - 4.8 = 25.2, step 0.9 -> exactly 28 (equality holds)
    draws = [(1.0, 0.1)] * 48
    assert cap_allowance(30.0, draws) == 28
    assert cap_allowance(1000.0, draws) == 48
    assert cap_allowance(0.0, draws) == 0


def test_cap_allowance_ordered_greedy():
    # fastest-first ordering is the caller's: P cores cost 0.9/step,
    # E cores 0.35/step — the allowance charges them in list order
    draws = [(1.0, 0.1)] * 2 + [(0.4, 0.05)] * 2
    # budget = cap - 0.3; two P steps = 1.8, each E step 0.35
    assert cap_allowance(2.1, draws) == 2
    assert cap_allowance(2.45, draws) == 3
    assert cap_allowance(2.8, draws) == 4


# ---------------------------------------------------------------------------
# SLO classes: backoff + serialization
# ---------------------------------------------------------------------------


def test_backoff_seeded_and_order_independent():
    slo = SLOClass("t", backoff_base_s=0.1, backoff_jitter=0.25)
    a = slo.backoff(1, seed=7, request_id=42)
    b = slo.backoff(2, seed=7, request_id=42)
    # keyed on (seed, request_id, attempt): replaying in any order or
    # interleaving other requests changes nothing
    slo.backoff(1, seed=7, request_id=99)
    assert slo.backoff(1, seed=7, request_id=42) == a
    assert slo.backoff(2, seed=7, request_id=42) == b
    # exponential base with bounded jitter
    assert 0.075 <= a <= 0.125
    assert 0.15 <= b <= 0.25
    # different key -> (almost surely) different draw
    assert slo.backoff(1, seed=8, request_id=42) != a


def test_backoff_no_jitter_is_exact():
    slo = SLOClass("t", backoff_base_s=0.2, backoff_jitter=0.0)
    assert slo.backoff(1) == 0.2
    assert slo.backoff(3) == 0.8
    with pytest.raises(ValueError):
        slo.backoff(0)


def test_slo_roundtrip():
    for slo in (INTERACTIVE, STANDARD, BATCH,
                SLOClass("x", deadline_s=2.0, priority=5, timeout_s=0.5,
                         retry_budget=3, backoff_base_s=0.01,
                         backoff_jitter=0.0, hedge_after_s=0.3,
                         best_effort=True)):
        assert SLOClass.from_dict(slo.to_dict()) == slo


# ---------------------------------------------------------------------------
# SimServing: targeted scenarios
# ---------------------------------------------------------------------------


def test_sim_completes_unloaded():
    reqs = [SimRequest(rid=i, release=0.1 * i, prompt=100, new=32,
                       slo=STANDARD) for i in range(20)]
    sim = SimServing(_model(), reqs, policy="busy").run()
    rep = sim.report("t")
    assert rep.serving["completed"] == 20
    assert rep.serving["shed"] == 0 and rep.serving["timed_out"] == 0
    assert rep.serving["attainment"] == 1.0
    assert all(r.outcome == "completed" for r in sim.requests)


def test_timeout_retry_then_give_up():
    # service (100/4000 + 80/160 = 0.525 s) >> timeout 0.1 s: every
    # attempt times out; one retry is granted, then the request fails
    slo = SLOClass("tight", deadline_s=30.0, timeout_s=0.1,
                   retry_budget=1, backoff_base_s=0.05)
    reqs = [SimRequest(rid=0, release=0.0, prompt=100, new=80, slo=slo)]
    bus = EventBus()
    rec = TraceRecorder(bus)
    sim = SimServing(_model(), reqs, policy="busy", bus=bus, seed=3).run()
    req = sim.requests[0]
    assert req.outcome == "timed_out"
    assert req.tries == 2
    rep = sim.report("t")
    assert rep.serving["retries"] == 1
    assert rep.serving["timed_out"] == 1
    assert rep.serving["shed_by_reason"] == {"timeout": 1}
    # the RETRY event carries the seeded backoff the sim actually used
    retry_evs = [e for e in rec.events if e.kind is EventKind.RETRY]
    assert len(retry_evs) == 1
    assert retry_evs[0].data["backoff_s"] == \
        slo.backoff(1, seed=3, request_id=0)
    # conservation through the retry: monitor fully drained
    assert sim.monitor.live_instances() == 0


def test_retry_skipped_when_deadline_already_lost():
    # the deadline admits the request (service fits) but the huge
    # backoff would land the retry beyond release + deadline, so the
    # retry is not even scheduled
    slo = SLOClass("hopeless", deadline_s=0.6, timeout_s=0.1,
                   retry_budget=5, backoff_base_s=10.0)
    reqs = [SimRequest(rid=0, release=0.0, prompt=100, new=80, slo=slo)]
    sim = SimServing(_model(), reqs, policy="busy").run()
    assert sim.requests[0].outcome == "timed_out"
    assert sim.requests[0].tries == 1
    assert sim.report("t").serving["retries"] == 0


def test_hedge_wins_over_straggler_and_cancels_loser():
    # replica 0 (dispatch-preferred) straggles 20x; the hedge fires on
    # replica 1 and finishes long before the primary would have
    slo = SLOClass("hedgy", deadline_s=60.0, timeout_s=50.0,
                   hedge_after_s=0.2)
    reqs = [SimRequest(rid=0, release=0.0, prompt=160, new=80, slo=slo)]
    timeline = ConditionTimeline([straggler(0.0, core=0, slowdown=20.0)])
    model = ServingModel(machine=MachineModel(name="duo", n_cores=2),
                         slots_per_replica=1)
    sim = SimServing(model, reqs, policy="busy",
                     conditions=timeline).run()
    req = sim.requests[0]
    rep = sim.report("t")
    assert req.outcome == "completed"
    assert rep.serving["hedges"] == 1
    assert rep.serving["hedge_wins"] == 1
    # base service is 0.54 s; the straggling primary alone would need
    # 10.8 s — completion proves the hedge won and was not cancelled
    assert req.done_at < 2.0
    # first completion cancelled the loser: no live attempts or busy
    # slots remain, and the monitor accounts exactly one completion
    assert sim._active == 0
    assert sim._busy == [0, 0]
    assert sim.monitor.live_instances() == 0
    assert sim.monitor.completed_instances() == 1


def test_hedge_not_issued_without_protection():
    slo = SLOClass("hedgy", deadline_s=60.0, timeout_s=50.0,
                   hedge_after_s=0.2)
    reqs = [SimRequest(rid=0, release=0.0, prompt=160, new=80, slo=slo)]
    timeline = ConditionTimeline([straggler(0.0, core=0, slowdown=20.0)])
    model = ServingModel(machine=MachineModel(name="duo", n_cores=2),
                         slots_per_replica=1)
    sim = SimServing(model, reqs, policy="busy", protection=False,
                     conditions=timeline).run()
    assert sim.report("t").serving["hedges"] == 0
    assert sim.requests[0].outcome == "completed"   # slow, but done


def test_core_fail_requeues_uncharged_and_recovers():
    # the failing replica's attempt is torn off and requeued without a
    # retry-budget debit; the request completes elsewhere
    slo = SLOClass("std", deadline_s=60.0, timeout_s=50.0, retry_budget=0)
    reqs = [SimRequest(rid=0, release=0.0, prompt=160, new=160, slo=slo)]
    timeline = ConditionTimeline([core_fail(0.3, core=0),
                                  core_recover(5.0, core=0)])
    model = ServingModel(machine=MachineModel(name="duo", n_cores=2),
                         slots_per_replica=1)
    bus = EventBus()
    rec = TraceRecorder(bus)
    sim = SimServing(model, reqs, policy="busy", conditions=timeline,
                     bus=bus).run()
    req = sim.requests[0]
    rep = sim.report("t")
    assert req.outcome == "completed"
    assert req.tries == 1                      # uncharged
    assert rep.serving["requeues"] == 1
    assert rep.serving["retries"] == 0
    modes = [e.data["mode"] for e in rec.events
             if e.kind is EventKind.DEGRADE]
    assert "quarantine" in modes
    requeue_evs = [e for e in rec.events if e.kind is EventKind.RETRY]
    assert requeue_evs and requeue_evs[0].data.get("requeued") is True


def test_power_cap_protected_zero_violation_and_brownout():
    # tiny homogeneous machine: 4 replicas at (1.0 active, 0.1 idle);
    # a 2.5 W cap allows exactly 2 hot (budget 2.1, step 0.9)
    slo_mix = [BATCH if i % 2 else STANDARD for i in range(40)]
    reqs = [SimRequest(rid=i, release=0.05 * i, prompt=100, new=64,
                       slo=slo_mix[i]) for i in range(40)]
    timeline = ConditionTimeline([power_cap(0.5, 2.5)])
    bus = EventBus()
    rec = TraceRecorder(bus)
    sim = SimServing(_model(), reqs, policy="busy",
                     conditions=timeline, bus=bus).run()
    rep = sim.report("protected")
    assert rep.cap_violation_s == 0.0
    # best-effort requests admitted under the cap brown out to 16 tokens
    browned = [r for r in sim.requests
               if r.outcome == "completed" and r.slo is BATCH
               and r.tokens_out == 16]
    assert browned
    assert rep.serving["truncated_tokens"] >= 48 * len(browned)
    modes = [e.data["mode"] for e in rec.events
             if e.kind is EventKind.DEGRADE]
    assert "brownout" in modes
    allowance_ev = next(e for e in rec.events
                        if e.kind is EventKind.DEGRADE
                        and e.data["mode"] == "brownout")
    assert allowance_ev.data["allowance"] == 2


def test_power_cap_unprotected_violates():
    reqs = [SimRequest(rid=i, release=0.05 * i, prompt=100, new=64,
                       slo=STANDARD) for i in range(40)]
    timeline = ConditionTimeline([power_cap(0.5, 2.5)])
    sim = SimServing(_model(), reqs, policy="busy", protection=False,
                     conditions=timeline).run()
    # busy policy keeps all 4 replicas hot at >= 1.0 W past the cap
    assert sim.report("unprotected").cap_violation_s > 0.0


def test_queue_full_evicts_lowest_priority_victim():
    # one slot, an in-flight request, queue bound 2: two batch
    # requests fill the queue; an interactive arrival evicts the
    # youngest batch request instead of being shed itself
    model = ServingModel(machine=MachineModel(name="solo", n_cores=1),
                         slots_per_replica=1)
    long_slo = SLOClass("std", deadline_s=60.0, timeout_s=50.0)
    reqs = [
        SimRequest(rid=0, release=0.0, prompt=100, new=160, slo=long_slo),
        SimRequest(rid=1, release=0.01, prompt=100, new=32, slo=BATCH),
        SimRequest(rid=2, release=0.02, prompt=100, new=32, slo=BATCH),
        SimRequest(rid=3, release=0.03, prompt=100, new=32,
                   slo=SLOClass("vip", deadline_s=60.0, priority=9)),
    ]
    adm = AdmissionController(max_queue_depth=2)
    sim = SimServing(model, reqs, policy="busy", admission=adm).run()
    by_id = {r.rid: r for r in sim.requests}
    assert by_id[2].outcome == "shed"          # youngest lowest-pri
    assert by_id[3].outcome == "completed"     # admitted over it
    assert by_id[1].outcome == "completed"
    assert sim.report("t").serving["shed_by_reason"] == {"queue": 1}


def test_protection_off_no_slo_is_plain_fifo():
    # no SLOs, protection off, no perturbations: every request
    # completes, and none of the protection event kinds fire
    reqs = [SimRequest(rid=i, release=0.05 * i, prompt=100, new=32)
            for i in range(30)]
    bus = EventBus()
    rec = TraceRecorder(bus)
    sim = SimServing(_model(), reqs, protection=False, bus=bus).run()
    rep = sim.report("plain")
    assert rep.serving["completed"] == 30
    assert rep.serving["shed"] == 0
    assert rep.serving["retries"] == 0
    assert rep.serving["hedges"] == 0
    assert rep.serving["degrades"] == 0
    protection_kinds = {EventKind.SHED, EventKind.RETRY,
                        EventKind.HEDGE, EventKind.DEGRADE}
    assert not [e for e in rec.events if e.kind in protection_kinds]
    # the serving extras stay out of the report repr, so pre-serving
    # report printing (and tests asserting on it) is unchanged
    assert "serving" not in repr(rep)


# ---------------------------------------------------------------------------
# Conservation invariant
# ---------------------------------------------------------------------------

_TIMELINES = [
    ConditionTimeline(),
    ConditionTimeline([power_cap(1.0, 2.5), power_cap(3.0, None)]),
    ConditionTimeline([core_fail(0.5, core=0), core_recover(2.0, core=0),
                       thermal_throttle(1.0, core_type="", freq=None)]),
    ConditionTimeline([straggler(0.2, core=1, slowdown=8.0),
                       power_cap(1.5, 2.5), core_fail(2.0, core=3)]),
]


def _assert_conserved(sim: SimServing, n: int) -> None:
    reqs = sim.requests
    assert len(reqs) == n
    # every request ends in exactly one terminal outcome, stamped
    outcomes = {"completed": 0, "shed": 0, "timed_out": 0}
    for r in reqs:
        assert r.outcome in outcomes
        outcomes[r.outcome] += 1
        assert r.done_at is not None and r.done_at >= r.release
    rep = sim.report("conserve")
    s = rep.serving
    assert outcomes["completed"] == s["completed"]
    assert outcomes["shed"] == s["shed"]
    assert outcomes["timed_out"] == s["timed_out"]
    assert sum(outcomes.values()) == s["requests"] == n
    assert sum(s["shed_by_reason"].values()) == \
        outcomes["shed"] + outcomes["timed_out"]
    # the monitor drained: nothing ready or executing survives the run
    assert sim.monitor.live_instances() == 0
    assert sim.monitor.completed_instances() == s["completed"]
    assert sim.monitor.shed_instances() == \
        outcomes["shed"] + outcomes["timed_out"]
    # no attempt leaked a slot
    assert sim._active == 0
    assert all(b == 0 for b in sim._busy)


def _conservation_run(seed: int, timeline: ConditionTimeline,
                      protection: bool) -> None:
    n = 250
    # ~3x the tiny machine's capacity: admission, timeouts, retries and
    # hedges all fire
    reqs = build_requests(PoissonArrivals(rate=45.0, seed=seed), n,
                          seed=seed)
    sim = SimServing(_model(), reqs, policy="prediction", rate_s=0.25,
                     protection=protection, conditions=timeline,
                     seed=seed)
    sim.run()
    _assert_conserved(sim, n)


@pytest.mark.parametrize("timeline", _TIMELINES)
@pytest.mark.parametrize("protection", [True, False])
def test_conservation_fixed_seeds(timeline, protection):
    _conservation_run(11, timeline, protection)


@given(st.integers(0, 2 ** 16), st.integers(0, len(_TIMELINES) - 1),
       st.booleans())
@settings(max_examples=15, deadline=None)
def test_conservation_property(seed, tidx, protection):
    _conservation_run(seed, _TIMELINES[tidx], protection)


# ---------------------------------------------------------------------------
# Trace round trip: sim -> trace -> sim, byte-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("machine", [TINY, HYBRID_PE])
def test_replay_byte_exact(tmp_path, machine):
    from repro.serving import replay_serving
    n = 300
    reqs = build_requests(PoissonArrivals(rate=60.0, seed=5), n, seed=5)
    timeline = ConditionTimeline([
        straggler(0.3, core=0, slowdown=5.0),
        power_cap(1.0, 0.25 * machine.n_cores),
        core_fail(1.5, core=1), core_recover(3.0, core=1),
        power_cap(4.0, None),
    ])
    kwargs = dict(policy="prediction", rate_s=0.25, seed=5)
    model = ServingModel(machine=machine, slots_per_replica=2)

    bus1 = EventBus()
    rec1 = TraceRecorder(bus1)
    SimServing(model, reqs, conditions=timeline, bus=bus1, **kwargs).run()
    p1 = rec1.to_jsonl(tmp_path / "orig.jsonl")

    loaded = TraceRecorder.from_jsonl(p1)
    bus2 = EventBus()
    rec2 = TraceRecorder(bus2)
    replay_serving(loaded.merged_events(), model, bus=bus2,
                   **kwargs).run()
    p2 = rec2.to_jsonl(tmp_path / "replay.jsonl")

    assert p1.read_bytes() == p2.read_bytes()
    # sanity: the trace is substantial and carries the SLO contracts
    lines = p1.read_text().splitlines()
    assert len(lines) > n
    subs = [json.loads(ln) for ln in lines
            if json.loads(ln)["kind"] == "task_submitted"]
    assert len(subs) == n
    assert any("slo" in d["data"] for d in subs)


# ---------------------------------------------------------------------------
# ServingEngine satellites: injected clock, per-engine ids, diagnostics
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_setup():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import init_params
    cfg = get_smoke_config("llama3.2-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class _VirtualClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        self.now += 0.125
        return self.now


def test_engine_injected_clock(engine_setup):
    from repro.serving import ServingEngine, Request
    cfg, params = engine_setup
    clock = _VirtualClock()
    bus = EventBus()
    rec = TraceRecorder(bus)
    engine = ServingEngine(cfg, params, max_batch=2, bus=bus, clock=clock)
    req = engine.submit(Request(prompt=[1, 2, 3]))
    # every timestamp is a tick of the injected clock — no wall time
    assert req.submitted_at == 0.125   # the injected clock's first tick
    assert all(ev.time % 0.125 == 0.0 for ev in rec.events)


def test_engine_ids_are_per_engine(engine_setup):
    from repro.serving import ServingEngine, Request
    cfg, params = engine_setup
    e1 = ServingEngine(cfg, params, max_batch=2)
    e2 = ServingEngine(cfg, params, max_batch=2)
    r1 = e1.submit(Request(prompt=[1, 2]))
    r2 = e2.submit(Request(prompt=[3, 4]))
    r3 = e1.submit(Request(prompt=[5, 6]))
    # two engines no longer interleave a module-global counter
    assert (r1.request_id, r3.request_id) == (0, 1)
    assert r2.request_id == 0


def test_engine_drain_diagnostics(engine_setup):
    from repro.serving import ServingEngine, Request
    cfg, params = engine_setup
    engine = ServingEngine(cfg, params, max_batch=2)
    engine.submit(Request(prompt=[1, 2, 3]))
    with pytest.raises(RuntimeError, match=r"1 queued, 0 active slots"):
        engine.run_until_drained(max_ticks=0)


def test_engine_admission_shed(engine_setup):
    from repro.serving import ServingEngine, Request
    cfg, params = engine_setup
    bus = EventBus()
    rec = TraceRecorder(bus)
    engine = ServingEngine(
        cfg, params, max_batch=2, bus=bus,
        admission=AdmissionController(max_queue_depth=1))
    kept = engine.submit(Request(prompt=[1, 2]))
    shed = engine.submit(Request(prompt=[3, 4]))
    assert kept in engine.queue
    assert shed in engine.shed and shed.done
    assert engine.monitor.shed_instances() == 1
    shed_evs = [e for e in rec.events if e.kind is EventKind.SHED]
    assert len(shed_evs) == 1
    assert shed_evs[0].data["reason"] == "queue"


def test_engine_brownout_truncates_best_effort(engine_setup):
    from repro.serving import ServingEngine, Request
    cfg, params = engine_setup
    bus = EventBus()
    rec = TraceRecorder(bus)
    engine = ServingEngine(cfg, params, max_batch=2, bus=bus,
                           brownout_tokens=4)
    req = engine.submit(Request(prompt=[1, 2], max_new_tokens=32,
                                slo=BATCH))
    assert req.max_new_tokens == 4
    kinds = [e.kind for e in rec.events]
    # DEGRADE lands between SUBMITTED and READY, after the truncation,
    # so the monitor only ever sees the browned-out cost
    assert kinds == [EventKind.TASK_SUBMITTED, EventKind.DEGRADE,
                     EventKind.TASK_READY]
    # non-best-effort traffic is untouched
    std = engine.submit(Request(prompt=[1, 2], max_new_tokens=32,
                                slo=STANDARD))
    assert std.max_new_tokens == 32
