"""Multi-application co-scheduling: ClusterArbiter plans, N-app runs,
fairness metrics, the pinned N=2 parity with the classic two-job
SimCluster DLB path, and multi-app trace record/replay."""

import pytest

from repro.core import (AppPlan, EventBus, GovernorSpec, MultiAppReport,
                        ResourceBroker, jain_fairness)
from repro.runtime import (HYBRID_PE, MN4, SimCluster, SimJobSpec,
                           run_multi_app, solo_job_spec)
from repro.trace import TraceRecorder, TraceReplayer, decision_sequence
from repro.workloads import build_gauss_seidel, build_multisaxpy, build_stream

GS_KW = dict(steps=6, bi=6, bj=6, block_elems=400_000, seed=0)
ST_KW = dict(rounds=4, blocks=400, block_elems=40_000, seed=1)
SX_KW = dict(grain="coarse", generations=6, blocks=24, seed=2)


def _two_specs(policy, graphs=None, buses=(None, None)):
    g_gs, g_st = graphs if graphs is not None else (
        build_gauss_seidel(**GS_KW), build_stream(**ST_KW))
    return [
        SimJobSpec(name="gauss", graph=g_gs, policy=policy,
                   cpus=list(range(24)), bus=buses[0]),
        SimJobSpec(name="stream", graph=g_st, policy=policy,
                   cpus=list(range(24, 48)), bus=buses[1]),
    ]


class TestN2Parity:
    """Acceptance pin: the N=2 arbiter reproduces the existing two-job
    SimCluster DLB decision sequence exactly."""

    @pytest.mark.parametrize("policy", ["dlb-lewi", "dlb-prediction"])
    def test_arbiter_matches_manual_two_job_cluster(self, policy):
        # -- the classic path: hand-built SimCluster with a broker ------
        buses = (EventBus(app="gauss"), EventBus(app="stream"))
        rec = TraceRecorder()
        rec.attach(buses[0]).attach(buses[1])
        broker = ResourceBroker()
        cl = SimCluster(MN4, broker=broker)
        for spec in _two_specs(policy, buses=buses):
            cl.add_job(spec)
        manual_reports = cl.run()
        manual_calls = broker.total_calls
        manual_seq = {
            app: decision_sequence(TraceReplayer(rec).for_app(app).events)
            for app in ("gauss", "stream")}

        # -- the arbiter frontend on identical fresh inputs -------------
        buses2 = (EventBus(app="gauss"), EventBus(app="stream"))
        rec2 = TraceRecorder()
        rec2.attach(buses2[0]).attach(buses2[1])
        report = run_multi_app(MN4, _two_specs(policy, buses=buses2))
        arb_seq = {
            app: decision_sequence(TraceReplayer(rec2).for_app(app).events)
            for app in ("gauss", "stream")}

        assert arb_seq == manual_seq
        assert report.total_dlb_calls == manual_calls
        for app in ("gauss", "stream"):
            assert report.apps[app].makespan == \
                manual_reports[app].makespan
            assert report.apps[app].dlb_calls == \
                manual_reports[app].dlb_calls
            assert report.apps[app].energy == manual_reports[app].energy
        assert len(manual_seq["gauss"]) > 0    # the pin is not vacuous


class TestPinnedCallCounts:
    """Regression pin for the Table-3 cost metric: exact per-policy DLB
    call counts on a fixed two-app scenario.  Catches both directions —
    silent inflation (e.g. counting ``max_n <= 0`` no-op acquires, the
    bug this PR fixes) and silently dropped broker traffic."""

    PINNED = {
        "dlb-lewi": {"gauss": 117, "stream": 1601},
        "dlb-hybrid": {"gauss": 108, "stream": 1601},
        "dlb-prediction": {"gauss": 326, "stream": 53},
    }

    @pytest.mark.parametrize("policy", sorted(PINNED))
    def test_exact_call_counts(self, policy):
        rep = run_multi_app(MN4, _two_specs(policy))
        assert {n: r.dlb_calls for n, r in rep.apps.items()} == \
            self.PINNED[policy]
        assert rep.total_dlb_calls == sum(self.PINNED[policy].values())

    def test_prediction_orders_of_magnitude_fewer_calls(self):
        assert sum(self.PINNED["dlb-prediction"].values()) * 4 <= \
            sum(self.PINNED["dlb-lewi"].values())


class TestArbiterPlans:
    def _arbitrated_cluster(self, policy="dlb-prediction"):
        broker = ResourceBroker()
        cl = SimCluster(MN4, broker=broker)
        for spec in _two_specs(policy):
            cl.add_job(spec)
        return cl, broker

    def test_cluster_builds_arbiter_with_broker(self):
        cl, broker = self._arbitrated_cluster()
        assert cl.arbiter is not None
        assert cl.arbiter.broker is broker
        assert set(cl.arbiter.apps()) == {"gauss", "stream"}
        # no broker ⇒ no arbiter
        assert SimCluster(MN4).arbiter is None

    def test_plan_tick_none_for_eager_policies(self):
        cl, _ = self._arbitrated_cluster("dlb-lewi")
        assert cl.arbiter.plan_tick("gauss", active=4, ready_tasks=9) is None

    def test_plan_tick_none_when_nothing_to_get(self):
        # empty pool, nothing lent out: the cheap peek suppresses the call
        cl, broker = self._arbitrated_cluster()
        plan = cl.arbiter.plan_tick("gauss", active=0, ready_tasks=10)
        assert plan is None
        assert broker.total_calls == 0

    def test_plan_tick_registers_unmet_demand_without_a_call(self):
        """A starved app whose tick fires after the pool drained makes
        no DLB call — but its claim must still be registered, or the
        least-recently-served reservation could never protect it."""
        cl, broker = self._arbitrated_cluster()
        assert cl.arbiter.plan_tick("gauss", active=0,
                                    ready_tasks=10) is None
        assert broker.total_calls == 0          # still no DLB call paid
        assert broker._jobs["gauss"].waiting > 0
        # demand evaporates ⇒ the reservation is dropped, so pooled
        # CPUs are not parked for an app that no longer asks
        assert cl.arbiter.plan_tick("gauss", active=24,
                                    ready_tasks=0) is None
        assert broker._jobs["gauss"].waiting == 0

    def test_plan_tick_requests_delta_minus_active(self):
        cl, broker = self._arbitrated_cluster()
        broker.lend("stream", 30)              # now the pool has a CPU
        gov = cl.arbiter.governor("gauss")
        delta = gov.predictor.delta            # optimistic start: 24
        plan = cl.arbiter.plan_tick("gauss", active=4, ready_tasks=50)
        assert plan is not None and plan.acquire == delta - 4
        assert not plan.eager

    def test_execute_eager_one_call_per_cpu(self):
        cl, broker = self._arbitrated_cluster("dlb-lewi")
        broker.lend("stream", 30)
        broker.lend("stream", 31)
        got = []
        n = cl.arbiter.execute(
            AppPlan(app="gauss", acquire=3, eager=True,
                    reclaim_if_short=False), got.append)
        assert sorted(n) == [30, 31] and sorted(got) == [30, 31]
        # 2 lends + 2 successful acquires + 1 empty-pool acquire
        assert broker.total_calls == 5
        assert cl.arbiter.stats["gauss"].acquired == 2

    def test_execute_reclaims_when_short(self):
        cl, broker = self._arbitrated_cluster()
        broker.lend("gauss", 0)
        assert broker.acquire("stream", 1) == [0]
        got = []
        cl.arbiter.execute(AppPlan(app="gauss", acquire=2), got.append)
        assert got == []                       # borrowed: comes back later
        assert broker.cpu_must_return(0)
        assert cl.arbiter.stats["gauss"].reclaims == 1

    def test_verbs_keep_share_stats(self):
        cl, broker = self._arbitrated_cluster()
        cl.arbiter.lend("gauss", 0)
        assert broker.pool_size() == 1
        assert cl.arbiter.stats["gauss"].lends == 1
        snap = cl.arbiter.snapshot()
        assert snap["gauss"]["calls"] == 1
        assert snap["gauss"]["delta"] >= 1


class TestMultiAppRun:
    def test_three_apps_complete_with_sharing_stats(self):
        specs = [
            SimJobSpec(name="gauss", graph=build_gauss_seidel(**GS_KW),
                       policy="dlb-prediction", cpus=list(range(16))),
            SimJobSpec(name="stream", graph=build_stream(**ST_KW),
                       policy="dlb-prediction", cpus=list(range(16, 32))),
            SimJobSpec(name="saxpy", graph=build_multisaxpy(**SX_KW),
                       policy="dlb-prediction", cpus=list(range(32, 48))),
        ]
        rep = run_multi_app(MN4, specs)
        assert set(rep.apps) == {"gauss", "stream", "saxpy"}
        assert rep.makespan == max(r.makespan for r in rep.apps.values())
        assert rep.aggregate_energy == pytest.approx(
            sum(r.energy for r in rep.apps.values()))
        assert rep.aggregate_edp == pytest.approx(
            rep.aggregate_energy * rep.makespan)
        assert rep.total_dlb_calls == sum(r.dlb_calls
                                          for r in rep.apps.values())
        for r in rep.apps.values():
            assert set(r.sharing) == {"lends", "acquired", "returns",
                                      "reclaims", "guard_refusals",
                                      "migrations"}
        # co-location actually traded CPUs somewhere
        assert any(r.sharing["lends"] > 0 for r in rep.apps.values())

    def test_solo_baselines_and_slowdown(self):
        specs = _two_specs("dlb-prediction")
        solo_graphs = {"gauss": build_gauss_seidel(**GS_KW),
                       "stream": build_stream(**ST_KW)}
        rep = run_multi_app(MN4, specs, solo_graphs=solo_graphs)
        assert set(rep.slowdown) == {"gauss", "stream"}
        for s in rep.slowdown.values():
            assert s > 0
        assert 0.0 < rep.fairness <= 1.0
        # solo baselines ran under the non-sharing equivalent
        assert rep.solo["gauss"].policy == "prediction"

    def test_overlapping_partitions_rejected(self):
        specs = _two_specs("dlb-lewi")
        specs[1] = SimJobSpec(name="stream", graph=build_stream(**ST_KW),
                              policy="dlb-lewi", cpus=list(range(20, 44)))
        with pytest.raises(ValueError, match="overlaps"):
            run_multi_app(MN4, specs)

    def test_unpinned_partition_rejected(self):
        spec = SimJobSpec(name="x", graph=build_stream(**ST_KW),
                          policy="dlb-lewi", cpus=None)
        with pytest.raises(ValueError, match="explicit"):
            run_multi_app(MN4, [spec])

    def test_solo_job_spec_maps_policy_in_governor_form(self):
        gspec = GovernorSpec(resources=4, policy="dlb-hybrid")
        spec = SimJobSpec(name="x", graph=build_stream(**ST_KW),
                          governor=gspec, cpus=[0, 1, 2, 3])
        solo = solo_job_spec(spec, build_stream(**ST_KW))
        assert solo.governor.policy == "hybrid"
        assert solo.bus is None


class TestHeterogeneousArbitration:
    def test_broker_becomes_typed_on_asymmetric_machine(self):
        broker = ResourceBroker()
        SimCluster(HYBRID_PE, broker=broker)
        assert broker.typed
        # and stays untyped on homogeneous machines (scalar parity path)
        broker2 = ResourceBroker()
        SimCluster(MN4, broker=broker2)
        assert not broker2.typed

    def test_hetero_multiapp_runs_and_bills_types(self):
        specs = [
            SimJobSpec(name="p-app", graph=build_stream(**ST_KW),
                       policy="dlb-prediction", cpus=list(range(8))),
            SimJobSpec(name="e-app", graph=build_multisaxpy(**SX_KW),
                       policy="dlb-prediction", cpus=list(range(8, 24))),
        ]
        rep = run_multi_app(HYBRID_PE, specs)
        for spec_name in ("p-app", "e-app"):
            assert rep.apps[spec_name].tasks_completed > 0
            by_type = rep.apps[spec_name].state_seconds_by_type
            assert by_type and set(by_type) <= {"P", "E"}

    def _pe_cluster(self, min_borrow_speed=None):
        broker = ResourceBroker()
        cl = SimCluster(HYBRID_PE, broker=broker)
        kw = {}
        if min_borrow_speed is not None:
            kw["governor"] = GovernorSpec(
                resources=8, policy="dlb-prediction",
                min_borrow_speed=min_borrow_speed)
        cl.add_job(SimJobSpec(name="p-app", graph=build_stream(**ST_KW),
                              policy="dlb-prediction",
                              cpus=list(range(8)), **kw))
        cl.add_job(SimJobSpec(name="e-app",
                              graph=build_multisaxpy(**SX_KW),
                              policy="dlb-prediction",
                              cpus=list(range(8, 24))))
        return cl, broker

    def test_speed_guard_refuses_slower_silicon(self):
        """A P-only app must not dilate its critical path with pooled
        E-core stragglers (min_borrow_speed defaults to 1.0)."""
        cl, broker = self._pe_cluster()
        broker.lend("e-app", 10)               # an E core hits the pool
        assert cl.arbiter._borrowable_types("p-app") == ["P"]
        got = cl.arbiter.execute(
            AppPlan(app="p-app", acquire=2, acquire_by_type={"P": 2}),
            lambda c: None)
        assert got == []                       # E core left in the pool
        assert broker.pool_size() == 1
        # ...and no broker call was paid for the refusal
        assert broker.job_calls("p-app") == 0

    def test_slow_owner_still_borrows_fast_cores(self):
        cl, broker = self._pe_cluster()
        broker.lend("p-app", 0)                # a P core hits the pool
        assert cl.arbiter._borrowable_types("e-app") == ["P", "E"]
        got = cl.arbiter.execute(
            AppPlan(app="e-app", acquire=1, acquire_by_type={"E": 1}),
            lambda c: None)
        assert got == [0]                      # P granted for E demand

    def test_min_borrow_speed_zero_disables_guard(self):
        cl, broker = self._pe_cluster(min_borrow_speed=0.0)
        broker.lend("e-app", 10)
        assert cl.arbiter._borrowable_types("p-app") == ["P", "E"]
        got = cl.arbiter.execute(
            AppPlan(app="p-app", acquire=2, acquire_by_type={"P": 2}),
            lambda c: None)
        assert got == [10]

    def test_reclaim_not_reissued_while_pending(self):
        """Regression for the hetero reclaim storm: re-issuing a reclaim
        every tick while the first one's return flags are still pending
        paid one DLB call per tick for nothing."""
        cl, broker = self._pe_cluster()
        broker.lend("p-app", 0)
        assert broker.acquire("e-app", 1) == [0]
        plan = AppPlan(app="p-app", acquire=1, acquire_by_type={"P": 1})
        cl.arbiter.execute(plan, lambda c: None)
        assert broker.reclaim_pending("p-app")
        calls = broker.job_calls("p-app")
        assert cl.arbiter.stats["p-app"].reclaims == 1
        cl.arbiter.execute(plan, lambda c: None)    # still pending
        assert broker.job_calls("p-app") == calls   # no extra call
        assert cl.arbiter.stats["p-app"].reclaims == 1

    def test_typed_targets_split_fastest_first(self):
        broker = ResourceBroker()
        cl = SimCluster(HYBRID_PE, broker=broker)
        cl.add_job(SimJobSpec(name="whole", graph=build_stream(**ST_KW),
                              policy="dlb-prediction",
                              cpus=list(range(24))))
        gov = cl.arbiter.governor("whole")
        targets = cl.arbiter._typed_targets(gov, target=30)
        # optimistic start: per-type Δ equals per-type counts, everything
        # is active (spinning) ⇒ no per-type deficit
        assert targets is None or all(n > 0 for n in targets.values())


class TestStrandedJobRecovery:
    """Regression: once ≥3 jobs trade CPUs, a job can end up with every
    owned CPU lent away while its last *borrowed* CPU is reclaimed at a
    task boundary — leaving ready work with no worker.  Policies with no
    prediction tick (LeWI/hybrid) had no recovery path and the cluster
    deadlocked (first seen as bench_multiapp HYBRID-PE N=4 dlb-hybrid).
    The forced-return path now claws capacity back through the broker."""

    APPS = {
        "gauss": (build_gauss_seidel,
                  dict(steps=8, bi=8, bj=8, block_elems=600_000, seed=0)),
        "stream": (build_stream,
                   dict(rounds=6, blocks=500, block_elems=40_000, seed=1)),
        "saxpy": (build_multisaxpy,
                  dict(grain="fine", generations=10, blocks=60,
                       block_elems=200_000, seed=2)),
        "hpccg": (None, None),   # placeholder; built below
    }

    def test_four_app_hybrid_hetero_completes(self):
        from repro.workloads import build_hpccg

        builders = dict(self.APPS)
        builders["hpccg"] = (build_hpccg,
                             dict(iterations=6, blocks=24,
                                  rows_per_block=16_384, seed=3))
        specs = [
            SimJobSpec(name=name, graph=fn(**kw), policy="dlb-hybrid",
                       cpus=list(range(i * 6, (i + 1) * 6)))
            for i, (name, (fn, kw)) in enumerate(builders.items())]
        rep = run_multi_app(HYBRID_PE, specs)
        for name, (fn, kw) in builders.items():
            assert rep.apps[name].tasks_completed == len(fn(**kw))


class TestFairnessMetrics:
    def test_jain_bounds(self):
        assert jain_fairness({}) == 1.0
        assert jain_fairness({"a": 2.0, "b": 2.0, "c": 2.0}) == \
            pytest.approx(1.0)
        skew = jain_fairness({"a": 1.0, "b": 0.0, "c": 0.0})
        assert skew == pytest.approx(1.0)      # zero entries are ignored
        skew2 = jain_fairness({"a": 10.0, "b": 1.0})
        assert 0.5 < skew2 < 1.0

    def test_report_build_aggregates(self):
        from repro.core import GovernorReport

        def rep(makespan, energy):
            return GovernorReport(policy="p", makespan=makespan,
                                  energy=energy, edp=energy * makespan,
                                  tasks_completed=1, resumes=0, idles=0,
                                  predictions=0, accuracy=None)

        apps = {"a": rep(2.0, 10.0), "b": rep(4.0, 6.0)}
        solo = {"a": rep(1.0, 10.0), "b": rep(4.0, 6.0)}
        r = MultiAppReport.build(apps, total_dlb_calls=7, solo=solo)
        assert r.makespan == 4.0
        assert r.aggregate_energy == 16.0
        assert r.aggregate_edp == 64.0
        assert r.slowdown == {"a": 2.0, "b": 1.0}
        assert r.total_dlb_calls == 7
        assert 0.5 < r.fairness < 1.0          # a slowed down, b did not


class TestCommittedBenchClaims:
    """The committed BENCH_multiapp.json must carry the headline: with
    N ≥ 3 co-scheduled apps, prediction-driven arbitration beats LeWI on
    aggregate EDP at comparable (here: strictly better) makespan."""

    def test_prediction_beats_lewi_aggregate_edp_n3_plus(self):
        import json
        import pathlib

        path = pathlib.Path(__file__).parent.parent / \
            "BENCH_multiapp.json"
        if not path.exists():
            pytest.skip("BENCH_multiapp.json not generated")
        rows = json.loads(path.read_text())["rows"]
        agg = {(r["machine"], r["n_apps"], r["policy"]): r
               for r in rows if r["app"] == "ALL"}
        checked = 0
        for (machine, n, policy), row in agg.items():
            if policy != "dlb-prediction" or n < 3:
                continue
            lewi = agg[(machine, n, "dlb-lewi")]
            assert row["edp"] < lewi["edp"], (machine, n)
            assert row["time_s"] <= lewi["time_s"] * 1.10, (machine, n)
            checked += 1
        assert checked >= 2        # both machines, N ∈ {3, 4}


class TestMultiAppTrace:
    """Per-app event namespacing: one recorder over N per-app buses
    yields a combined trace that splits and replays per app."""

    def _record_two_app_run(self, policy="dlb-prediction"):
        buses = (EventBus(app="gauss"), EventBus(app="stream"))
        rec = TraceRecorder()
        rec.attach(buses[0]).attach(buses[1])
        broker = ResourceBroker()
        cl = SimCluster(MN4, broker=broker)
        for spec in _two_specs(policy, buses=buses):
            cl.add_job(spec)
        reports = cl.run()
        return rec, reports

    def test_trace_splits_per_app(self):
        rec, reports = self._record_two_app_run()
        rp = TraceReplayer(rec)
        assert rp.apps() == ["gauss", "stream"]
        for app in ("gauss", "stream"):
            graph, arrivals = rp.for_app(app).build()
            assert len(graph) == reports[app].tasks_completed
            assert arrivals is None            # closed-world graphs

    def test_default_job_bus_is_namespaced(self):
        cl = SimCluster(MN4)
        job = cl.add_job(SimJobSpec(name="solo",
                                    graph=build_stream(**ST_KW),
                                    policy="busy", cpus=list(range(24))))
        assert job.bus.app == "solo"

    def test_multiapp_round_trip_reproduces_decisions(self):
        """sim→sim round trip for a co-scheduled DLB run: rebuild each
        app's graph from the combined trace, replay both on a fresh
        broker'd cluster, and the per-app decision sequences and DLB
        call counts come back exactly."""
        rec, reports = self._record_two_app_run()
        rp = TraceReplayer(rec)
        graphs = {app: rp.for_app(app).build()[0]
                  for app in ("gauss", "stream")}

        machine = TraceReplayer.replay_machine(MN4)
        buses = (EventBus(app="gauss"), EventBus(app="stream"))
        rec2 = TraceRecorder()
        rec2.attach(buses[0]).attach(buses[1])
        broker = ResourceBroker()
        cl = SimCluster(machine, broker=broker)
        for spec in _two_specs("dlb-prediction",
                               graphs=(graphs["gauss"], graphs["stream"]),
                               buses=buses):
            cl.add_job(spec)
        replay_reports = cl.run()

        orig = {app: decision_sequence(TraceReplayer(rec).for_app(app)
                                       .events)
                for app in ("gauss", "stream")}
        back = {app: decision_sequence(TraceReplayer(rec2).for_app(app)
                                       .events)
                for app in ("gauss", "stream")}
        assert back == orig
        for app in ("gauss", "stream"):
            assert replay_reports[app].dlb_calls == reports[app].dlb_calls
