"""Trainer end-to-end: loss descent, checkpoint/restart continuity."""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.train.trainer import Trainer, TrainerConfig
from repro.train.steps import StepConfig


def _tcfg(tmp_path=None, steps=12, **kw):
    return TrainerConfig(steps=steps, global_batch=4, seq_len=32,
                         checkpoint_dir=str(tmp_path) if tmp_path else None,
                         checkpoint_every=5, log_every=1000,
                         step=StepConfig(accum=2, warmup=2), **kw)


def test_loss_decreases():
    cfg = get_smoke_config("llama3.2-1b")
    tr = Trainer(cfg, _tcfg(steps=15))
    hist = tr.run()
    tr.close()
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first


@pytest.mark.slow
def test_checkpoint_restart_continuity(tmp_path):
    """An interrupted-and-restored run must produce EXACTLY the losses of
    an uninterrupted run: params/opt round-trip bitwise (bf16 stored as
    raw views) and the data pipeline regenerates batch k for step k."""
    cfg = get_smoke_config("llama3.2-1b")
    ref = Trainer(cfg, _tcfg(steps=12, **{}))
    ref.run()
    ref_losses = [h["loss"] for h in ref.history]
    ref.close()

    tr1 = Trainer(cfg, _tcfg(tmp_path, steps=10))
    tr1.run()
    tr1.close()

    tr2 = Trainer(cfg, _tcfg(tmp_path, steps=10))
    assert tr2.maybe_restore()
    assert tr2.step == 10
    tr2.data.close()
    from repro.data import SyntheticLM
    tr2.data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=4,
                           accum=2, seed=0, start_step=10)
    tr2.run(2)
    tr2.close()
    cont = [h["loss"] for h in tr2.history]
    np.testing.assert_allclose(cont, ref_losses[10:12], rtol=1e-6)


def test_compression_trainer_runs():
    cfg = get_smoke_config("llama3.2-1b")
    tr = Trainer(cfg, _tcfg(steps=6, compress=True))
    hist = tr.run()
    tr.close()
    assert hist[-1]["loss"] < hist[0]["loss"] * 1.2
