"""DLB broker + sharing policies (paper §3.3, Table 3)."""

from _hypothesis_compat import given, settings, st

from repro.core.monitoring import TaskMonitor
from repro.core.prediction import CPUPredictor, PredictionConfig
from repro.core.sharing import (DLBHybridPolicy, DLBPredictionPolicy,
                                LeWIPolicy, ResourceBroker)
from repro.core.policies import PollDecision


def _broker2() -> ResourceBroker:
    b = ResourceBroker()
    b.register_job("a", [0, 1, 2, 3])
    b.register_job("b", [4, 5, 6, 7])
    return b


def _broker_n(n_jobs: int, cpus_per_job: int = 2) -> ResourceBroker:
    b = ResourceBroker()
    for i in range(n_jobs):
        base = i * cpus_per_job
        b.register_job(f"j{i}", list(range(base, base + cpus_per_job)))
    return b


class TestBroker:
    def test_lend_acquire_roundtrip(self):
        b = _broker2()
        b.lend("a", 0)
        assert b.pool_size() == 1
        got = b.acquire("b", 2)
        assert got == [0]
        assert b.holder(0) == "b"
        # returning it gives it back to the pool (a has no reclaim flag)
        b.lend("b", 0)
        assert b.holder(0) == ""
        got = b.acquire("a", 1)              # owner prefers its own cpu
        assert got == [0] and b.holder(0) == "a"

    def test_reclaim_flags_borrowed(self):
        b = _broker2()
        b.lend("a", 1)
        assert b.acquire("b", 1) == [1]
        back = b.reclaim("a")
        assert back == []                    # borrowed: comes back later
        assert b.cpu_must_return(1)
        owner = b.return_cpu("b", 1)
        assert owner == "a" and b.holder(1) == "a"

    def test_call_counting(self):
        b = _broker2()
        b.lend("a", 0)
        b.acquire("b", 1)
        b.acquire("b", 1)                    # failed acquire still counts
        assert b.job_calls("a") == 1
        assert b.job_calls("b") == 2
        assert b.total_calls == 3

    def test_noop_acquire_is_not_a_dlb_call(self):
        """Regression: ``acquire(max_n <= 0)`` never reaches the DLB
        library, so it must not inflate the Table-3 call-cost metric —
        ``dlb-prediction`` computes ``acquire_target`` every tick and a
        zero target used to be billed as a real call."""
        b = _broker2()
        assert b.acquire("b", 0) == []
        assert b.acquire("b", -3) == []
        assert b.job_calls("b") == 0
        assert b.total_calls == 0
        # a real (even unsuccessful) request still counts
        assert b.acquire("b", 1) == []
        assert b.job_calls("b") == 1 and b.total_calls == 1

    def test_return_cpu_keeps_pending_reclaim_wanted(self):
        """Regression: returning ONE of several flagged CPUs must not
        clear the owner's reclaim_wanted while other lent CPUs still
        carry return flags — that silently dropped multi-CPU reclaims."""
        b = _broker2()
        b.lend("a", 0)
        b.lend("a", 1)
        assert sorted(b.acquire("b", 2)) == [0, 1]
        assert b.reclaim("a") == []          # both borrowed: flagged
        assert b.cpu_must_return(0) and b.cpu_must_return(1)
        assert b.return_cpu("b", 0) == "a"
        # cpu 1 is still flagged ⇒ the reclaim must stay wanted
        assert b._jobs["a"].reclaim_wanted
        # ...so b's next lend of cpu 1 hands it straight to the owner
        assert b.lend("b", 1) == "a"
        assert b.holder(1) == "a"
        # nothing pending anymore
        assert not b._jobs["a"].reclaim_wanted

    def test_return_last_flagged_cpu_clears_reclaim_wanted(self):
        b = _broker2()
        b.lend("a", 0)
        assert b.acquire("b", 1) == [0]
        b.reclaim("a")
        b.return_cpu("b", 0)
        assert not b._jobs["a"].reclaim_wanted

    @given(st.lists(st.tuples(st.sampled_from(["lend_a", "lend_b",
                                               "acq_a", "acq_b"]),
                              st.integers(0, 7)),
                    max_size=60))
    @settings(max_examples=100, deadline=None)
    def test_conservation(self, ops):
        """Property: every CPU always has exactly one holder ∈ {a, b,
        pool}; pool+held == 8 after any op sequence."""
        b = _broker2()
        for op, cpu in ops:
            if op == "lend_a":
                b.lend("a", cpu)
            elif op == "lend_b":
                b.lend("b", cpu)
            elif op == "acq_a":
                b.acquire("a", 1)
            else:
                b.acquire("b", 1)
            holders = [b.holder(c) for c in range(8)]
            assert all(h in ("a", "b", "") for h in holders)
            assert b.pool_size() == sum(1 for h in holders if h == "")


def _check_invariants(b: ResourceBroker) -> None:
    """Full-state broker invariants (the property tests' oracle):

    * every CPU has exactly one holder — a registered job or the pool;
    * a CPU is in the pool iff its holder is "";
    * ``lent``/``borrowed`` stay disjoint and mutually consistent:
      ``cpu ∈ owner.lent``  ⟺ someone else (or the pool) holds it,
      ``cpu ∈ job.borrowed`` ⟺ job holds a CPU it does not own.
    """
    jobs = b._jobs
    for cpu, owner in b._owner.items():
        holder = b.holder(cpu)
        assert holder == "" or holder in jobs
        assert (holder == "") == (cpu in b._pool)
        assert (cpu in jobs[owner].lent) == (holder != owner)
        for name, acct in jobs.items():
            assert not (acct.owned & acct.borrowed)
            assert (cpu in acct.borrowed) == \
                (holder == name and owner != name)
    assert len(b._pool) == len(set(b._pool))      # no duplicates


class TestBrokerInvariants:
    """Property-style interleavings over all four broker verbs."""

    OPS = ["lend_a", "lend_b", "acq_a", "acq_b", "reclaim_a", "reclaim_b",
           "ret_a", "ret_b"]

    @staticmethod
    def _apply(b: ResourceBroker, op: str, cpu: int) -> None:
        job = "a" if op.endswith("_a") else "b"
        if op.startswith("lend"):
            # lending is only legal for a CPU the job actually runs on
            if b.holder(cpu) == job:
                b.lend(job, cpu)
        elif op.startswith("acq"):
            b.acquire(job, 1 + cpu % 3)
        elif op.startswith("reclaim"):
            b.reclaim(job)
        else:   # cooperative return at a task boundary
            if cpu in b._jobs[job].borrowed and b.cpu_must_return(cpu):
                b.return_cpu(job, cpu)

    @given(st.lists(st.tuples(st.sampled_from(OPS), st.integers(0, 7)),
                    max_size=80))
    @settings(max_examples=200, deadline=None)
    def test_random_interleavings_hold_invariants(self, ops):
        b = _broker2()
        for op, cpu in ops:
            self._apply(b, op, cpu)
            _check_invariants(b)

    def test_deterministic_interleaving(self):
        """A fixed dense sequence so the invariants run even without
        hypothesis installed."""
        b = _broker2()
        seq = [("lend_a", 0), ("lend_a", 1), ("acq_b", 0), ("reclaim_a", 0),
               ("ret_b", 0), ("lend_b", 1), ("lend_b", 4), ("acq_a", 2),
               ("reclaim_b", 0), ("ret_a", 4), ("lend_a", 2), ("acq_b", 1),
               ("reclaim_a", 0), ("ret_b", 2), ("ret_b", 1), ("acq_a", 1)]
        for op, cpu in seq:
            self._apply(b, op, cpu)
            _check_invariants(b)


class TestBrokerInvariantsNJobs:
    """The same partition invariants under random N ∈ [2, 5] jobs —
    multiprogramming is exactly where holder/lent/borrowed bookkeeping
    has historically gone wrong (one borrower's return touching another
    owner's flags, the fairness reservation skewing the pool, …)."""

    VERBS = ["lend", "acq", "reclaim", "ret"]

    @staticmethod
    def _apply(b: ResourceBroker, verb: str, job: str, cpu: int) -> None:
        if verb == "lend":
            # lending is only legal for a CPU the job actually runs on
            if b.holder(cpu) == job:
                b.lend(job, cpu)
        elif verb == "acq":
            b.acquire(job, 1 + cpu % 3)
        elif verb == "reclaim":
            b.reclaim(job)
        else:   # cooperative return at a task boundary
            if cpu in b._jobs[job].borrowed and b.cpu_must_return(cpu):
                b.return_cpu(job, cpu)

    @given(st.integers(2, 5),
           st.lists(st.tuples(st.sampled_from(VERBS), st.integers(0, 4),
                              st.integers(0, 9)),
                    max_size=100))
    @settings(max_examples=150, deadline=None)
    def test_random_n_job_interleavings(self, n_jobs, ops):
        b = _broker_n(n_jobs)
        n_cpus = n_jobs * 2
        for verb, job_i, cpu in ops:
            self._apply(b, verb, f"j{job_i % n_jobs}", cpu % n_cpus)
            _check_invariants(b)

    def test_deterministic_interleaving_5_jobs(self):
        """Dense 5-job sequence; runs even without hypothesis."""
        b = _broker_n(5)
        seq = [("lend", "j0", 0), ("lend", "j0", 1), ("lend", "j3", 6),
               ("acq", "j1", 2), ("acq", "j2", 1), ("reclaim", "j0", 0),
               ("ret", "j1", 0), ("ret", "j1", 1), ("ret", "j2", 6),
               ("lend", "j4", 8), ("acq", "j2", 0), ("acq", "j3", 2),
               ("reclaim", "j4", 0), ("ret", "j2", 8), ("lend", "j1", 2),
               ("acq", "j0", 1), ("reclaim", "j3", 0), ("ret", "j0", 6),
               ("acq", "j4", 2), ("lend", "j2", 4)]
        for verb, job, cpu in seq:
            self._apply(b, verb, job, cpu)
            _check_invariants(b)


class TestForeignClaimantFairness:
    """Regression: with ≥3 jobs, own-first-then-FIFO draining let the
    borrower whose tick fired first take the whole pool every round,
    starving a third job indefinitely.  The broker now reserves foreign
    CPUs for less-recently-served claimants with registered unmet
    demand (round-robin via least-recently-served)."""

    @staticmethod
    def _broker3() -> ResourceBroker:
        b = ResourceBroker()
        b.register_job("a", [0, 1])
        b.register_job("b", [2, 3])
        b.register_job("c", [4, 5])
        return b

    def test_three_job_starvation_round_robin(self):
        b = self._broker3()
        b.lend("a", 0)
        b.lend("a", 1)
        # b's tick always fires first: without fairness it would win the
        # whole pool on every round.
        assert b.acquire("b", 2) == [0, 1]
        # c asks, comes up short -> its unmet demand is registered
        assert b.acquire("c", 2) == []
        # the CPUs come back to the pool...
        b.lend("b", 0)
        b.lend("b", 1)
        # ...and b (served more recently than the waiting c) must now
        # leave them for c, even though it asks first again.
        assert b.acquire("b", 2) == []
        assert b.acquire("c", 2) == [0, 1]
        # roles flip: b is now the least recently served waiter
        b.lend("c", 0)
        b.lend("c", 1)
        assert b.acquire("c", 2) == []
        assert b.acquire("b", 2) == [0, 1]

    def test_own_cpus_never_reserved_away(self):
        """The reservation applies to *foreign* claims only: an owner
        reclaiming its own lent silicon always wins."""
        b = self._broker3()
        b.lend("a", 0)
        assert b.acquire("b", 2) == [0]      # b borrows, is "served"
        assert b.acquire("c", 1) == []       # c registers unmet demand
        b.lend("b", 0)                       # back to the pool
        # a's own CPU: c's reservation must not block the owner
        assert b.acquire("a", 1) == [0]

    def test_lending_clears_stale_demand(self):
        b = self._broker3()
        b.lend("a", 0)
        assert b.acquire("b", 1) == [0]
        assert b.acquire("c", 1) == []       # c waiting
        b.lend("b", 0)
        b.lend("c", 4)                       # c lends ⇒ surplus ⇒ no claim
        assert b.acquire("b", 1) == [0]      # reservation gone


class TestTypedBroker:
    """Per-core-type accounting: a P-core lent is not an E-core grant."""

    @staticmethod
    def _typed() -> ResourceBroker:
        b = ResourceBroker(core_type_of=lambda c: "P" if c < 4 else "E")
        b.register_job("a", [0, 1, 4, 5])    # 2 P + 2 E
        b.register_job("b", [2, 3, 6, 7])    # 2 P + 2 E
        return b

    def test_pool_by_type(self):
        b = self._typed()
        b.lend("a", 0)
        b.lend("a", 4)
        b.lend("a", 5)
        assert b.pool_by_type() == {"P": 1, "E": 2}
        assert b.pool_size("P") == 1 and b.pool_size("E") == 2
        assert b.pool_size() == 3

    def test_typed_acquire_filters(self):
        b = self._typed()
        b.lend("a", 0)                       # P into the pool
        b.lend("a", 4)                       # E into the pool
        got = b.acquire("b", 2, core_type="E")
        assert got == [4]                    # never the P core
        assert b.pool_by_type() == {"P": 1}
        assert b.acquire("b", 1, core_type="P") == [0]

    def test_untyped_broker_reports_blank_type(self):
        b = _broker2()
        b.lend("a", 0)
        assert b.pool_by_type() == {"": 1}
        assert not b.typed


class TestSharingPolicies:
    def test_lewi_lends_first_poll(self):
        assert LeWIPolicy().on_poll_empty(0, 4, 1) is PollDecision.LEND

    def test_hybrid_spins_first(self):
        p = DLBHybridPolicy(spin_budget=100)
        assert p.on_poll_empty(0, 4, 99) is PollDecision.SPIN
        assert p.on_poll_empty(0, 4, 100) is PollDecision.LEND

    def test_prediction_lends_only_surplus(self):
        m = TaskMonitor(min_samples=1)
        for i in range(3):
            m.on_task_ready(i, "t", 1.0)
            m.on_task_execute(i, "t", 1.0)
            m.on_task_completed(i, "t", 1.0, 50e-6)
        m.on_task_ready(100, "t", 1.0)       # one window of work
        pred = CPUPredictor(m, n_cpus=4, config=PredictionConfig(
            rate_s=50e-6, min_samples=1, allow_oversubscription=True))
        pred.tick()
        p = DLBPredictionPolicy(pred)
        assert p.on_poll_empty(0, active=4, spin_count=1) \
            is PollDecision.LEND             # δ=4 > Δ=1
        assert p.on_poll_empty(0, active=1, spin_count=1) \
            is PollDecision.SPIN
        assert not p.eager_acquire           # single call per tick
        assert p.acquire_target(active=0, ready_tasks=10) == 1  # Δ−δ
