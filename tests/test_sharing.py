"""DLB broker + sharing policies (paper §3.3, Table 3)."""

from _hypothesis_compat import given, settings, st

from repro.core.monitoring import TaskMonitor
from repro.core.prediction import CPUPredictor, PredictionConfig
from repro.core.sharing import (DLBHybridPolicy, DLBPredictionPolicy,
                                LeWIPolicy, ResourceBroker)
from repro.core.policies import PollDecision


def _broker2() -> ResourceBroker:
    b = ResourceBroker()
    b.register_job("a", [0, 1, 2, 3])
    b.register_job("b", [4, 5, 6, 7])
    return b


class TestBroker:
    def test_lend_acquire_roundtrip(self):
        b = _broker2()
        b.lend("a", 0)
        assert b.pool_size() == 1
        got = b.acquire("b", 2)
        assert got == [0]
        assert b.holder(0) == "b"
        # returning it gives it back to the pool (a has no reclaim flag)
        b.lend("b", 0)
        assert b.holder(0) == ""
        got = b.acquire("a", 1)              # owner prefers its own cpu
        assert got == [0] and b.holder(0) == "a"

    def test_reclaim_flags_borrowed(self):
        b = _broker2()
        b.lend("a", 1)
        assert b.acquire("b", 1) == [1]
        back = b.reclaim("a")
        assert back == []                    # borrowed: comes back later
        assert b.cpu_must_return(1)
        owner = b.return_cpu("b", 1)
        assert owner == "a" and b.holder(1) == "a"

    def test_call_counting(self):
        b = _broker2()
        b.lend("a", 0)
        b.acquire("b", 1)
        b.acquire("b", 1)                    # failed acquire still counts
        assert b.job_calls("a") == 1
        assert b.job_calls("b") == 2
        assert b.total_calls == 3

    def test_return_cpu_keeps_pending_reclaim_wanted(self):
        """Regression: returning ONE of several flagged CPUs must not
        clear the owner's reclaim_wanted while other lent CPUs still
        carry return flags — that silently dropped multi-CPU reclaims."""
        b = _broker2()
        b.lend("a", 0)
        b.lend("a", 1)
        assert sorted(b.acquire("b", 2)) == [0, 1]
        assert b.reclaim("a") == []          # both borrowed: flagged
        assert b.cpu_must_return(0) and b.cpu_must_return(1)
        assert b.return_cpu("b", 0) == "a"
        # cpu 1 is still flagged ⇒ the reclaim must stay wanted
        assert b._jobs["a"].reclaim_wanted
        # ...so b's next lend of cpu 1 hands it straight to the owner
        assert b.lend("b", 1) == "a"
        assert b.holder(1) == "a"
        # nothing pending anymore
        assert not b._jobs["a"].reclaim_wanted

    def test_return_last_flagged_cpu_clears_reclaim_wanted(self):
        b = _broker2()
        b.lend("a", 0)
        assert b.acquire("b", 1) == [0]
        b.reclaim("a")
        b.return_cpu("b", 0)
        assert not b._jobs["a"].reclaim_wanted

    @given(st.lists(st.tuples(st.sampled_from(["lend_a", "lend_b",
                                               "acq_a", "acq_b"]),
                              st.integers(0, 7)),
                    max_size=60))
    @settings(max_examples=100, deadline=None)
    def test_conservation(self, ops):
        """Property: every CPU always has exactly one holder ∈ {a, b,
        pool}; pool+held == 8 after any op sequence."""
        b = _broker2()
        for op, cpu in ops:
            if op == "lend_a":
                b.lend("a", cpu)
            elif op == "lend_b":
                b.lend("b", cpu)
            elif op == "acq_a":
                b.acquire("a", 1)
            else:
                b.acquire("b", 1)
            holders = [b.holder(c) for c in range(8)]
            assert all(h in ("a", "b", "") for h in holders)
            assert b.pool_size() == sum(1 for h in holders if h == "")


def _check_invariants(b: ResourceBroker) -> None:
    """Full-state broker invariants (the property tests' oracle):

    * every CPU has exactly one holder — a registered job or the pool;
    * a CPU is in the pool iff its holder is "";
    * ``lent``/``borrowed`` stay disjoint and mutually consistent:
      ``cpu ∈ owner.lent``  ⟺ someone else (or the pool) holds it,
      ``cpu ∈ job.borrowed`` ⟺ job holds a CPU it does not own.
    """
    jobs = b._jobs
    for cpu, owner in b._owner.items():
        holder = b.holder(cpu)
        assert holder == "" or holder in jobs
        assert (holder == "") == (cpu in b._pool)
        assert (cpu in jobs[owner].lent) == (holder != owner)
        for name, acct in jobs.items():
            assert not (acct.owned & acct.borrowed)
            assert (cpu in acct.borrowed) == \
                (holder == name and owner != name)
    assert len(b._pool) == len(set(b._pool))      # no duplicates


class TestBrokerInvariants:
    """Property-style interleavings over all four broker verbs."""

    OPS = ["lend_a", "lend_b", "acq_a", "acq_b", "reclaim_a", "reclaim_b",
           "ret_a", "ret_b"]

    @staticmethod
    def _apply(b: ResourceBroker, op: str, cpu: int) -> None:
        job = "a" if op.endswith("_a") else "b"
        if op.startswith("lend"):
            # lending is only legal for a CPU the job actually runs on
            if b.holder(cpu) == job:
                b.lend(job, cpu)
        elif op.startswith("acq"):
            b.acquire(job, 1 + cpu % 3)
        elif op.startswith("reclaim"):
            b.reclaim(job)
        else:   # cooperative return at a task boundary
            if cpu in b._jobs[job].borrowed and b.cpu_must_return(cpu):
                b.return_cpu(job, cpu)

    @given(st.lists(st.tuples(st.sampled_from(OPS), st.integers(0, 7)),
                    max_size=80))
    @settings(max_examples=200, deadline=None)
    def test_random_interleavings_hold_invariants(self, ops):
        b = _broker2()
        for op, cpu in ops:
            self._apply(b, op, cpu)
            _check_invariants(b)

    def test_deterministic_interleaving(self):
        """A fixed dense sequence so the invariants run even without
        hypothesis installed."""
        b = _broker2()
        seq = [("lend_a", 0), ("lend_a", 1), ("acq_b", 0), ("reclaim_a", 0),
               ("ret_b", 0), ("lend_b", 1), ("lend_b", 4), ("acq_a", 2),
               ("reclaim_b", 0), ("ret_a", 4), ("lend_a", 2), ("acq_b", 1),
               ("reclaim_a", 0), ("ret_b", 2), ("ret_b", 1), ("acq_a", 1)]
        for op, cpu in seq:
            self._apply(b, op, cpu)
            _check_invariants(b)


class TestSharingPolicies:
    def test_lewi_lends_first_poll(self):
        assert LeWIPolicy().on_poll_empty(0, 4, 1) is PollDecision.LEND

    def test_hybrid_spins_first(self):
        p = DLBHybridPolicy(spin_budget=100)
        assert p.on_poll_empty(0, 4, 99) is PollDecision.SPIN
        assert p.on_poll_empty(0, 4, 100) is PollDecision.LEND

    def test_prediction_lends_only_surplus(self):
        m = TaskMonitor(min_samples=1)
        for i in range(3):
            m.on_task_ready(i, "t", 1.0)
            m.on_task_execute(i, "t", 1.0)
            m.on_task_completed(i, "t", 1.0, 50e-6)
        m.on_task_ready(100, "t", 1.0)       # one window of work
        pred = CPUPredictor(m, n_cpus=4, config=PredictionConfig(
            rate_s=50e-6, min_samples=1, allow_oversubscription=True))
        pred.tick()
        p = DLBPredictionPolicy(pred)
        assert p.on_poll_empty(0, active=4, spin_count=1) \
            is PollDecision.LEND             # δ=4 > Δ=1
        assert p.on_poll_empty(0, active=1, spin_count=1) \
            is PollDecision.SPIN
        assert not p.eager_acquire           # single call per tick
        assert p.acquire_target(active=0, ready_tasks=10) == 1  # Δ−δ
