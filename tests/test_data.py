"""Data pipeline: determinism, sharding, label alignment."""

import numpy as np

from repro.data import SyntheticLM


def _collect(**kw):
    d = SyntheticLM(vocab=100, seq_len=16, global_batch=4, **kw)
    batches = [next(d) for _ in range(3)]
    d.close()
    return batches


def test_deterministic_across_runs():
    a = _collect(seed=3)
    b = _collect(seed=3)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.tokens, y.tokens)
        np.testing.assert_array_equal(x.labels, y.labels)


def test_restart_from_step_matches():
    full = _collect(seed=1)
    resumed = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=1,
                          start_step=2)
    b2 = next(resumed)
    resumed.close()
    np.testing.assert_array_equal(full[2].tokens, b2.tokens)


def test_shards_differ_but_are_deterministic():
    s0 = SyntheticLM(vocab=100, seq_len=16, global_batch=8, seed=0,
                     shard=0, n_shards=2)
    s1 = SyntheticLM(vocab=100, seq_len=16, global_batch=8, seed=0,
                     shard=1, n_shards=2)
    a, b = next(s0), next(s1)
    s0.close(); s1.close()
    assert a.tokens.shape == (1, 4, 16)     # half the global batch
    assert not np.array_equal(a.tokens, b.tokens)


def test_labels_are_shifted_tokens():
    d = SyntheticLM(vocab=50, seq_len=8, global_batch=2, seed=0)
    b = next(d)
    d.close()
    np.testing.assert_array_equal(b.labels[..., :-1], b.tokens[..., 1:])
    assert (b.labels[..., -1] == -1).all()


def test_frontend_prefix_and_masked_labels():
    d = SyntheticLM(vocab=50, seq_len=16, global_batch=2, seed=0,
                    frontend_len=4, d_model=8)
    b = next(d)
    d.close()
    assert b.tokens.shape == (1, 2, 12)
    assert b.labels.shape == (1, 2, 16)
    assert (b.labels[..., :4] == -1).all()
    assert b.prefix.shape == (1, 2, 4, 8)


def test_vocab_bounds():
    d = SyntheticLM(vocab=33, seq_len=64, global_batch=4, seed=9)
    b = next(d)
    d.close()
    assert b.tokens.min() >= 0 and b.tokens.max() < 33
