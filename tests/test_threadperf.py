"""ThreadExecutor fast lane: sharded queues, batched monitoring,
per-worker wake targeting, and the threaded-trace → sim round trip.

Structural properties are tested on :class:`ShardedScheduler` directly
(single-threaded — every interleaving is then deterministic); the
threaded tests assert end-state invariants (all tasks ran, no wake
timeout, no lock-order violation) rather than schedules, because a real
8-worker schedule is not reproducible.
"""

import itertools
import json
import time
from pathlib import Path

import pytest

from repro.analysis import annotations, install_witness
from repro.core import GovernorSpec
from repro.core.events import EventBus
from repro.core.monitoring import TaskMonitor
from repro.runtime import ShardedScheduler, Task, TaskGraph, ThreadExecutor
from repro.runtime import task as task_mod
from repro.trace import TraceRecorder, TraceReplayer
from repro.workloads import BurstArrivals

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_threadperf.json"


def fanout_graph(width=8, depth=6, service=1e-5):
    """``depth`` waves of ``width`` tasks behind a barrier each — wide
    enough to spill across shards, deep enough to exercise handoff."""
    g = TaskGraph()
    done = []
    prev_wave = []
    for d in range(depth):
        wave = []
        for w in range(width):
            t = Task(f"w{w % 3}", cost=1.0 + w % 3,
                     fn=lambda: done.append(1), service_time=service)
            for p in prev_wave:
                t.depends_on(p)
            g.add(t)
            wave.append(t)
        prev_wave = wave
    return g, done


class TestShardedScheduler:
    def test_global_queue_hands_off_fifo(self):
        s = ShardedScheduler(2)
        a, b = Task("a"), Task("b")
        assert s.submit_all([a, b]) == 2
        # external submissions land on the global queue; any worker
        # drains it oldest-first
        assert s.poll(1) is a
        assert s.poll(0) is b
        assert s.poll(0) is None and s.poll(1) is None

    def test_local_lifo_then_fifo_steal(self):
        s = ShardedScheduler(2)
        a = Task("a")
        b = Task("b").depends_on(a)
        c = Task("c").depends_on(a)
        s.submit_all([a, b, c])
        assert s.poll(0) is a
        assert s.complete(a, 0.0, worker_id=0) == [b, c]
        # owner pops its own shard LIFO: most recently readied runs
        # next, cache-warm
        assert s.poll(0) is c
        # a thief takes the *oldest* entry from the victim's far end
        assert s.poll(1) is b
        assert s.steals == 1
        s.complete(c, 0.0, worker_id=0)
        s.complete(b, 0.0, worker_id=1)
        assert s.drained() and s.pending == 0

    def test_monitor_ops_buffer_until_flush(self):
        m = TaskMonitor()
        s = ShardedScheduler(1, monitor=m, flush_batch=1000)
        tasks = [Task("t") for _ in range(3)]
        s.submit_all(tasks)
        for _ in tasks:
            t = s.poll(0)
            s.complete(t, 1e-4, worker_id=0)
        # transitions sit in the worker's buffer — one monitor lock
        # acquisition happens at flush, not per event
        assert m.completed_instances() == 0
        s.flush_worker(0)
        assert m.completed_instances() == 3

    def test_flush_triggers_at_batch_threshold(self):
        m = TaskMonitor()
        s = ShardedScheduler(1, monitor=m, flush_batch=2)
        s.submit_all([Task("t"), Task("t")])
        t1 = s.poll(0)              # 1 op buffered (execute)
        assert m.completed_instances() == 0
        s.complete(t1, 1e-4, worker_id=0)   # 2nd op hits the threshold
        assert m.completed_instances() == 1

    def test_flush_all_is_the_drain_backstop(self):
        m = TaskMonitor()
        s = ShardedScheduler(4, monitor=m, flush_batch=1000)
        s.submit_all([Task("t") for _ in range(4)])
        for w in range(4):
            s.complete(s.poll(w), 1e-4, worker_id=w)
        s.flush_all()
        assert m.completed_instances() == 4


class TestExecutorLifecycle:
    def test_submit_after_close_raises(self):
        ex = ThreadExecutor(2, policy="busy").start()
        done = []
        ex.submit(Task("w", fn=lambda: done.append(1), service_time=1e-6))
        ex.close()
        assert done == [1]
        with pytest.raises(RuntimeError, match="after close"):
            ex.submit(Task("w", fn=lambda: done.append(2)))

    def test_submit_after_closed_run_raises(self):
        g, done = fanout_graph(width=4, depth=2)
        ex = ThreadExecutor(2, policy="idle")
        ex.run(g)
        with pytest.raises(RuntimeError, match="after close"):
            ex.submit(Task("w", fn=lambda: None))

    @pytest.mark.parametrize("policy", ["idle", "hybrid", "prediction"])
    def test_wake_targeting_no_timeouts(self, policy):
        """Every idle stretch in this run is far below the 0.5 s parked
        recheck, so a single missed wakeup would strand a worker for the
        full timeout; ``wake_timeouts == 0`` is the no-missed-wakeup
        witness for the targeted (non-``notify_all``) wake path."""
        g, done = fanout_graph(width=8, depth=6)
        ex = ThreadExecutor(4, policy=policy, prediction_rate_s=1e-3)
        ex.run(g)
        assert len(done) == 48
        assert ex.wake_timeouts == 0

    def test_wake_targeting_open_mode(self):
        ex = ThreadExecutor(3, policy="idle").start()
        done = []
        for burst in range(5):
            for _ in range(6):
                ex.submit(Task("w", cost=1.0, fn=lambda: done.append(1),
                               service_time=1e-6))
            time.sleep(2e-3)    # idle lull well under the 0.5 s recheck
        ex.close()
        assert len(done) == 30
        assert ex.wake_timeouts == 0


class TestThreadedTraceReplay:
    @pytest.mark.parametrize("policy", ["busy", "idle", "hybrid",
                                        "prediction"])
    def test_threaded_trace_replays_in_sim(self, policy, tmp_path):
        """A trace recorded on real threads (N interleaved event
        streams, merged by per-stream seq) must rebuild and replay in
        the simulator — and the sim replay of that replay must be
        byte-identical, the same round-trip contract sim-recorded
        traces have."""
        g, done = fanout_graph(width=6, depth=4, service=1e-4)
        n = len(g.tasks)
        ex = ThreadExecutor(4, policy=policy, prediction_rate_s=1e-3)
        rec = TraceRecorder(bus=ex.bus)
        r1 = ex.run(g)
        assert r1.tasks_completed == n == len(done)

        spec = GovernorSpec(resources=4, policy=policy, monitoring=True)
        bus2 = EventBus()
        rec2 = TraceRecorder(bus=bus2)
        # task ids are a process-global counter; byte identity needs
        # both rebuilds to mint the same ids (as test_simperf does)
        task_mod._ids = itertools.count(10_000)
        r2 = TraceReplayer(rec).replay(spec, bus=bus2)
        assert r2.tasks_completed == n

        bus3 = EventBus()
        rec3 = TraceRecorder(bus=bus3)
        task_mod._ids = itertools.count(10_000)
        r3 = TraceReplayer(rec2).replay(spec, bus=bus3)
        assert r3.tasks_completed == n
        p2 = rec2.to_jsonl(tmp_path / "replay1.jsonl")
        p3 = rec3.to_jsonl(tmp_path / "replay2.jsonl")
        assert p2.read_bytes() == p3.read_bytes()

    def test_threaded_jsonl_round_trip(self, tmp_path):
        """Merged threaded trace → JSONL → replayer: same graph."""
        g, _ = fanout_graph(width=5, depth=3, service=1e-5)
        ex = ThreadExecutor(3, policy="busy")
        rec = TraceRecorder(bus=ex.bus)
        ex.run(g)
        path = rec.to_jsonl(tmp_path / "threaded.jsonl")
        graph, _arrivals = TraceReplayer(path).build()
        assert len(graph.tasks) == len(g.tasks)


@pytest.mark.slow
class TestOpenModeStress:
    def test_burst_stress_under_strict_witness(self):
        """≥8 workers, burst arrivals, prediction policy, with the
        lock-order witness in strict mode: any inversion raises on the
        acquiring thread instead of being collected for session end."""
        saved = annotations._witness
        witness = install_witness(strict=True)
        try:
            ex = ThreadExecutor(8, policy="prediction",
                                prediction_rate_s=1e-3).start()
            done = []
            times = BurstArrivals(burst_size=64, gap=4e-3,
                                  spacing=0.0).times(512)
            t0 = time.perf_counter()
            for rt in times:
                lag = rt - (time.perf_counter() - t0)
                if lag > 0:
                    time.sleep(lag)
                ex.submit(Task("w", cost=1.0, fn=lambda: done.append(1),
                               service_time=1e-5))
            ex.close()
        finally:
            annotations._set_witness(saved)
        assert len(done) == 512
        assert not witness.violations
        assert witness.check_declared() == []
        assert ex.wake_timeouts == 0


class TestThroughputPins:
    """The committed BENCH_threadperf.json is the contract."""

    @pytest.fixture(autouse=True)
    def _no_witness(self):
        # Measurement-only tests must not pay the suite-wide lock-order
        # witness's per-acquisition bookkeeping.
        from repro.analysis import witness_paused
        with witness_paused():
            yield

    @pytest.fixture(scope="class")
    def bench(self):
        assert BENCH_PATH.exists(), "BENCH_threadperf.json not committed"
        rows = json.loads(BENCH_PATH.read_text())["rows"]
        return {(r["scenario"], r["mode"]): r for r in rows}

    def test_committed_acceptance_speedup(self, bench):
        """Acceptance pin: ≥1.5× tasks/sec vs the recorded pre-change
        baseline on the 8-worker closed-graph scenario."""
        base = bench[("closed/8w/busy", "baseline")]
        fast = bench[("closed/8w/busy", "fastlane")]
        assert fast["tasks_per_sec"] >= 1.5 * base["tasks_per_sec"]

    def test_committed_no_scenario_collapsed(self, bench):
        """No committed scenario may sit below 0.9× its recorded
        baseline (open/2w is driver-bound, not scheduler-bound, so
        parity there is expected — collapse is not)."""
        for (scenario, mode), row in bench.items():
            if mode != "fastlane":
                continue
            base = bench[(scenario, "baseline")]
            assert row["tasks_per_sec"] > 0.9 * base["tasks_per_sec"], \
                f"{scenario} collapsed vs recorded baseline"

    @pytest.mark.slow
    def test_throughput_floor_renormalized(self, bench):
        """Re-run the gate scenario and compare *normalized* throughput
        (tasks/sec × calibration seconds) against the committed row.
        Threaded wall time is far noisier than the simulator's CPU
        time, so the floor is generous: >50% regression fails."""
        from benchmarks.bench_threadperf import (calibrate, chain_graph)

        committed = bench[("closed/8w/busy", "fastlane")]
        calib_now = min(calibrate() for _ in range(3))
        best = None
        for _ in range(3):  # best-of-3, like the committed measurement
            g = chain_graph(32, 200)
            ex = ThreadExecutor(8, policy="busy")
            t0 = time.perf_counter()
            ex.run(g)
            wall = time.perf_counter() - t0
            best = wall if best is None or wall < best else best
        norm_now = (6400 / best) * calib_now
        norm_committed = (committed["tasks_per_sec"]
                          * committed["calibration"])
        assert norm_now >= 0.5 * norm_committed, (
            f"fast-lane throughput regressed: {6400 / best:.0f} tasks/s "
            f"(normalized {norm_now:.0f}) vs committed "
            f"{committed['tasks_per_sec']:.0f} "
            f"(normalized {norm_committed:.0f})")
