"""Serving engine: greedy correctness, continuous batching, autoscaler."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import forward, init_params
from repro.serving import AutoScaler, Request, ServingEngine

CFG = get_smoke_config("llama3.2-1b")
KEY = jax.random.PRNGKey(0)
PARAMS = init_params(KEY, CFG)


def _greedy_reference(prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        logits, _ = forward(PARAMS, jnp.asarray([toks], jnp.int32), CFG)
        toks.append(int(jnp.argmax(logits[0, -1, :CFG.vocab])))
    return toks[len(prompt):]


@pytest.mark.slow
def test_single_request_matches_reference():
    engine = ServingEngine(CFG, PARAMS, max_batch=2, max_len=64)
    req = engine.submit(Request(prompt=[5, 9, 2, 7], max_new_tokens=6))
    engine.run_until_drained()
    assert req.done
    assert req.output == _greedy_reference([5, 9, 2, 7], 6)


@pytest.mark.slow
def test_continuous_batching_mixed_lengths():
    engine = ServingEngine(CFG, PARAMS, max_batch=2, max_len=64)
    prompts = [[1, 2, 3], [4, 5, 6, 7, 8], [9, 10], [11, 12, 13, 14]]
    reqs = [engine.submit(Request(prompt=p, max_new_tokens=4))
            for p in prompts]
    engine.run_until_drained()
    for p, r in zip(prompts, reqs):
        assert r.done
        assert r.output == _greedy_reference(p, 4), p


def test_slots_freed_and_reused():
    engine = ServingEngine(CFG, PARAMS, max_batch=1, max_len=64)
    reqs = [engine.submit(Request(prompt=[i + 1], max_new_tokens=3))
            for i in range(3)]
    engine.run_until_drained()
    assert all(r.done for r in reqs)
    # serialized through one slot: completion order == arrival order
    times = [r.done_at for r in reqs]
    assert times == sorted(times)


def test_autoscaler_tracks_load():
    monitor_engine = ServingEngine(CFG, PARAMS, max_batch=4, max_len=64)
    scaler = AutoScaler(monitor_engine.monitor, max_replicas=4,
                        policy="prediction")
    # no load ⇒ scale to zero
    assert scaler.target(0, 0) == 0
    # queue load ⇒ scale out (count-based until α is learned)
    for i in range(8):
        monitor_engine.submit(Request(prompt=[1, 2], max_new_tokens=2))
    assert scaler.target(8, 0) >= 1
    monitor_engine.run_until_drained()
    assert scaler.target(0, 0) == 0


def test_autoscaler_policies_differ():
    engine = ServingEngine(CFG, PARAMS, max_batch=4, max_len=64)
    busy = AutoScaler(engine.monitor, 4, policy="busy")
    idle = AutoScaler(engine.monitor, 4, policy="idle")
    assert busy.target(0, 0) == 4
    assert idle.target(0, 0) == 0
    assert idle.target(2, 1) == 3


def test_autoscaler_never_exceeds_max_replicas_when_oversubscribed():
    """Regression: a prediction stack configured with the DLB-style
    oversubscribing Alg. 1 must still cap the serving target at the
    replicas the deployment owns."""
    from repro.core.governor import GovernorSpec
    from repro.core.monitoring import TaskMonitor
    from repro.core.prediction import PredictionConfig

    monitor = TaskMonitor(min_samples=1)
    scaler = AutoScaler(monitor, max_replicas=4, spec=GovernorSpec(
        resources=4, policy="prediction", monitoring=True,
        prediction=PredictionConfig(min_samples=1, rate_s=50e-6,
                                    allow_oversubscription=True,
                                    oversubscription_cap=4.0)))
    for i in range(3):
        monitor.on_task_ready(i, "req", 1.0)
        monitor.on_task_execute(i, "req", 1.0)
        monitor.on_task_completed(i, "req", 1.0, 50e-6)
    for i in range(12):                    # far more work than replicas
        monitor.on_task_ready(100 + i, "req", 1.0)
    assert scaler.predictor.compute_delta() > 4   # Δ oversubscribes...
    assert scaler.target(12, 0) == 4              # ...the target cannot
