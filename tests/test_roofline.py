"""The HLO roofline analyzer, validated against known-answer programs.

Key validations (DESIGN.md §6):
* scanned vs unrolled: trip-count scaling recovers the unrolled FLOPs;
* collective bytes match hand-computed ring formulas for an explicit
  psum program;
* the raw ``cost_analysis()`` flops really do count the while body once
  (the artifact that motivates the custom walker).
"""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.roofline import analyze_hlo, roofline_terms

D = 64


def _flops_of(fn, *args) -> tuple[float, float]:
    compiled = jax.jit(fn).lower(*args).compile()
    a = analyze_hlo(compiled.as_text(), n_devices=1)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):        # older jax returns [dict], newer dict
        ca = ca[0] if ca else {}
    raw = ca.get("flops", 0.0)
    return a.flops, raw


def test_single_matmul_flops_exact():
    x = jnp.ones((8, D), jnp.float32)
    w = jnp.ones((D, D), jnp.float32)
    flops, _ = _flops_of(lambda a, b: a @ b, x, w)
    assert flops == pytest.approx(2 * 8 * D * D, rel=0.01)


def test_scan_flops_match_unrolled():
    n = 7
    ws = jnp.ones((n, D, D), jnp.float32)
    x = jnp.ones((8, D), jnp.float32)

    def scanned(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    def unrolled(ws, x):
        h = x
        for i in range(n):
            h = jnp.tanh(h @ ws[i])
        return h

    f_scan, raw_scan = _flops_of(scanned, ws, x)
    f_unr, _ = _flops_of(unrolled, ws, x)
    assert f_scan == pytest.approx(f_unr, rel=0.05)
    # and the raw cost_analysis undercounts the scanned one (body once)
    assert raw_scan < f_scan / 2


def test_nested_scan_trip_scaling():
    inner, outer = 3, 5
    ws = jnp.ones((outer, inner, D, D), jnp.float32)
    x = jnp.ones((4, D), jnp.float32)

    def fn(ws, x):
        def outer_body(h, w_in):
            def inner_body(h2, w):
                return h2 @ w, None
            h, _ = jax.lax.scan(inner_body, h, w_in)
            return h, None
        h, _ = jax.lax.scan(outer_body, x, ws)
        return h

    flops, _ = _flops_of(fn, ws, x)
    assert flops == pytest.approx(2 * 4 * D * D * inner * outer, rel=0.05)


def test_memory_bytes_scale_with_scan():
    n = 9
    xs = jnp.ones((n, 128, 128), jnp.float32)

    def fn(xs):
        def body(c, x):
            return c + x * 2.0, None
        c, _ = jax.lax.scan(body, jnp.zeros((128, 128)), xs)
        return c

    compiled = jax.jit(fn).lower(xs).compile()
    a = analyze_hlo(compiled.as_text(), n_devices=1)
    # each step reads + writes ≥ one (128,128) f32 tile
    assert a.hbm_bytes >= n * 128 * 128 * 4 * 2


_COLLECTIVE_PROBE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.roofline import analyze_hlo

    mesh = jax.make_mesh((8,), ("d",))
    X = jax.ShapeDtypeStruct((8, 1024), jnp.float32,
                             sharding=NamedSharding(mesh, P("d", None)))

    def fn(x):
        # one full all-reduce of a (1024,) f32 vector over 8 devices;
        # the explicit NamedSharding constraint works with and without
        # a jax.set_mesh context (jax.sharding.AxisType / jax.set_mesh
        # do not exist on every supported jax version)
        return jax.lax.with_sharding_constraint(
            x.sum(axis=0, keepdims=True),
            NamedSharding(mesh, P(None, None)))

    compiled = jax.jit(fn).lower(X).compile()
    a = analyze_hlo(compiled.as_text(), n_devices=8)
    # ring all-reduce: 2 * size * (g-1)/g per device
    expect = 2 * 1024 * 4 * 7 / 8
    assert a.collective_by_kind.get("all-reduce", 0) == expect, \\
        (a.collective_by_kind, expect)
    print("COLLECTIVE_OK")
""")


def test_collective_bytes_hand_computed():
    """Run in a subprocess so the 8-device flag can't leak into the
    single-device test session."""
    r = subprocess.run([sys.executable, "-c", _COLLECTIVE_PROBE],
                       capture_output=True, text=True, cwd=".",
                       timeout=300)
    assert "COLLECTIVE_OK" in r.stdout, (r.stdout, r.stderr)


def test_roofline_terms_math():
    class A:
        flops = 197e12          # exactly one second of compute
        hbm_bytes = 819e9 / 2   # half a second of HBM
        collective_bytes = 0.0
        collective_by_kind = {}
        collective_count = 0

    t = roofline_terms(A(), n_chips=4, model_flops_total=4 * 197e12)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.dominant == "compute"
    assert t.useful_ratio == pytest.approx(1.0)


def test_dominant_term_selection():
    class A:
        flops = 1.0
        hbm_bytes = 819e9 * 3
        collective_bytes = 0.0
        collective_by_kind = {}
        collective_count = 0

    t = roofline_terms(A(), 1, 1.0)
    assert t.dominant == "memory"
