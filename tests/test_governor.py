"""Unified ResourceGovernor API: spec round-trip, policy registry, and
cross-frontend parity (ThreadExecutor vs SimExecutor stacks built from
one GovernorSpec make identical decisions on a fixed task trace)."""

import pytest

from repro.core.governor import (DEFAULT_MIN_SAMPLES, GovernorReport,
                                 GovernorSpec, ResourceGovernor,
                                 _REGISTRY, policy_entry, register_policy,
                                 registered_policies)
from repro.core.policies import BusyPolicy
from repro.core.prediction import PredictionConfig
from repro.runtime import (MN4, SimCluster, SimExecutor, SimJobSpec, Task,
                           TaskGraph, ThreadExecutor)


class TestSpec:
    def test_round_trip(self):
        spec = GovernorSpec(
            resources=12, policy="prediction",
            prediction=PredictionConfig(rate_s=1e-3, min_samples=2,
                                        allow_oversubscription=True),
            spin_budget=7, monitoring=True, min_resources=2,
            policy_params={"foo": 1})
        assert GovernorSpec.from_dict(spec.to_dict()) == spec

    def test_defaults_are_unified(self):
        spec = GovernorSpec(resources=4, policy="prediction")
        assert spec.prediction.min_samples == DEFAULT_MIN_SAMPLES
        gov = ResourceGovernor(spec)
        # the monitor inherits the same threshold — no 4-vs-3 split
        assert gov.monitor.min_samples == DEFAULT_MIN_SAMPLES
        assert gov.predictor.config.min_samples == DEFAULT_MIN_SAMPLES

    def test_validation(self):
        with pytest.raises(ValueError):
            GovernorSpec(resources=0)
        with pytest.raises(ValueError):
            GovernorSpec(resources=4, spin_budget=0)
        with pytest.raises(ValueError):
            GovernorSpec(resources=4, min_resources=5)
        with pytest.raises(ValueError):
            PredictionConfig(min_samples=0)
        with pytest.raises(ValueError):
            PredictionConfig(rate_s=0.0)
        with pytest.raises(ValueError):
            PredictionConfig(rate_s=-1e-3)


class TestRegistry:
    def test_builtins_registered(self):
        names = registered_policies()
        for expected in ("busy", "idle", "hybrid", "prediction",
                         "dlb-lewi", "dlb-hybrid", "dlb-prediction"):
            assert expected in names

    def test_unknown_policy_lists_all_names(self):
        with pytest.raises(ValueError) as exc:
            policy_entry("no-such-policy")
        msg = str(exc.value)
        # the error must enumerate every registered name — including the
        # DLB/sharing policies the old make_policy dispatch omitted
        for name in registered_policies():
            assert name in msg

    def test_register_custom_policy(self):
        @register_policy("test-custom")
        def _custom(spec, predictor):
            return BusyPolicy()

        try:
            assert "test-custom" in registered_policies()
            gov = ResourceGovernor(GovernorSpec(resources=2,
                                                policy="test-custom"))
            assert isinstance(gov.policy, BusyPolicy)
        finally:
            _REGISTRY.pop("test-custom", None)

    def test_custom_policy_reads_params(self):
        @register_policy("test-param")
        def _param(spec, predictor):
            p = BusyPolicy()
            p.knob = spec.policy_params["knob"]
            return p

        try:
            gov = ResourceGovernor(GovernorSpec(
                resources=2, policy="test-param",
                policy_params={"knob": 42}))
            assert gov.policy.knob == 42
        finally:
            _REGISTRY.pop("test-param", None)


class TestGovernor:
    def test_sharing_predictor_oversubscribes(self):
        gov = ResourceGovernor(GovernorSpec(resources=8,
                                            policy="dlb-prediction"))
        assert gov.sharing
        assert gov.predictor.config.allow_oversubscription

    def test_pull_frontend_has_no_worker_state(self):
        gov = ResourceGovernor(GovernorSpec(resources=4,
                                            policy="prediction"))
        assert gov.manager is None and gov.energy is None
        with pytest.raises(RuntimeError):
            gov.on_poll_empty(0)

    def test_target_semantics(self):
        busy = ResourceGovernor(GovernorSpec(resources=4, policy="busy",
                                             min_resources=1))
        idle = ResourceGovernor(GovernorSpec(resources=4, policy="idle",
                                             min_resources=1))
        pred = ResourceGovernor(GovernorSpec(resources=4,
                                             policy="prediction",
                                             min_resources=1))
        assert busy.target(0, 0) == 4       # always hot
        assert idle.target(0, 0) == 0       # scale to zero
        assert idle.target(2, 1) == 3       # reactive
        assert pred.target(0, 0) == 0       # no live work ⇒ zero
        assert 1 <= pred.target(5, 0) <= 4  # Δ clamped to bounds


class TestParity:
    """ThreadExecutor and SimExecutor assembled from the SAME GovernorSpec
    must make identical policy decisions on a fixed trace — the redesign's
    core guarantee that the simulator is a faithful twin."""

    SPEC = GovernorSpec(resources=4, policy="prediction",
                        prediction=PredictionConfig(rate_s=1e-3,
                                                    min_samples=1))

    @staticmethod
    def _drive(gov):
        """A fixed task trace fed straight to the governor lifecycle
        surface; returns every observable decision."""
        out = []
        # three tasks become ready; α unknown ⇒ count-based prediction
        for tid in range(3):
            gov.monitor.on_task_ready(tid, "t", 1.0)
        out.append(gov.tick())
        out.append(list(gov.on_tasks_added(3)))
        # two workers execute; one finishes fast, one slow
        for wid, tid in ((0, 0), (1, 1)):
            gov.monitor.on_task_execute(tid, "t", 1.0)
            gov.on_task_started(wid)
        gov.monitor.on_task_completed(0, "t", 1.0, 5e-4)
        gov.on_task_finished(0)
        gov.monitor.on_task_completed(1, "t", 1.0, 2e-3)
        gov.on_task_finished(1)
        out.append(gov.tick())
        # empty polls after the queue drains (task 2 still ready)
        for wid in (0, 1, 2, 3):
            out.append(gov.on_poll_empty(wid))
        out.append(gov.tick())
        out.append(list(gov.on_tasks_added(1)))
        return out

    def test_identical_decision_sequences(self):
        tex = ThreadExecutor(spec=self.SPEC)
        cluster = SimCluster(MN4)
        job = cluster.add_job(SimJobSpec(
            name="parity", graph=TaskGraph(), governor=self.SPEC,
            cpus=list(range(self.SPEC.resources))))
        gov_thread, gov_sim = tex.governor, job.governor
        assert type(gov_thread.policy) is type(gov_sim.policy)
        assert gov_thread.spec == gov_sim.spec
        assert self._drive(gov_thread) == self._drive(gov_sim)

    def test_run_reports_share_schema(self):
        def graph():
            g = TaskGraph()
            prev = None
            for _ in range(10):
                t = Task("link", cost=1.0, service_time=1e-5)
                if prev is not None:
                    t.depends_on(prev)
                g.add(t)
                prev = t
            return g

        r_sim = SimExecutor(MN4, spec=self.SPEC).run(graph())
        r_thr = ThreadExecutor(spec=self.SPEC).run(graph())
        assert isinstance(r_sim, GovernorReport)
        assert isinstance(r_thr, GovernorReport)
        assert r_sim.policy == r_thr.policy == "prediction"
        assert r_sim.tasks_completed == r_thr.tasks_completed == 10
