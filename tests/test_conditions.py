"""Dynamic machine conditions: timelines, clamps, broker invariants,
and perturbed replay round trips.

Covers the conditions subsystem end to end:

* ``Perturbation`` / ``ConditionTimeline`` construction, serialization,
  seeded scenario determinism, and ``neutralized()`` semantics;
* the ``PowerModel.power`` / ``MachineModel.service_time`` frequency
  clamp contracts (documented in their docstrings);
* ``EnergyMeter`` lazy power-cap violation accounting;
* ``ResourceBroker`` fail/recover invariants, deterministically and —
  when hypothesis is installed — under random interleavings of the
  sharing verbs (no core simultaneously lent and failed; pool counts
  conserve);
* perturbed sim→sim trace replays: the PERTURBATION events round-trip
  the timeline byte-exactly and replay-of-replay is a fixed point for
  every policy on both a homogeneous and a heterogeneous machine;
* the empty timeline as the degenerate case: byte-identical traces and
  bit-identical reports vs. no conditions at all.
"""

import itertools
import json
import random

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import EventBus, EventKind, GovernorSpec
from repro.core.conditions import (ConditionTimeline, MachineConditions,
                                   Perturbation, PerturbationKind,
                                   core_fail, core_recover, power_cap,
                                   straggler, thermal_throttle)
from repro.core.energy import CoreState, EnergyMeter, PowerModel
from repro.core.sharing import ResourceBroker
from repro.runtime import task as task_mod
from repro.runtime import (DVFS2, HYBRID_PE, MN4, SimCluster, SimExecutor,
                           SimJobSpec, Task, TaskGraph)
from repro.trace import TraceRecorder, TraceReplayer


def wave_graph(seed=0, n_waves=6, width=8):
    """Waves of parallel tasks separated by barriers (test_trace idiom)."""
    rng = random.Random(seed)
    g = TaskGraph()
    prev = None
    for _ in range(n_waves):
        wave = [Task("wave", cost=1.0,
                     service_time=rng.uniform(5e-5, 2e-4))
                for _ in range(width)]
        for t in wave:
            if prev is not None:
                t.depends_on(prev)
            g.add(t)
        bar = Task("barrier", cost=0.1, service_time=1e-5)
        for t in wave:
            bar.depends_on(t)
        g.add(bar)
        prev = bar
    return g


def perturbed_timeline():
    """One of everything, timed to land mid-run for wave_graph()."""
    return ConditionTimeline([
        power_cap(0.0, 20.0),
        core_fail(0.0005, 2),
        straggler(0.001, 5, 4.0),
        thermal_throttle(0.0015, "P", 0.6),
        core_recover(0.002, 2),
    ])


def trace_bytes(rec: TraceRecorder) -> str:
    return "\n".join(json.dumps(e.to_dict()) for e in rec.merged_events())


# ---------------------------------------------------------------------------
# Perturbation / ConditionTimeline
# ---------------------------------------------------------------------------

class TestTimeline:
    def test_serialization_round_trip(self):
        tl = perturbed_timeline()
        back = ConditionTimeline.from_dicts(tl.to_dicts())
        assert back.to_dicts() == tl.to_dicts()
        assert list(back) == list(tl)

    def test_sorted_by_time_then_insertion(self):
        a, b = core_fail(1.0, 0), core_fail(1.0, 1)
        tl = ConditionTimeline([straggler(2.0, 3, 2.0), b, a])
        assert [p.core for p in tl] == [1, 0, 3]

    def test_negative_time_rejected(self):
        with pytest.raises(ValueError):
            ConditionTimeline([core_fail(-0.1, 0)])

    def test_straggler_slowdown_validated(self):
        with pytest.raises(ValueError):
            straggler(0.0, 0, 0.5)

    def test_empty_timeline_is_falsy(self):
        assert not ConditionTimeline()
        assert perturbed_timeline()

    def test_neutralized_disarms_speed_keeps_structure(self):
        tl = perturbed_timeline().neutralized()
        by_kind = {p.kind: p for p in tl}
        # speed-changing perturbations are disarmed...
        assert by_kind[PerturbationKind.STRAGGLER].slowdown == 1.0
        assert by_kind[PerturbationKind.THERMAL_THROTTLE].freq == 1.0
        # ...but the STRAGGLER keeps its suspect marker
        mc = MachineConditions()
        mc.apply(by_kind[PerturbationKind.STRAGGLER])
        assert mc.is_suspect(5)
        assert mc.slowdown_of(5) == 1.0
        # structural perturbations survive verbatim
        assert by_kind[PerturbationKind.POWER_CAP].watts == 20.0
        assert by_kind[PerturbationKind.CORE_FAIL].core == 2
        # idempotent: the replay-of-replay fixed point depends on this
        assert tl.neutralized().to_dicts() == tl.to_dicts()

    def test_random_faults_seeded_deterministic(self):
        kw = dict(n_cores=16, horizon=1.0, n_faults=4, mttr=0.1)
        a = ConditionTimeline.random_faults(seed=7, **kw)
        b = ConditionTimeline.random_faults(seed=7, **kw)
        c = ConditionTimeline.random_faults(seed=8, **kw)
        assert a.to_dicts() == b.to_dicts()
        assert a.to_dicts() != c.to_dicts()
        fails = [p for p in a if p.kind is PerturbationKind.CORE_FAIL]
        assert len(fails) == 4
        assert len({p.core for p in fails}) == 4     # distinct cores
        for p in a:
            assert 0.0 <= p.time < 1.0
        # every recover follows its core's failure
        fail_at = {p.core: p.time for p in fails}
        for p in a:
            if p.kind is PerturbationKind.CORE_RECOVER:
                assert p.time >= fail_at[p.core]

    def test_random_stragglers_in_range(self):
        tl = ConditionTimeline.random_stragglers(
            n_cores=8, horizon=2.0, n_stragglers=3,
            slowdown_range=(2.0, 4.0), seed=3)
        assert len(tl) == 3
        for p in tl:
            assert 2.0 <= p.slowdown <= 4.0


class TestMachineConditions:
    def test_fail_recover(self):
        mc = MachineConditions()
        mc.apply(core_fail(0.0, 3))
        assert mc.is_failed(3) and mc.failed_cores() == [3]
        mc.apply(core_recover(1.0, 3))
        assert not mc.is_failed(3) and not mc.any_active

    def test_thermal_cap_set_and_lift(self):
        mc = MachineConditions()
        mc.apply(thermal_throttle(0.0, "P", 0.6))
        assert mc.thermal_cap("P") == 0.6
        assert mc.thermal_cap("E") == 1.0
        mc.apply(thermal_throttle(1.0, "P", None))
        assert mc.thermal_cap("P") == 1.0
        assert not mc.any_active

    def test_straggler_cured_only_by_none(self):
        mc = MachineConditions()
        mc.apply(straggler(0.0, 4, 3.0))
        assert mc.slowdown_of(4) == 3.0 and mc.is_suspect(4)
        # slowdown 1.0 = disarmed but still suspect (replay semantics)
        mc.apply(Perturbation(1.0, PerturbationKind.STRAGGLER, core=4,
                              slowdown=1.0))
        assert mc.slowdown_of(4) == 1.0 and mc.is_suspect(4)
        mc.apply(Perturbation(2.0, PerturbationKind.STRAGGLER, core=4))
        assert not mc.is_suspect(4) and not mc.any_active

    def test_power_cap_set_and_lift(self):
        mc = MachineConditions()
        mc.apply(power_cap(0.0, 25.0))
        assert mc.power_cap_w == 25.0 and mc.any_active
        mc.apply(power_cap(1.0, None))
        assert mc.power_cap_w is None and not mc.any_active

    @given(st.lists(st.tuples(st.booleans(), st.integers(0, 7)),
                    max_size=60))
    @settings(max_examples=100, deadline=None)
    def test_failed_set_tracks_reference(self, ops):
        mc = MachineConditions()
        ref: set[int] = set()
        for fail, core in ops:
            if fail:
                mc.apply(core_fail(0.0, core))
                ref.add(core)
            else:
                mc.apply(core_recover(0.0, core))
                ref.discard(core)
            assert set(mc.failed_cores()) == ref
            assert mc.is_failed(core) == (core in ref)


# ---------------------------------------------------------------------------
# Frequency clamp contracts (PowerModel.power / MachineModel.service_time)
# ---------------------------------------------------------------------------

class TestPowerModelClamp:
    def test_above_band_clamps_to_base(self):
        pm = PowerModel()
        assert pm.power(CoreState.ACTIVE, 1.5) == pm.active
        assert pm.power(CoreState.SPIN, 7.0) == pm.spin

    def test_below_band_clamps_to_idle_floor(self):
        pm = PowerModel()
        # freq < 0 clamps to 0: the dynamic term vanishes, never negative
        assert pm.power(CoreState.ACTIVE, -2.0) == pm.idle
        assert pm.power(CoreState.ACTIVE, 0.0) == pm.idle

    def test_in_band_bit_identical_cubic(self):
        pm = PowerModel(active=0.8, idle=0.05)
        f = 0.73
        assert pm.power(CoreState.ACTIVE, f) == \
            pm.idle + (pm.active - pm.idle) * f ** 3
        assert pm.power(CoreState.ACTIVE, 1.0) == pm.active

    def test_static_states_ignore_frequency(self):
        pm = PowerModel()
        for f in (-1.0, 0.4, 1.0, 2.0):
            assert pm.power(CoreState.IDLE, f) == pm.idle
            assert pm.power(CoreState.OFF, f) == pm.off


class TestServiceTimeClamp:
    def test_above_band_clamps_to_max_freq(self):
        assert MN4.service_time(1.0, 0, freq=2.0) == \
            MN4.service_time(1.0, 0, freq=1.0)
        assert DVFS2.service_time(1.0, 0, freq=1.5) == \
            DVFS2.service_time(1.0, 0, freq=1.0)

    def test_nonpositive_clamps_to_lowest_step(self):
        # DVFS2 sockets publish steps (0.75, 0.875, 1.0)
        assert DVFS2.service_time(1.0, 0, freq=0.0) == \
            DVFS2.service_time(1.0, 0, freq=0.75)
        assert DVFS2.service_time(1.0, 0, freq=-1.0) == \
            DVFS2.service_time(1.0, 0, freq=0.75)
        # homogeneous machines fall back to their single full step —
        # a frequency of zero must never stall the task forever
        assert MN4.service_time(1.0, 0, freq=0.0) == \
            MN4.service_time(1.0, 0, freq=1.0)

    def test_in_band_honored_bit_identically(self):
        # 0.8 sits between DVFS2's published steps — thermal throttling
        # legitimately pins a core below/between its nominal steps
        assert DVFS2.service_time(1.0, 0, freq=0.8) == \
            1.0 / (DVFS2.speed_of(0) * 0.8)
        # heterogeneous: E-core speed scales the same clamped band
        e_core = 10   # HYBRID_PE cores 8..23 are E-cores
        assert HYBRID_PE.service_time(1.0, e_core, freq=0.5) == \
            1.0 / (HYBRID_PE.speed_of(e_core) * 0.5)


# ---------------------------------------------------------------------------
# EnergyMeter power-cap violation accounting
# ---------------------------------------------------------------------------

class TestCapViolationAccounting:
    def test_lazy_until_first_cap(self):
        m = EnergyMeter(4)
        m.set_state(0, CoreState.ACTIVE, 1.0)
        m.finish(2.0)
        assert m.power_cap_w is None
        assert m.cap_violation_s == 0.0

    def test_violation_seconds_accumulate(self):
        m = EnergyMeter(2)                    # both cores SPIN at 1.0 W
        m.set_power_cap(0.0, 1.5)
        assert m.watts == pytest.approx(2.0)  # 2.0 W > 1.5 W cap
        m.set_state(0, CoreState.IDLE, 1.0)   # 1.1 W <= cap from t=1
        assert m.cap_violation_s == pytest.approx(1.0)
        m.finish(3.0)
        assert m.watts == pytest.approx(1.1)
        assert m.cap_violation_s == pytest.approx(1.0)

    def test_lifting_cap_stops_violation(self):
        m = EnergyMeter(2)
        m.set_power_cap(0.0, 1.5)
        m.set_power_cap(1.0, None)            # lift: 1 violating second
        m.finish(5.0)
        assert m.cap_violation_s == pytest.approx(1.0)


class TestMachineWideCap:
    """SimCluster integrates the *summed* draw of all live jobs against
    the cap — per-job meters can only judge their own slice, so two
    individually compliant tenants can still blow the machine budget."""

    def _cluster(self, cap_w, jobs):
        tl = ConditionTimeline([power_cap(0.0, cap_w)])
        cl = SimCluster(MN4, conditions=tl)
        for name, seed, cpus in jobs:
            cl.add_job(SimJobSpec(name=name, graph=wave_graph(seed=seed),
                                  policy="busy", cpus=cpus))
        return cl, cl.run()

    def test_single_tenant_matches_meter(self):
        # with one job owning the whole machine, the machine-wide
        # integral and the job's own meter see the same draw
        cl, reps = self._cluster(20.0, [("app", 0, list(range(48)))])
        assert cl.machine_cap_violation_s > 0.0
        assert cl.machine_cap_violation_s == pytest.approx(
            reps["app"].cap_violation_s, rel=1e-6)

    def test_two_compliant_tenants_blow_the_budget(self):
        # 24 spinning cores each = 24 W per meter, under the 30 W cap —
        # but 48 W together: only the machine-wide integral notices
        cl, reps = self._cluster(
            30.0, [("a", 0, list(range(24))),
                   ("b", 1, list(range(24, 48)))])
        for rep in reps.values():
            assert rep.cap_violation_s == 0.0
        first_done = min(r.makespan for r in reps.values())
        assert cl.machine_cap_violation_s == pytest.approx(
            first_done, rel=1e-6)

    def test_finished_tenants_stop_drawing(self):
        # after the shorter job completes, the survivor's 24 W sits
        # under the cap — the finished job's frozen meter must not
        # keep counting phantom watts against the machine
        cl, reps = self._cluster(
            25.0, [("a", 0, list(range(24))),
                   ("b", 1, list(range(24, 48)))])
        first_done = min(r.makespan for r in reps.values())
        assert cl.machine_cap_violation_s == pytest.approx(
            first_done, rel=1e-6)
        assert cl.machine_cap_violation_s < max(
            r.makespan for r in reps.values())


# ---------------------------------------------------------------------------
# ResourceBroker fail/recover invariants
# ---------------------------------------------------------------------------

def _two_job_broker() -> ResourceBroker:
    b = ResourceBroker()
    b.register_job("A", [0, 1, 2, 3])
    b.register_job("B", [4, 5, 6, 7])
    return b


def _owner_of(cpu: int) -> str:
    return "A" if cpu < 4 else "B"


def _check_invariants(b: ResourceBroker) -> None:
    pooled = [c for c in range(8) if b.holder(c) == ""]
    # pool count conserves: the pool is exactly the holder-less CPUs
    assert b.pool_size() == len(pooled)
    for cpu in range(8):
        if b.is_failed(cpu):
            # a failed core is parked with its owner: never in the
            # pool, never lent, never held by a borrower
            assert b.holder(cpu) == _owner_of(cpu)
    assert not any(b.is_failed(c) for c in pooled)


class TestBrokerFaults:
    def test_fail_pulls_from_pool_and_refuses_lend(self):
        b = _two_job_broker()
        b.lend("A", 0)
        assert b.pool_size() == 1
        b.fail_core(0)
        assert b.pool_size() == 0
        assert b.holder(0) == "A"
        # dead silicon cannot be lent or granted
        b.lend("A", 0)
        assert b.pool_size() == 0
        assert b.acquire("B", 4) == []
        _check_invariants(b)

    def test_fail_borrowed_core_reports_holder(self):
        b = _two_job_broker()
        b.lend("A", 1)
        assert b.acquire("B", 1) == [1]
        assert b.fail_core(1) == "B"       # B must evict its worker
        assert b.holder(1) == "A"
        _check_invariants(b)

    def test_recover_rejoins_owner_directly(self):
        b = _two_job_broker()
        b.fail_core(2)
        assert b.recover_core(2) == "A"
        assert not b.is_failed(2)
        assert b.holder(2) == "A"
        assert b.pool_size() == 0          # never resurfaces via the pool
        b.lend("A", 2)                     # lendable again after recovery
        assert b.pool_size() == 1
        _check_invariants(b)

    @given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 7)),
                    max_size=80))
    @settings(max_examples=200, deadline=None)
    def test_invariants_under_random_interleavings(self, ops):
        b = _two_job_broker()
        for op, cpu in ops:
            owner = _owner_of(cpu)
            other = "B" if owner == "A" else "A"
            if op == 0:                       # current holder lends
                h = b.holder(cpu)
                if h:
                    b.lend(h, cpu)
            elif op == 1:                     # the other job borrows
                for got in b.acquire(other, 1):
                    assert not b.is_failed(got)
            elif op == 2:
                b.reclaim(owner)
            elif op == 3:
                if not b.is_failed(cpu):
                    b.fail_core(cpu)
            elif op == 4:
                if b.is_failed(cpu):
                    b.recover_core(cpu)
            else:                             # borrower hands it back
                h = b.holder(cpu)
                if h and h != owner and not b.is_failed(cpu):
                    b.return_cpu(h, cpu)
            _check_invariants(b)


# ---------------------------------------------------------------------------
# Perturbed sim runs: behaviour
# ---------------------------------------------------------------------------

class TestPerturbedRuns:
    def test_core_fail_requeues_and_completes(self):
        g = wave_graph()
        n_tasks = len(g.tasks)
        spec = GovernorSpec(resources=8, policy="busy", monitoring=True)
        ex = SimExecutor(MN4, spec=spec,
                         conditions=ConditionTimeline(
                             [core_fail(0.0005, 2)]))
        r = ex.run(g)
        # the in-flight task on core 2 was re-queued, nothing was lost
        assert r.tasks_completed == n_tasks

    def test_straggler_dilates_makespan(self):
        spec = GovernorSpec(resources=8, policy="busy", monitoring=True)
        base = SimExecutor(MN4, spec=spec).run(wave_graph()).makespan
        slow = SimExecutor(
            MN4, spec=spec,
            conditions=ConditionTimeline([straggler(0.0, 0, 8.0)]),
        ).run(wave_graph()).makespan
        assert slow > base

    def test_power_cap_violation_surfaces_in_report(self):
        spec = GovernorSpec(resources=8, policy="busy", monitoring=True)
        r = SimExecutor(
            MN4, spec=spec,
            conditions=ConditionTimeline([power_cap(0.0, 1.0)]),
        ).run(wave_graph())
        # busy keeps 8 cores hot against a 1 W budget: violation time
        # is essentially the whole run
        assert r.cap_violation_s > 0.0
        assert r.cap_violation_s == pytest.approx(r.makespan, rel=0.2)

    def test_thermal_throttle_slows_typed_machine(self):
        spec = GovernorSpec(resources=24, policy="busy", monitoring=True,
                            topology=HYBRID_PE.topology())
        base = SimExecutor(HYBRID_PE, spec=spec) \
            .run(wave_graph(width=24)).makespan
        hot = SimExecutor(
            HYBRID_PE, spec=spec,
            conditions=ConditionTimeline(
                [thermal_throttle(0.0, "P", 0.5)]),
        ).run(wave_graph(width=24)).makespan
        assert hot > base


# ---------------------------------------------------------------------------
# Perturbed trace replay round trips
# ---------------------------------------------------------------------------

MACHINES = [(MN4, 8, "mn4"), (HYBRID_PE, 24, "hybrid")]
POLICIES = ["busy", "idle", "hybrid", "prediction", "hetero-prediction"]


def _spec(machine, n, policy):
    return GovernorSpec(
        resources=n, policy=policy, monitoring=True,
        topology=machine.topology() if machine.core_types else None)


def _record_run(machine, n, policy, conditions):
    task_mod._ids = itertools.count()
    ex = SimExecutor(machine, spec=_spec(machine, n, policy),
                     conditions=conditions)
    rec = TraceRecorder(bus=ex.bus)
    report = ex.run(wave_graph())
    return rec, report


def _record_replay(rec, spec):
    task_mod._ids = itertools.count()
    bus = EventBus()
    rec2 = TraceRecorder(bus=bus)
    report = TraceReplayer(rec).replay(spec, bus=bus)
    return rec2, report


class TestPerturbedReplay:
    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("machine,n",
                             [(m, n) for m, n, _ in MACHINES],
                             ids=[i for _, _, i in MACHINES])
    def test_replay_of_replay_is_byte_exact(self, machine, n, policy):
        spec = _spec(machine, n, policy)
        rec1, r1 = _record_run(machine, n, policy, perturbed_timeline())

        # the recorded PERTURBATION events reconstruct the timeline —
        # exactly the prefix that fired before the run completed
        tl = TraceReplayer(rec1).conditions()
        assert tl is not None
        scheduled = perturbed_timeline().to_dicts()
        assert len(tl) >= 3
        assert tl.to_dicts() == scheduled[:len(tl)]

        # first replay: neutral machine, neutralized conditions
        rec2, r2 = _record_replay(rec1, spec)
        assert r2.tasks_completed == r1.tasks_completed
        # replays re-record the neutralized form of the recorded prefix
        tl2 = TraceReplayer(rec2).conditions()
        assert tl2 is not None
        assert tl2.to_dicts() == tl.neutralized().to_dicts()[:len(tl2)]

        # replay-of-replay is a fixed point: byte-identical trace,
        # bit-identical report
        rec3, r3 = _record_replay(rec2, spec)
        assert trace_bytes(rec3) == trace_bytes(rec2)
        assert repr(r3) == repr(r2)

    def test_unperturbed_trace_has_no_conditions(self):
        rec, _ = _record_run(MN4, 8, "busy", None)
        assert TraceReplayer(rec).conditions() is None


# ---------------------------------------------------------------------------
# Empty timeline = degenerate case
# ---------------------------------------------------------------------------

class TestEmptyTimelineParity:
    @pytest.mark.parametrize("policy", ["busy", "prediction"])
    def test_empty_timeline_byte_identical_to_none(self, policy):
        rec_none, r_none = _record_run(MN4, 8, policy, None)
        rec_empty, r_empty = _record_run(MN4, 8, policy,
                                         ConditionTimeline())
        assert trace_bytes(rec_empty) == trace_bytes(rec_none)
        assert repr(r_empty) == repr(r_none)
        assert r_empty.cap_violation_s == 0.0
