"""TaskGraph token dependences (OmpSs-2 ``in``/``out`` semantics):
read-after-write, write-after-read, write-after-write, and tasks that
both read and write one token."""

from repro.runtime import Task, TaskGraph


def test_read_after_write():
    g = TaskGraph()
    w = g.add(Task("w"), out=["x"])
    r1 = g.add(Task("r1"), in_=["x"])
    r2 = g.add(Task("r2"), in_=["x"])
    assert w.deps == []
    assert r1.deps == [w]
    assert r2.deps == [w]


def test_write_after_read_readers_become_deps():
    g = TaskGraph()
    w1 = g.add(Task("w1"), out=["x"])
    r1 = g.add(Task("r1"), in_=["x"])
    r2 = g.add(Task("r2"), in_=["x"])
    w2 = g.add(Task("w2"), out=["x"])
    # WAR: the next writer waits for every reader since the last write
    # (and, transitively safe, the previous writer too).
    assert r1 in w2.deps and r2 in w2.deps
    # a reader after the new write depends on w2 only
    r3 = g.add(Task("r3"), in_=["x"])
    assert r3.deps == [w2]


def test_write_after_write_chain():
    g = TaskGraph()
    w1 = g.add(Task("w1"), out=["x"])
    w2 = g.add(Task("w2"), out=["x"])
    w3 = g.add(Task("w3"), out=["x"])
    assert w2.deps == [w1]
    assert w3.deps == [w2]          # chain, not fan-in to w1


def test_task_reads_and_writes_same_token():
    g = TaskGraph()
    w = g.add(Task("w"), out=["x"])
    rw = g.add(Task("rw"), in_=["x"], out=["x"])
    # depends on the last writer exactly once, never on itself
    assert rw.deps == [w]
    assert rw not in rw.deps
    # a later reader sees rw as the last writer
    r = g.add(Task("r"), in_=["x"])
    assert r.deps == [rw]
    # and a later writer waits on rw (the reader list was reset)
    w2 = g.add(Task("w2"), out=["x"])
    assert r in w2.deps and rw in w2.deps and w not in w2.deps


def test_independent_tokens_do_not_interfere():
    g = TaskGraph()
    wx = g.add(Task("wx"), out=["x"])
    wy = g.add(Task("wy"), out=["y"])
    rxy = g.add(Task("rxy"), in_=["x", "y"])
    assert wy.deps == []
    assert set(rxy.deps) == {wx, wy}


def test_token_deps_execute_in_order():
    """End-to-end: the token-derived DAG serializes a RAW/WAR/WAW mix."""
    from repro.runtime import MN4, SimExecutor

    g = TaskGraph()
    g.add(Task("w1", service_time=1e-5), out=["x"])
    g.add(Task("r1", service_time=1e-5), in_=["x"])
    g.add(Task("rw", service_time=1e-5), in_=["x"], out=["x"])
    g.add(Task("r2", service_time=1e-5), in_=["x"])
    rep = SimExecutor(MN4, policy="busy", n_cpus=4).run(g)
    # fully serialized by the token chain: makespan ~ 4 tasks end to end
    assert rep.makespan >= 4 * 1e-5
