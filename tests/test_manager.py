"""WorkerManager mechanics: the unified poll/reevaluate transition path
and the energy-timeline close on borrowed-CPU removal."""

from repro.core.energy import CoreState, EnergyMeter
from repro.core.manager import WorkerManager, WorkerState
from repro.core.monitoring import TaskMonitor
from repro.core.policies import BusyPolicy, PollDecision, PredictionPolicy
from repro.core.prediction import CPUPredictor, PredictionConfig
from repro.core.sharing import LeWIPolicy


class _Clock:
    def __init__(self) -> None:
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _prediction_policy(delta: int, n: int = 8) -> PredictionPolicy:
    m = TaskMonitor(min_samples=1)
    for i in range(3):
        m.on_task_ready(i, "t", 1.0)
        m.on_task_execute(i, "t", 1.0)
        m.on_task_completed(i, "t", 1.0, 50e-6)
    for i in range(delta):
        m.on_task_ready(100 + i, "t", 1.0)
    pred = CPUPredictor(m, n_cpus=n, config=PredictionConfig(
        rate_s=50e-6, min_samples=1))
    pred.tick()
    assert pred.delta == delta
    return PredictionPolicy(pred)


class TestRemoveWorkerEnergy:
    def test_removed_borrowed_core_stops_accruing(self):
        """A reclaimed borrowed CPU must stop burning SPIN power the
        moment it is removed — not at finish()."""
        clock = _Clock()
        energy = EnergyMeter(0)
        mgr = WorkerManager(0, BusyPolicy(), clock=clock, energy=energy,
                            worker_ids=[])
        mgr.add_worker(7)             # borrowed CPU arrives, spinning
        clock.t = 1.0
        mgr.remove_worker(7)          # owner reclaimed it
        clock.t = 5.0
        energy.finish(5.0)
        acc = energy.state_seconds()
        assert acc[CoreState.SPIN] == 1.0     # not 5.0
        assert acc[CoreState.OFF] == 4.0
        assert energy.energy() == 1.0         # spin power only while held

    def test_reborrowed_core_keeps_prior_accounting(self):
        """Borrow → return → borrow again must accumulate across both
        windows (re-registration used to wipe the timeline)."""
        clock = _Clock()
        energy = EnergyMeter(0)
        mgr = WorkerManager(0, BusyPolicy(), clock=clock, energy=energy,
                            worker_ids=[])
        mgr.add_worker(7)
        clock.t = 1.0
        mgr.remove_worker(7)
        clock.t = 3.0
        mgr.add_worker(7)             # same CPU borrowed again
        clock.t = 4.0
        mgr.remove_worker(7)
        energy.finish(5.0)
        acc = energy.state_seconds()
        assert acc[CoreState.SPIN] == 2.0     # both borrow windows
        assert acc[CoreState.OFF] == 3.0
        assert energy.energy() == 2.0

    def test_remove_unknown_worker_is_noop(self):
        clock = _Clock()
        mgr = WorkerManager(2, BusyPolicy(), clock=clock,
                            energy=EnergyMeter(2))
        mgr.remove_worker(99)         # never added: no KeyError, no write
        assert mgr.n_workers == 2


class TestUnifiedTransitionPath:
    def test_reevaluate_lend_resets_spin_counts(self):
        """The LEND branch of reevaluate_spinners used to skip the
        spin-count reset that poll_empty performs."""
        clock = _Clock()
        mgr = WorkerManager(2, LeWIPolicy(), clock=clock)
        mgr._spin_counts[0] = 42      # simulate prior empty polls
        mgr._spin_counts[1] = 17
        parked = mgr.reevaluate_spinners()
        assert sorted(parked) == [0, 1]
        assert mgr.state(0) is WorkerState.LENT
        assert mgr._spin_counts[0] == 0
        assert mgr._spin_counts[1] == 0

    def test_reevaluate_idle_counts_transitions(self):
        clock = _Clock()
        mgr = WorkerManager(4, _prediction_policy(delta=2), clock=clock)
        parked = mgr.reevaluate_spinners()
        # δ=4 spinners against Δ=2: two idle transitions, both counted
        assert len(parked) == 2
        assert mgr.idles == 2
        assert all(mgr._spin_counts[w] == 0 for w in parked)

    def test_poll_and_reevaluate_agree(self):
        """Both paths run the same helper: identical state, counters and
        spin counts for the same decision."""
        clock = _Clock()
        via_poll = WorkerManager(1, LeWIPolicy(), clock=clock)
        via_poll.poll_empty(0, spin_count_override=9)
        via_reeval = WorkerManager(1, LeWIPolicy(), clock=clock)
        via_reeval._spin_counts[0] = 9
        via_reeval.reevaluate_spinners()
        assert via_poll.states() == via_reeval.states()
        assert via_poll._spin_counts == via_reeval._spin_counts
        assert via_poll.idles == via_reeval.idles

    def test_poll_empty_idle_still_counts(self):
        clock = _Clock()
        mgr = WorkerManager(4, _prediction_policy(delta=2), clock=clock)
        assert mgr.poll_empty(0) is PollDecision.IDLE
        assert mgr.idles == 1
        assert mgr.state(0) is WorkerState.IDLE


class TestActiveByType:
    def test_counts_split_per_type(self):
        clock = _Clock()
        mgr = WorkerManager(4, BusyPolicy(), clock=clock,
                            core_type_of=lambda w: "P" if w < 2 else "E")
        mgr.task_started(0)
        assert mgr.active_by_type() == {"P": 2, "E": 2}
        mgr.poll_empty(2)             # busy: stays SPIN, still active
        assert mgr.active_by_type() == {"P": 2, "E": 2}

    def test_empty_without_mapping(self):
        mgr = WorkerManager(2, BusyPolicy(), clock=_Clock())
        assert mgr.active_by_type() == {}
