"""Fast-path parity + throughput pins for the simulator hot-path overhaul.

The sequential scheduler (``threadsafe=False``, the simulator default)
must be *observationally invisible*: same seed ⇒ byte-identical trace
JSONL and bit-identical ``GovernorReport``\\ s against the locked
reference (``threadsafe=True``) for every registered policy.  On top of
that, the committed ``BENCH_simperf.json`` pins the throughput floor —
a future PR that regresses recorded events/sec by more than 30% (in
machine-normalized terms) fails here.
"""

from __future__ import annotations

import gc
import itertools
import json
import sys
import time
from pathlib import Path

import pytest

from repro.core import EventBus, EventKind, RuntimeEvent
from repro.core.governor import policy_entry, registered_policies
from repro.core.sharing import ResourceBroker
from repro.runtime import HYBRID_PE, MN4, MachineModel, SimCluster, SimJobSpec
from repro.runtime.scheduler import Scheduler, _SeqScheduler
from repro.runtime.task import Task, TaskGraph
from repro.trace import TraceRecorder
from repro.workloads.cholesky import build_cholesky

import repro.runtime.task as task_mod

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_simperf.json"

M8 = MachineModel(name="M8", n_cores=8)


def _fresh_graph(p: int = 8, seed: int = 0) -> TaskGraph:
    """Cholesky graph with the global task-id counter reset, so two
    builds produce identical task ids (trace bytes compare equal)."""
    task_mod._ids = itertools.count()
    return build_cholesky("fine", p=p, seed=seed)


def _run_single(policy: str, threadsafe: bool, tmp_path: Path,
                tag: str) -> tuple[dict, Path]:
    machine = HYBRID_PE if policy_entry(policy).needs_topology else M8
    graph = _fresh_graph()
    cluster = SimCluster(machine, threadsafe=threadsafe)
    job = cluster.add_job(SimJobSpec(name="job0", graph=graph,
                                     policy=policy))
    rec = TraceRecorder(job.bus)
    reports = cluster.run()
    path = tmp_path / f"{tag}.jsonl"
    rec.to_jsonl(path)
    rec.detach()
    return reports, path


def _run_sharing(policy: str, threadsafe: bool, tmp_path: Path,
                 tag: str) -> tuple[dict, Path]:
    task_mod._ids = itertools.count()
    g0 = build_cholesky("fine", p=8, seed=0)
    g1 = build_cholesky("fine", p=6, seed=1)
    cluster = SimCluster(M8, broker=ResourceBroker(),
                         threadsafe=threadsafe)
    j0 = cluster.add_job(SimJobSpec(name="a", graph=g0, policy=policy,
                                    cpus=list(range(0, 4))))
    j1 = cluster.add_job(SimJobSpec(name="b", graph=g1, policy=policy,
                                    cpus=list(range(4, 8))))
    rec = TraceRecorder(j0.bus)
    rec.attach(j1.bus)
    reports = cluster.run()
    path = tmp_path / f"{tag}.jsonl"
    rec.to_jsonl(path)
    rec.detach()
    return reports, path


@pytest.mark.parametrize("policy", registered_policies())
def test_fast_path_parity_all_policies(policy, tmp_path):
    """threadsafe on/off ⇒ equal GovernorReports AND byte-identical
    trace JSONL, for every registered policy (sharing policies run as a
    two-job broker cluster — they deadlock without a co-tenant)."""
    runner = (_run_sharing if policy_entry(policy).sharing
              else _run_single)
    rep_fast, trace_fast = runner(policy, False, tmp_path, "fast")
    rep_ref, trace_ref = runner(policy, True, tmp_path, "ref")
    assert rep_fast == rep_ref
    assert trace_fast.read_bytes() == trace_ref.read_bytes()


def test_seq_scheduler_selected_by_flag():
    assert isinstance(Scheduler(threadsafe=False), _SeqScheduler)
    assert not isinstance(Scheduler(), _SeqScheduler)
    assert type(Scheduler()) is Scheduler


class TestSubmitAllBatched:
    """Satellite: ``submit_all`` takes the lock once per batch — and
    stays equivalent to task-by-task ``submit`` on a 10k-task graph."""

    N = 10_000

    def _chain(self) -> list[Task]:
        task_mod._ids = itertools.count()
        tasks = []
        prev = None
        for i in range(self.N):
            t = Task("w", cost=1.0, service_time=1e-6,
                     deps=[prev] if prev is not None and i % 7 == 0
                     else [])
            tasks.append(t)
            prev = t
        return tasks

    @pytest.mark.parametrize("threadsafe", [True, False])
    def test_matches_per_task_submit(self, threadsafe):
        batched = Scheduler(threadsafe=threadsafe)
        n_batched = batched.submit_all(self._chain())
        onebyone = Scheduler(threadsafe=threadsafe)
        n_single = 0
        for t in self._chain():
            n_single += onebyone.submit(t)
        assert n_batched == n_single
        assert batched.pending == onebyone.pending == self.N
        assert batched.ready_count == onebyone.ready_count == n_batched
        # drain both identically
        a = batched.poll()
        b = onebyone.poll()
        assert (a.task_id, a.type_name) == (b.task_id, b.type_name)


class TestQuietBusIsFree:
    """Satellite: one ``interested`` check per event, and publishing on
    a subscriber-free bus is a guaranteed no-alloc no-op."""

    def test_no_subscribers_no_callbacks_no_allocs(self):
        bus = EventBus()
        ev = RuntimeEvent(kind=EventKind.TASK_READY, time=0.0, task_id=1,
                          type_name="t", cost=1.0)
        assert not bus.interested(EventKind.TASK_READY)
        gc.disable()
        try:
            bus.publish(ev)  # warm up any lazy state
            before = sys.getallocatedblocks()
            for _ in range(1000):
                bus.publish(ev)
            delta = sys.getallocatedblocks() - before
        finally:
            gc.enable()
        assert delta <= 2, f"publish allocated {delta} blocks"

    def test_kind_filtered_subscriber_not_invoked_for_other_kinds(self):
        bus = EventBus()
        calls = []
        bus.subscribe(calls.append, kinds=[EventKind.PREDICTION])
        assert bus.interested(EventKind.PREDICTION)
        assert not bus.interested(EventKind.TASK_READY)
        for _ in range(10):
            bus.publish(RuntimeEvent(kind=EventKind.TASK_READY, time=0.0,
                                     task_id=1, type_name="t", cost=1.0))
        assert calls == []
        bus.publish(RuntimeEvent(kind=EventKind.PREDICTION, time=0.0,
                                 data={"delta": 1}))
        assert len(calls) == 1

    def test_interest_union_tracks_unsubscribe(self):
        bus = EventBus()
        h = bus.subscribe(lambda e: None, kinds=[EventKind.TASK_READY])
        assert bus.interested(EventKind.TASK_READY)
        bus.unsubscribe(h)
        assert not bus.interested(EventKind.TASK_READY)
        # all-kinds subscriber makes every kind interesting
        bus.subscribe(lambda e: None)
        assert bus.interested(EventKind.WORKER_STATE)

    def test_monitor_subscribe_after_scheduler_no_double_count(self):
        """A monitor subscription on the scheduler's bus made AFTER
        construction must not double-count on top of the direct drive
        (the old monitor-as-subscriber wiring was idempotent here)."""
        from repro.core import TaskMonitor

        bus = EventBus()
        mon = TaskMonitor()
        sched = Scheduler(mon, bus=bus)
        mon.subscribe(bus)              # late wiring of the same pair
        sched.submit(Task("a", cost=1.0))
        assert mon.live_instances() == 1

    def test_scheduler_builds_no_events_without_subscribers(self):
        """The monitor is driven directly — a monitored-but-untraced
        run never constructs a RuntimeEvent."""
        built = []
        orig_publish = EventBus.publish

        def counting(self, event):
            built.append(event)
            return orig_publish(self, event)

        EventBus.publish = counting
        try:
            graph = _fresh_graph(p=6)
            cluster = SimCluster(M8)
            cluster.add_job(SimJobSpec(name="job0", graph=graph,
                                       policy="prediction"))
            reports = cluster.run()
        finally:
            EventBus.publish = orig_publish
        assert reports["job0"].tasks_completed == len(graph.tasks)
        assert built == []


class TestThroughputPins:
    """The committed BENCH_simperf.json is the contract."""

    @pytest.fixture(autouse=True)
    def _no_witness(self):
        # Measurement-only tests must not pay the suite-wide lock-order
        # witness's per-acquisition bookkeeping (calibrate() has no lock
        # traffic, so normalization would not cancel it out).
        from repro.analysis import witness_paused
        with witness_paused():
            yield

    @pytest.fixture(scope="class")
    def bench(self):
        assert BENCH_PATH.exists(), "BENCH_simperf.json not committed"
        rows = json.loads(BENCH_PATH.read_text())["rows"]
        return {(r["scenario"], r["mode"]): r for r in rows}

    def test_committed_speedup_at_least_5x_closed(self, bench):
        """Acceptance pin: ≥5× events/sec vs the pre-change baseline
        row on the 100k-task closed scenario."""
        base = bench[("closed-cholesky-100k/prediction", "baseline")]
        fast = bench[("closed-cholesky-100k/prediction", "fast")]
        assert fast["events_per_sec"] >= 5.0 * base["events_per_sec"]

    def test_every_scenario_improved(self, bench):
        for (scenario, mode), row in bench.items():
            if mode != "fast":
                continue
            base = bench[(scenario, "baseline")]
            assert row["events_per_sec"] > 2.0 * base["events_per_sec"], \
                f"{scenario} regressed vs recorded baseline"

    @pytest.mark.slow
    def test_throughput_floor_renormalized(self, bench):
        """Re-run the gate scenario and compare *normalized* throughput
        (events/sec × calibration loop seconds — machine-speed
        invariant) against the committed row: >30% regression fails."""
        from benchmarks.bench_simperf import calibrate

        committed = bench[("closed-cholesky-100k/prediction", "fast")]
        calib_now = min(calibrate() for _ in range(3))
        eps_now = 0.0
        for _ in range(3):  # best-of-3, like the committed measurement
            task_mod._ids = itertools.count()
            graph = build_cholesky("fine", p=84, seed=0)
            cluster = SimCluster(MN4)
            cluster.add_job(SimJobSpec(name="job0", graph=graph,
                                       policy="prediction"))
            t0 = time.process_time()
            cluster.run()
            cpu = time.process_time() - t0
            eps_now = max(eps_now, cluster.events_processed / cpu)
        norm_now = eps_now * calib_now
        norm_committed = (committed["events_per_sec"]
                          * committed["calibration"])
        assert norm_now >= 0.7 * norm_committed, (
            f"simulator throughput regressed: {eps_now:.0f} ev/s "
            f"(normalized {norm_now:.0f}) vs committed "
            f"{committed['events_per_sec']:.0f} ev/s "
            f"(normalized {norm_committed:.0f})")
