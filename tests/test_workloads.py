"""Workload graph builders: structure, counts, dependency sanity."""

import pytest

from repro.runtime import SimExecutor, MN4
from repro.workloads import WORKLOADS, build_cholesky
from repro.workloads.cholesky import cholesky_task_count


def test_cholesky_coarse_count_matches_paper():
    g = build_cholesky(grain="coarse")
    # paper Table 2 reports ~600 instances for coarse Cholesky
    assert 500 <= len(g.tasks) <= 700
    assert len(g.tasks) == cholesky_task_count(14)


def test_cholesky_kernel_mix():
    g = build_cholesky(grain="coarse", p=6)
    kinds = {}
    for t in g.tasks:
        kinds[t.type_name] = kinds.get(t.type_name, 0) + 1
    assert kinds["potrf"] == 6
    assert kinds["trsm"] == 15
    assert kinds["syrk"] == 15
    assert kinds["gemm"] == 20


def test_cholesky_first_task_is_potrf_root():
    g = build_cholesky(grain="coarse", p=4)
    roots = [t for t in g.tasks if not t.deps]
    assert len(roots) == 1 and roots[0].type_name == "potrf"


@pytest.mark.parametrize("name", list(WORKLOADS))
def test_graphs_acyclic_and_runnable(name):
    kw = {}
    if name.startswith("cholesky"):
        kw["p"] = 6
    elif name == "hpccg":
        kw = dict(iterations=3, blocks=8)
    elif name == "gauss-seidel":
        kw = dict(steps=3, bi=4, bj=4)
    elif name.startswith("multisaxpy"):
        kw = dict(generations=3, blocks=16)
    else:
        kw = dict(rounds=2, blocks=16)
    g = WORKLOADS[name](seed=0, **kw)
    rep = SimExecutor(MN4, policy="busy").run(g)   # deadlock ⇒ raises
    assert rep.makespan > 0


def test_instance_counts_scale_like_paper():
    """Default scales approximate paper Table 2 instance counts."""
    assert len(WORKLOADS["hpccg"]()) >= 10_000
    assert len(WORKLOADS["gauss-seidel"]()) >= 25_000
    assert len(WORKLOADS["multisaxpy-fine"]()) >= 100_000
    assert len(WORKLOADS["multisaxpy-coarse"]()) >= 20_000


def test_costs_positive_and_proportional():
    g = build_cholesky(grain="coarse", p=4, tile=1024)
    by_kind = {t.type_name: t.cost for t in g.tasks}
    assert by_kind["gemm"] == pytest.approx(2 * by_kind["trsm"])
    assert all(t.cost > 0 for t in g.tasks)
    assert all(t.service_time and t.service_time > 0 for t in g.tasks)
