"""Optimizer, schedules, clipping, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_warmup, global_norm)
from repro.train.compression import (compress_grads, dequantize_int8,
                                     init_error_feedback, quantize_int8)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum(p["x"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-4


def test_adamw_bf16_state_halves_memory():
    params = {"w": jnp.zeros((64, 64), jnp.bfloat16)}
    s32 = adamw_init(params, AdamWConfig(state_dtype="float32"))
    s16 = adamw_init(params, AdamWConfig(state_dtype="bfloat16"))
    assert s32["mu"]["w"].dtype == jnp.float32
    assert s16["mu"]["w"].dtype == jnp.bfloat16


def test_cosine_warmup_shape():
    assert float(cosine_warmup(0, warmup=10, total=100)) == 0.0
    assert float(cosine_warmup(10, warmup=10, total=100)) \
        == pytest.approx(1.0)
    assert float(cosine_warmup(100, warmup=10, total=100)) \
        == pytest.approx(0.1)
    # monotone decay after warmup
    vals = [float(cosine_warmup(s, warmup=10, total=100))
            for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


@given(st.lists(st.floats(-100, 100), min_size=1, max_size=32),
       st.floats(0.1, 10.0))
@settings(max_examples=100, deadline=None)
def test_clip_property(vals, max_norm):
    tree = {"g": jnp.asarray(vals, jnp.float32)}
    clipped, norm = clip_by_global_norm(tree, max_norm)
    new_norm = float(global_norm(clipped))
    assert new_norm <= max_norm * 1.01
    if float(norm) <= max_norm:     # no-op when under the cap
        np.testing.assert_allclose(np.asarray(clipped["g"]),
                                   np.asarray(tree["g"]), rtol=1e-5)


class TestCompression:
    def test_quantize_roundtrip_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, s) - x))
        assert err.max() <= float(s) * 0.5 + 1e-6

    def test_error_feedback_accumulates_residual(self):
        g = {"w": jnp.full((16,), 0.001)}
        ef = init_error_feedback(g)
        total = jnp.zeros((16,))
        for _ in range(50):
            deq, ef = compress_grads(g, ef)
            total = total + deq["w"]
        # With EF, the long-run average equals the true gradient.
        np.testing.assert_allclose(np.asarray(total) / 50, 0.001,
                                   rtol=0.05)

    def test_train_step_with_compression_runs(self):
        from repro.configs import get_smoke_config
        from repro.models import init_params
        from repro.train.steps import StepConfig, make_train_step
        cfg = get_smoke_config("llama3.2-1b")
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = AdamWConfig(lr=1e-3)
        opt_state = adamw_init(params, opt)
        opt_state["ef"] = init_error_feedback(params)
        fn = jax.jit(make_train_step(cfg, None, opt,
                                     StepConfig(compress=True, warmup=1)))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 2, 16), 0,
                                  cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        losses = []
        for i in range(6):
            params, opt_state, m = fn(params, opt_state,
                                      jnp.asarray(i, jnp.int32), batch)
            losses.append(float(m["loss"]))
        assert "ef" in opt_state
        assert losses[-1] < losses[0]
