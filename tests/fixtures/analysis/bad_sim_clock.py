"""Analyzer fixture: determinism hazards in a "sim" module (the stem
puts it in the determinism lint's scope).  Never imported — parsed by
``repro.analysis`` in tests."""

import random
import time


def jitter() -> float:
    # wall clock + global PRNG: two ways to make a replay diverge
    return time.time() + random.random()


def order(xs: list[int]) -> list[int]:
    return list(set(xs))  # hash-order leak


def walk(xs: set[int]) -> int:
    total = 0
    for x in xs | {0}:  # iterating set algebra: hash-order leak
        total += x
    return total
