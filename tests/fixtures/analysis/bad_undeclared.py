"""Analyzer fixture: lock owner with no declared discipline, plus a
dead lock and a threading primitive behind a @lock_free class.  Never
imported — parsed by ``repro.analysis`` in tests."""

import threading

from repro.analysis import guarded_by, lock_free

LOCK_ORDER = ("Declared",)


class Quiet:
    """Owns a lock, declares nothing: undeclared-lock."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.n = 0

    def bump(self) -> None:
        with self._lock:
            self.n += 1


@guarded_by("x")
class Declared:
    """Declares a lock it never acquires: unused-lock."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.x = 0


@lock_free
class Fast:
    """@lock_free but builds a primitive in a helper: lock-free."""

    def work(self) -> None:
        self._setup()

    def _setup(self) -> None:
        self._gate = threading.Event()
