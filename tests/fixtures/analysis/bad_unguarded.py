"""Analyzer fixture: guarded field mutated outside its declared lock.
Never imported — parsed by ``repro.analysis`` in tests."""

import threading

from repro.analysis import guarded_by

LOCK_ORDER = ("Counter",)


@guarded_by("total")
class Counter:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.total = 0

    def add(self, n: int) -> None:
        with self._lock:
            self.total += n

    def reset(self) -> None:
        self.total = 0  # race: no lock held

    def drain(self) -> int:
        with self._lock:
            n = self.total
        self.total = 0  # race: lock released before the write
        return n
