"""Analyzer fixture: seeded lock-order inversion (the PR 4 deadlock
shape).  Never imported — parsed by ``repro.analysis`` in tests."""

import threading

from repro.analysis import guarded_by

LOCK_ORDER = ("Outer", "Inner")


@guarded_by("items")
class Outer:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.items: list[int] = []

    def add(self, x: int) -> None:
        with self._lock:
            self.items.append(x)


@guarded_by("count")
class Inner:
    def __init__(self, outer: Outer) -> None:
        self._lock = threading.Lock()
        self.outer = outer
        self.count = 0

    def poke(self) -> None:
        # Holding Inner (rank 1) while calling into Outer.add, which
        # acquires Outer (rank 0): declared-order inversion.
        with self._lock:
            self.count += 1
            self.outer.add(self.count)

    def nested(self) -> None:
        # Same inversion, lexically nested.
        with self._lock:
            with self.outer._lock:
                self.outer.items.clear()
