"""Concurrency & determinism analyzer: CLI exit pins, rule coverage,
suppression enforcement, runtime witness, and the _SeqScheduler
owning-thread contract."""

import json
import threading
from pathlib import Path

import pytest

from repro.analysis import (LOCK_ORDER, install_witness, lock_free,
                            registered_classes, witness_paused)
from repro.analysis import annotations as _annotations
from repro.analysis.__main__ import determinism_scope, main
from repro.core.events import EventBus
from repro.core.monitoring import TaskMonitor
from repro.runtime.scheduler import Scheduler
from repro.runtime.task import Task
from repro.trace.recorder import TraceRecorder

FIXTURES = Path(__file__).resolve().parent / "fixtures" / "analysis"
REPRO_PKG = Path(__file__).resolve().parent.parent / "src" / "repro"


# ---------------------------------------------------------------------------
# CLI exit-status pins (the acceptance contract)
# ---------------------------------------------------------------------------


class TestCLI:
    def test_repo_is_clean(self, capsys):
        """Self-hosting: the analyzer exits 0 on the whole package."""
        assert main([]) == 0
        assert "clean: 0 findings" in capsys.readouterr().out

    def test_lock_order_inversion_fixture_fails(self, capsys):
        assert main([str(FIXTURES / "bad_lock_order.py")]) == 1
        out = capsys.readouterr().out
        assert "[lock-order]" in out
        # both shapes: one-hop call into a locking method AND lexical
        # with-nesting
        assert out.count("[lock-order]") == 2

    def test_unguarded_mutation_fixture_fails(self, capsys):
        assert main([str(FIXTURES / "bad_unguarded.py")]) == 1
        out = capsys.readouterr().out
        assert out.count("[unguarded-field]") == 2

    def test_wall_clock_in_sim_module_fixture_fails(self, capsys):
        assert main([str(FIXTURES / "bad_sim_clock.py")]) == 1
        out = capsys.readouterr().out
        assert "[wall-clock]" in out
        assert "[unseeded-random]" in out
        assert "[set-iteration]" in out

    def test_undeclared_unused_and_lock_free_rules(self, capsys):
        assert main([str(FIXTURES / "bad_undeclared.py")]) == 1
        out = capsys.readouterr().out
        assert "[undeclared-lock]" in out
        assert "[unused-lock]" in out
        assert "[lock-free]" in out

    def test_json_output(self, capsys):
        assert main([str(FIXTURES / "bad_unguarded.py"), "--json"]) == 1
        payload = json.loads(capsys.readouterr().out)
        assert payload["files_analyzed"] == 1
        rules = {f["rule"] for f in payload["findings"]}
        assert rules == {"unguarded-field"}
        f = payload["findings"][0]
        assert set(f) == {"rule", "path", "line", "message"}

    def test_directory_target_recurses(self, capsys):
        assert main([str(FIXTURES)]) == 1
        out = capsys.readouterr().out
        assert "4 file(s) analyzed" in out


class TestDeterminismScope:
    def test_runtime_in_trace_in_executor_out(self):
        assert determinism_scope(Path("src/repro/runtime/sim.py"))
        assert determinism_scope(Path("src/repro/trace/replay.py"))
        assert determinism_scope(Path("src/repro/workloads/arrivals.py"))
        assert not determinism_scope(
            Path("src/repro/runtime/thread_executor.py"))
        assert not determinism_scope(Path("src/repro/core/governor.py"))
        # the machine-conditions timeline feeds the simulator/trace
        assert determinism_scope(Path("src/repro/core/conditions.py"))

    def test_sim_stem_matches_anywhere(self, tmp_path):
        assert determinism_scope(tmp_path / "my_simulator.py")
        assert determinism_scope(tmp_path / "replay_check.py")
        assert not determinism_scope(tmp_path / "model.py")


# ---------------------------------------------------------------------------
# suppression convention
# ---------------------------------------------------------------------------


class TestSuppressions:
    def test_justified_suppression_silences(self, tmp_path, capsys):
        f = tmp_path / "quiet_sim.py"
        f.write_text(
            "import time\n"
            "def now():\n"
            "    return time.time()"
            "  # analysis: ignore[wall-clock] -- live frontend epoch\n")
        assert main([str(f)]) == 0

    def test_unjustified_suppression_is_a_finding(self, tmp_path, capsys):
        f = tmp_path / "quiet_sim.py"
        f.write_text(
            "import time\n"
            "def now():\n"
            "    return time.time()  # analysis: ignore[wall-clock]\n")
        assert main([str(f)]) == 1
        out = capsys.readouterr().out
        assert "[bad-suppression]" in out

    def test_suppression_is_rule_specific(self, tmp_path, capsys):
        f = tmp_path / "quiet_sim.py"
        f.write_text(
            "import time\n"
            "def now():\n"
            "    return time.time()"
            "  # analysis: ignore[set-iteration] -- wrong rule\n")
        assert main([str(f)]) == 1
        assert "[wall-clock]" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# annotation conventions
# ---------------------------------------------------------------------------


class TestAnnotations:
    def test_all_eight_lock_owners_registered(self):
        reg = registered_classes()
        for name in LOCK_ORDER:
            assert name in reg, f"{name} lost its annotation"
        assert "_SeqScheduler" in reg
        assert "CPUPredictor" in reg

    def test_guarded_by_requires_lock_order_entry(self):
        from repro.analysis import guarded_by

        with pytest.raises(ValueError, match="LOCK_ORDER"):
            @guarded_by("_x")
            class NotRanked:  # noqa: F811
                pass

    def test_declared_metadata(self):
        assert Scheduler.__lock_attr__ == "_lock"
        assert "_ready" in Scheduler.__guarded_fields__
        assert (Scheduler.__lock_rank__
                < TaskMonitor.__lock_rank__
                < TraceRecorder.__lock_rank__
                < EventBus.__lock_rank__)


# ---------------------------------------------------------------------------
# runtime witness
# ---------------------------------------------------------------------------


@pytest.fixture
def fresh_witness():
    """A private witness for the duration of one test; the suite-wide
    session witness is restored afterwards so deliberately-seeded
    violations never leak into the session teardown check."""
    saved = _annotations._witness
    w = install_witness(strict=False)
    yield w
    _annotations._set_witness(saved)


class TestWitness:
    def test_records_declared_order_nesting(self, fresh_witness):
        mon = TaskMonitor()
        s = Scheduler(monitor=mon)
        t = Task(cost=1.0, type_name="a")
        s.submit(t)
        s.complete(s.poll(0), 0.5, 0)
        assert ("Scheduler", "TaskMonitor") in fresh_witness.observed
        assert fresh_witness.violations == []
        assert fresh_witness.check_declared() == []

    def test_flags_inverted_acquisition(self, fresh_witness):
        rec = TraceRecorder()   # rank after Scheduler
        s = Scheduler()
        with rec._lock:
            with s._lock:
                pass
        assert len(fresh_witness.violations) == 1
        assert "inversion" in fresh_witness.violations[0]
        problems = fresh_witness.check_declared()
        assert problems and "inverts declared LOCK_ORDER" in problems[0]

    def test_strict_mode_raises_at_the_inversion(self):
        saved = _annotations._witness
        try:
            install_witness(strict=True)
            rec = TraceRecorder()
            s = Scheduler()
            with pytest.raises(RuntimeError, match="inversion"):
                with rec._lock:
                    with s._lock:
                        pass
        finally:
            _annotations._set_witness(saved)

    def test_same_lock_reacquisition_flagged(self, fresh_witness):
        b1, b2 = EventBus(), EventBus()
        with b1._lock:
            with b2._lock:  # same rank: ambiguous order between peers
                pass
        assert fresh_witness.violations

    def test_witness_paused_builds_plain_locks(self, fresh_witness):
        with witness_paused():
            s = Scheduler()
        assert type(s._lock) is type(threading.Lock())
        s2 = Scheduler()  # instrumentation resumes after the pause
        assert type(s2._lock) is not type(threading.Lock())

    def test_multithreaded_use_stays_clean(self, fresh_witness):
        s = Scheduler(monitor=TaskMonitor())
        tasks = [Task(cost=1.0, type_name="t") for _ in range(200)]
        s.submit_all(tasks)

        def drain():
            while True:
                task = s.poll(0)
                if task is None:
                    return
                s.complete(task, 0.1, 0)

        threads = [threading.Thread(target=drain) for _ in range(4)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert s.pending == 0
        assert fresh_witness.violations == []
        assert fresh_witness.check_declared() == []


# ---------------------------------------------------------------------------
# _SeqScheduler owning-thread contract (satellite)
# ---------------------------------------------------------------------------


class TestSeqSchedulerOwnership:
    def test_single_thread_use_is_fine(self):
        s = Scheduler(threadsafe=False)
        t = Task(cost=1.0, type_name="a")
        assert s.submit(t)
        assert s.poll(0) is t
        s.complete(t, 0.1, 0)
        assert s.drained()

    def test_second_thread_raises(self):
        s = Scheduler(threadsafe=False)
        s.submit(Task(cost=1.0, type_name="a"))  # binds the owner
        caught = []

        def misuse():
            try:
                s.poll(0)
            except RuntimeError as e:
                caught.append(e)

        th = threading.Thread(target=misuse)
        th.start()
        th.join()
        assert len(caught) == 1
        assert "single-threaded by contract" in str(caught[0])

    def test_lock_free_annotation_present(self):
        s = Scheduler(threadsafe=False)
        assert type(s).__lock_free__ is True
        assert lock_free is not None  # re-exported for annotating
