"""Event bus: pub/sub semantics, serialization, monitor-as-subscriber."""

import pytest

from repro.core import EventBus, EventKind, RuntimeEvent, TaskMonitor
from repro.runtime import Scheduler, Task


def ev(kind, **kw):
    kw.setdefault("time", 0.0)
    return RuntimeEvent(kind=kind, **kw)


class TestEventBus:
    def test_publish_reaches_subscriber(self):
        bus = EventBus()
        got = []
        bus.subscribe(got.append)
        e = ev(EventKind.TASK_READY, task_id=1, type_name="t", cost=1.0)
        bus.publish(e)
        assert got == [e]

    def test_kind_filter(self):
        bus = EventBus()
        got = []
        bus.subscribe(got.append, kinds=[EventKind.PREDICTION])
        bus.publish(ev(EventKind.TASK_READY, task_id=1, type_name="t",
                       cost=1.0))
        bus.publish(ev(EventKind.PREDICTION, data={"delta": 3}))
        assert [e.kind for e in got] == [EventKind.PREDICTION]

    def test_unsubscribe(self):
        bus = EventBus()
        got = []
        h = bus.subscribe(got.append)
        bus.publish(ev(EventKind.PREDICTION))
        bus.unsubscribe(h)
        bus.publish(ev(EventKind.PREDICTION))
        assert len(got) == 1
        assert bus.n_subscribers == 0

    def test_subscription_order_preserved(self):
        bus = EventBus()
        order = []
        bus.subscribe(lambda e: order.append("a"))
        bus.subscribe(lambda e: order.append("b"))
        bus.publish(ev(EventKind.PREDICTION))
        assert order == ["a", "b"]

    def test_double_subscribe_delivers_once(self):
        """Regression: subscribing the same handler twice silently
        doubled every delivery (e.g. TaskMonitor costs); subscribe is
        now idempotent per handler."""
        bus = EventBus()
        got = []
        bus.subscribe(got.append)
        bus.subscribe(got.append)
        assert bus.n_subscribers == 1
        bus.publish(ev(EventKind.PREDICTION))
        assert len(got) == 1

    def test_resubscribe_updates_kind_filter(self):
        bus = EventBus()
        got = []
        bus.subscribe(got.append, kinds=[EventKind.PREDICTION])
        bus.subscribe(got.append, kinds=[EventKind.TASK_READY])
        bus.publish(ev(EventKind.PREDICTION))
        bus.publish(ev(EventKind.TASK_READY, task_id=1, type_name="t",
                       cost=1.0))
        assert [e.kind for e in got] == [EventKind.TASK_READY]

    def test_subscribe_unsubscribe_symmetric(self):
        """One subscribe ⟺ one unsubscribe, including for bound methods
        (fresh objects on each attribute access, equal by value)."""
        bus = EventBus()

        class Sink:
            def __init__(self):
                self.got = []

            def on_event(self, e):
                self.got.append(e)

        sink = Sink()
        bus.subscribe(sink.on_event)
        bus.subscribe(sink.on_event)          # idempotent
        assert bus.n_subscribers == 1
        bus.unsubscribe(sink.on_event)        # removes exactly the one
        assert bus.n_subscribers == 0
        bus.publish(ev(EventKind.PREDICTION))
        assert sink.got == []

    def test_app_namespace_stamped_on_publish(self):
        bus = EventBus(app="gauss")
        got = []
        bus.subscribe(got.append)
        bus.publish(ev(EventKind.PREDICTION))
        assert got[0].app == "gauss"
        # an event that already carries a namespace keeps it
        bus.publish(ev(EventKind.PREDICTION, app="other"))
        assert got[1].app == "other"
        d = got[0].to_dict()
        assert d["app"] == "gauss"
        assert RuntimeEvent.from_dict(d).app == "gauss"

    def test_unnamespaced_event_dict_has_no_app_key(self):
        e = ev(EventKind.PREDICTION)
        assert "app" not in e.to_dict()       # old traces stay identical

    def test_event_dict_round_trip(self):
        e = ev(EventKind.TASK_COMPLETED, time=1.5, task_id=7,
               type_name="x", cost=2.0, worker_id=3, elapsed=0.25,
               data={"parent": None, "deps": [1, 2]})
        e2 = RuntimeEvent.from_dict(e.to_dict())
        assert e2.kind is EventKind.TASK_COMPLETED
        assert e2.task_id == 7 and e2.elapsed == 0.25
        assert list(e2.data["deps"]) == [1, 2]


class TestMonitorSubscriber:
    """The TaskMonitor observes the scheduler through the bus only."""

    def test_scheduler_publishes_monitor_aggregates(self):
        mon = TaskMonitor()
        sched = Scheduler(mon)
        a = Task("a", cost=2.0)
        b = Task("b", cost=1.0).depends_on(a)
        sched.submit(a)
        sched.submit(b)
        assert mon.live_instances() == 1          # only `a` is ready
        t = sched.poll(worker_id=0)
        assert t is a
        sched.complete(a, elapsed=0.1, worker_id=0)
        assert mon.completed_instances() == 1
        assert mon.live_instances() == 1          # b became ready
        assert mon.unitary_cost("a") == pytest.approx(0.05)

    def test_external_bus_shared_with_other_subscribers(self):
        bus = EventBus()
        mon = TaskMonitor()
        seen = []
        bus.subscribe(seen.append)
        sched = Scheduler(mon, bus=bus)
        sched.submit(Task("a", cost=1.0))
        kinds = [e.kind for e in seen]
        assert kinds == [EventKind.TASK_SUBMITTED, EventKind.TASK_READY]
        assert mon.live_instances() == 1

    def test_monitor_subscribe_idempotent_per_bus(self):
        bus = EventBus()
        mon = TaskMonitor()
        mon.subscribe(bus)
        mon.subscribe(bus)                    # no double counting
        sched = Scheduler(mon, bus=bus)       # wires the same pair again
        sched.submit(Task("a", cost=1.0))
        assert mon.live_instances() == 1
        bus2 = EventBus()
        mon.subscribe(bus2)                   # distinct bus still works
        bus2.publish(RuntimeEvent(kind=EventKind.TASK_READY, time=0.0,
                                  task_id=99, type_name="b", cost=1.0))
        assert mon.live_instances() == 2

    def test_submitted_event_carries_deps_and_release(self):
        bus = EventBus()
        seen = []
        bus.subscribe(seen.append, kinds=[EventKind.TASK_SUBMITTED])
        sched = Scheduler(bus=bus)
        a = Task("a")
        b = Task("b", release_time=1.5).depends_on(a)
        sched.submit(a)
        sched.submit(b)
        assert seen[0].data["deps"] == []
        assert seen[1].data["deps"] == [a.task_id]
        assert seen[1].data["release_time"] == 1.5


class TestSubscribeChurnProperty:
    """Property: under any interleaving of subscribe/unsubscribe (with
    arbitrary kind filters, duplicate subscribes, and unsubscribes of
    never-registered handlers), the cached ``interest`` union and the
    ``interested()`` pre-check stay consistent with the live subscriber
    list — the copy-on-write cache can never go stale."""

    KINDS = list(EventKind)

    @staticmethod
    def _expected_interest(subs):
        kinds = set()
        for _, ks in subs:
            if ks is None:
                return None
            kinds |= ks
        return frozenset(kinds)

    def _assert_consistent(self, bus):
        assert bus.interest == self._expected_interest(bus._subs)
        for kind in self.KINDS:
            delivered = any(ks is None or kind in ks
                            for _, ks in bus._subs)
            assert bus.interested(kind) == delivered

    @pytest.mark.parametrize("seed", range(8))
    def test_random_churn_keeps_interest_cache_consistent(self, seed):
        import random as _random

        rng = _random.Random(seed)
        bus = EventBus()
        handlers = [(lambda _e, i=i: None) for i in range(6)]
        for step in range(120):
            h = rng.choice(handlers)
            action = rng.random()
            if action < 0.55:
                ks = (None if rng.random() < 0.3 else
                      rng.sample(self.KINDS, rng.randint(0, 4)))
                bus.subscribe(h, kinds=ks)
            else:
                bus.unsubscribe(h)
            self._assert_consistent(bus)
            # no duplicate registrations, ever
            regs = [hh for hh, _ in bus._subs]
            assert len(regs) == len(set(map(id, regs)))
        # full teardown returns the bus to the quiet state
        for h in handlers:
            bus.unsubscribe(h)
        from repro.core.events import QUIET_INTEREST
        assert bus.interest == QUIET_INTEREST
        assert bus.n_subscribers == 0

    def test_counts_delivered_events_exactly_once_through_churn(self):
        import random as _random

        rng = _random.Random(1234)
        bus = EventBus()
        counts = [0, 0, 0]
        handlers = [lambda e, i=0: counts.__setitem__(0, counts[0] + 1),
                    lambda e, i=1: counts.__setitem__(1, counts[1] + 1),
                    lambda e, i=2: counts.__setitem__(2, counts[2] + 1)]
        expected = [0, 0, 0]
        live = [False, False, False]
        for _ in range(300):
            i = rng.randrange(3)
            if rng.random() < 0.5:
                bus.subscribe(handlers[i])
                live[i] = True
            else:
                bus.unsubscribe(handlers[i])
                live[i] = False
            bus.publish(ev(EventKind.TASK_READY, task_id=1,
                           type_name="t", cost=1.0))
            for j in range(3):
                if live[j]:
                    expected[j] += 1
        assert counts == expected
