"""Heterogeneous-core machine model + frequency-aware prediction.

Covers the topology data model, per-(task-type × core-type) monitoring,
the per-core-type Δ_c plan (fastest cores first, count fallback, DVFS
step), core-type-aware parking/waking, per-type energy accounting — and
the two acceptance properties: exact homogeneous parity with the
existing ``prediction`` policy, and an EDP win (within a makespan
guard) over ``busy`` on an asymmetric preset.
"""

import pytest

from repro.core.energy import CoreState, EnergyMeter, PowerModel
from repro.core.events import EventBus, EventKind
from repro.core.governor import GovernorSpec, ResourceGovernor
from repro.core.monitoring import TaskMonitor
from repro.core.policies import HeteroPredictionPolicy, PollDecision
from repro.core.prediction import CPUPredictor, PredictionConfig
from repro.core.topology import CoreTopology, CoreType
from repro.runtime import (DVFS2, HYBRID_PE, MN4, MachineModel,
                           SimExecutor, Task, TaskGraph)

PE = CoreTopology(types=(
    CoreType(name="P", count=4, speed=1.0),
    CoreType(name="E", count=8, speed=0.5,
             power=PowerModel(active=0.4, spin=0.4, idle=0.05)),
))


def _wide_graph(n=300, cost=1.0, service=2e-4) -> TaskGraph:
    g = TaskGraph()
    for _ in range(n):
        g.add(Task(type_name="t", cost=cost, service_time=service))
    return g


class TestTopology:
    def test_positional_mapping(self):
        assert PE.n_cores == 12
        assert [PE.type_of(i) for i in (0, 3, 4, 11)] == \
            ["P", "P", "E", "E"]
        assert PE.speed_of(0) == 1.0 and PE.speed_of(11) == 0.5
        # global simulator ids wrap per machine
        assert PE.type_of(12) == "P" and PE.type_of(16) == "E"

    def test_fastest_first_and_mean_speed(self):
        assert [t.name for t in PE.fastest_first()] == ["P", "E"]
        assert PE.mean_speed() == pytest.approx((4 * 1.0 + 8 * 0.5) / 12)

    def test_validation(self):
        with pytest.raises(ValueError):
            CoreType(name="x", count=0)
        with pytest.raises(ValueError):
            CoreType(name="x", count=1, speed=0.0)
        with pytest.raises(ValueError):
            CoreType(name="x", count=1, freq_steps=(1.0, 0.5))  # descending
        with pytest.raises(ValueError):
            CoreType(name="x", count=1, freq_steps=(0.5, 1.5))  # > 1
        with pytest.raises(ValueError):
            CoreTopology(types=(CoreType(name="a", count=1),
                                CoreType(name="a", count=1)))

    def test_round_trip(self):
        assert CoreTopology.from_dict(PE.to_dict()) == PE

    def test_machine_presets(self):
        assert HYBRID_PE.topology().type_names() == ["P", "E"]
        assert DVFS2.topology().by_name("S0").freq_steps == \
            (0.75, 0.875, 1.0)
        with pytest.raises(ValueError):
            MachineModel(name="bad", n_cores=4, core_types=(
                CoreType(name="a", count=3),))  # counts don't sum

    def test_machine_service_time(self):
        # P core at full speed, E core at 55%, frequency divides further
        base = 1e-3
        assert HYBRID_PE.service_time(base, core=0) == base
        assert HYBRID_PE.service_time(base, core=8) == \
            pytest.approx(base / 0.55)
        assert HYBRID_PE.service_time(base, core=0, freq=0.5) == \
            pytest.approx(2 * base)
        # homogeneous machines ignore the core index
        assert MN4.service_time(base, core=17) == base


class TestMonitorPerCoreType:
    def test_alpha_split_by_core_type(self):
        m = TaskMonitor(min_samples=2)
        m.set_core_type_of(lambda w: "P" if w < 4 else "E")
        for i in range(4):
            m.on_task_ready(i, "t", 1.0)
            m.on_task_execute(i, "t", 1.0)
            # P cores twice as fast as E cores
            worker = 0 if i % 2 == 0 else 7
            m.on_task_completed(i, "t", 1.0, 1e-3 if worker < 4 else 2e-3,
                                core_type="P" if worker < 4 else "E")
        assert m.unitary_cost("t", core_type="P") == pytest.approx(1e-3)
        assert m.unitary_cost("t", core_type="E") == pytest.approx(2e-3)
        # the aggregate mixes both
        assert 1e-3 < m.unitary_cost("t") < 2e-3

    def test_alpha_normalized_by_frequency(self):
        """Samples measured on a downclocked core bake in the 1/q
        dilation; the per-core α must store the full-speed cost or the
        planner double-counts the slowdown and oscillates."""
        m = TaskMonitor(min_samples=1)
        m.on_task_ready(0, "t", 1.0)
        m.on_task_execute(0, "t", 1.0)
        m.on_task_completed(0, "t", 1.0, 2e-3, core_type="S", freq=0.5)
        assert m.unitary_cost("t", core_type="S") == pytest.approx(1e-3)
        # the aggregate keeps the raw (wall-clock) sample
        assert m.unitary_cost("t") == pytest.approx(2e-3)

    def test_hetero_snapshot_reliability(self):
        m = TaskMonitor(min_samples=2)
        m.on_task_ready(0, "t", 1.0)
        m.on_task_execute(0, "t", 1.0)
        m.on_task_completed(0, "t", 1.0, 1e-3, core_type="P")
        m.on_task_ready(1, "t", 1.0)
        (snap,) = m.workload_snapshot_hetero()
        assert snap.alpha_by_core["P"][1] == 1
        assert not snap.alpha_by_core["P"][2]   # 1 sample < min_samples=2


def _monitor_with_work(n_ready: int, alpha: float = 50e-6,
                       min_samples: int = 1,
                       core_type: str = "P") -> TaskMonitor:
    """α = rate ⇒ each live task is one CPU-window of work on a
    unit-speed core."""
    m = TaskMonitor(min_samples=min_samples)
    for i in range(3):
        m.on_task_ready(i, "t", 1.0)
        m.on_task_execute(i, "t", 1.0)
        m.on_task_completed(i, "t", 1.0, alpha, core_type=core_type)
    for i in range(n_ready):
        m.on_task_ready(100 + i, "t", 1.0)
    return m


class TestHeteroPlan:
    def test_fastest_cores_filled_first(self):
        m = _monitor_with_work(n_ready=10)      # 10 unit-speed windows
        pred = CPUPredictor(m, n_cpus=12, topology=PE,
                            config=PredictionConfig(rate_s=50e-6,
                                                    min_samples=1))
        pred.tick()
        # all 4 P cores fill first; the remaining work lands on E cores,
        # Δ ≤ live instances (Alg. 1's ΣM cap) trims the slow type
        assert pred.delta_by_type == {"P": 4, "E": 6}
        assert pred.delta == 10

    def test_instance_cap_trims_slowest_type(self):
        m = _monitor_with_work(n_ready=6)
        pred = CPUPredictor(m, n_cpus=12, topology=PE,
                            config=PredictionConfig(rate_s=50e-6,
                                                    min_samples=1))
        pred.tick()
        # 6 windows of work: E cores at speed 0.5 would need 4 cores for
        # the last 2 windows, but only 6 task instances exist (one task
        # occupies one core) — the surplus is trimmed from the slow type
        assert pred.delta_by_type == {"P": 4, "E": 2}
        assert pred.delta == 6

    def test_count_fallback_takes_one_core_each(self):
        m = TaskMonitor(min_samples=4)          # nothing reliable yet
        for i in range(5):
            m.on_task_ready(i, "t", 1.0)
        pred = CPUPredictor(m, n_cpus=12, topology=PE,
                            config=PredictionConfig(min_samples=4))
        pred.tick()
        # 5 instances, fastest first: all 4 P cores + 1 E core
        assert pred.delta_by_type == {"P": 4, "E": 1}
        assert pred.delta == 5

    def test_no_live_work_keeps_one_fastest_core(self):
        m = TaskMonitor(min_samples=1)
        pred = CPUPredictor(m, n_cpus=12, topology=PE)
        pred.tick()
        assert pred.delta == 1
        assert pred.delta_by_type == {"P": 1}

    def test_topology_size_must_match(self):
        with pytest.raises(ValueError):
            CPUPredictor(TaskMonitor(), n_cpus=5, topology=PE)

    def test_fast_core_reserve_keeps_p_cores_awake(self):
        """On a speed-asymmetric topology the fastest type stays fully
        awake while live work exists: a parked P-core would lose the
        dispatch race to a spinning E-core on the critical path."""
        m = _monitor_with_work(n_ready=1)   # one window of work
        pred = CPUPredictor(m, n_cpus=12, topology=PE,
                            config=PredictionConfig(rate_s=50e-6,
                                                    min_samples=1))
        pred.tick()
        assert pred.delta_by_type["P"] == 4     # all P reserved
        assert pred.delta_by_type.get("E", 0) == 0

    def test_no_reserve_on_single_speed_topology(self):
        two_sockets = CoreTopology(types=(CoreType(name="S0", count=4),
                                          CoreType(name="S1", count=4)))
        m = _monitor_with_work(n_ready=1, core_type="S0")
        pred = CPUPredictor(m, n_cpus=8, topology=two_sockets,
                            config=PredictionConfig(rate_s=50e-6,
                                                    min_samples=1))
        pred.tick()
        assert pred.delta == 1                  # no reserve boost


class TestFrequencyRecommendation:
    DVFS = CoreTopology(types=(
        CoreType(name="S", count=8, freq_steps=(0.75, 0.875, 1.0)),))

    def _pred(self, n_ready, alpha=50e-6, **cfg):
        m = _monitor_with_work(n_ready=n_ready, alpha=alpha,
                               core_type="S")
        cfg.setdefault("rate_s", 50e-6)
        cfg.setdefault("min_samples", 1)
        pred = CPUPredictor(m, n_cpus=8, topology=self.DVFS,
                            config=PredictionConfig(**cfg))
        pred.tick()
        return pred

    def test_saturated_type_stays_at_max_step(self):
        pred = self._pred(n_ready=8)        # demand == capacity
        assert pred.freq_by_type == {"S": 1.0}

    def test_slack_stretches_wide_and_slow(self):
        # 6 half-window tasks = 3 windows of demand on 8 cores: the plan
        # widens to 5 cores (margin 1.25) at the EDP-optimal 0.75 step —
        # same throughput, lower modeled P_active(q)/q²
        pred = self._pred(n_ready=6, alpha=25e-6)
        assert pred.freq_by_type["S"] == 0.75
        assert pred.delta_by_type["S"] == 5

    def test_no_spare_instances_means_no_stretch(self):
        # 2 long tasks on 8 cores: slack in cores, but only 2 runnable
        # instances — widening is impossible, so slowing the 2 active
        # cores would dilate the critical path; stay at max step
        pred = self._pred(n_ready=2)
        assert pred.freq_by_type["S"] == 1.0

    def test_freq_floor_guards_the_critical_path(self):
        pred = self._pred(n_ready=6, alpha=25e-6, freq_floor=0.9)
        # 0.75 and 0.875 are below the floor ⇒ stay at 1.0
        assert pred.freq_by_type["S"] == 1.0

    def test_count_fallback_disables_stretching(self):
        m = TaskMonitor(min_samples=4)
        for i in range(2):
            m.on_task_ready(i, "t", 1.0)    # unknown durations
        pred = CPUPredictor(m, n_cpus=8, topology=self.DVFS,
                            config=PredictionConfig(min_samples=4))
        pred.tick()
        assert pred.freq_by_type["S"] == 1.0


class TestHeteroPolicy:
    def test_per_type_poll_decisions(self):
        m = _monitor_with_work(n_ready=6)
        pred = CPUPredictor(m, n_cpus=12, topology=PE,
                            config=PredictionConfig(rate_s=50e-6,
                                                    min_samples=1))
        pred.tick()                          # Δ = {P: 4, E: 4}
        pol = HeteroPredictionPolicy(pred)
        counts = {"P": 4, "E": 5}
        pol.bind_topology(lambda w: "P" if w < 4 else "E", lambda: counts)
        # E is over its Δ_c ⇒ an E worker parks, a P worker spins
        assert pol.on_poll_empty(7, active=9, spin_count=1) \
            is PollDecision.IDLE
        assert pol.on_poll_empty(0, active=9, spin_count=1) \
            is PollDecision.SPIN

    def test_unbound_falls_back_to_total_delta(self):
        m = _monitor_with_work(n_ready=6)
        pred = CPUPredictor(m, n_cpus=12, topology=PE,
                            config=PredictionConfig(rate_s=50e-6,
                                                    min_samples=1))
        pred.tick()
        pol = HeteroPredictionPolicy(pred)
        assert pred.delta == 6
        assert pol.on_poll_empty(0, active=7, spin_count=1) \
            is PollDecision.IDLE             # 7 > Δ=6
        assert pol.on_poll_empty(0, active=6, spin_count=1) \
            is PollDecision.SPIN


class TestGovernorWiring:
    def test_spec_round_trip_with_topology(self):
        spec = GovernorSpec(resources=12, policy="hetero-prediction",
                            topology=PE, park_order="fast-first")
        assert GovernorSpec.from_dict(spec.to_dict()) == spec

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            GovernorSpec(resources=4, park_order="sideways")
        with pytest.raises(ValueError):
            GovernorSpec(resources=4, topology=PE)  # 12 != 4

    def test_park_and_wake_order(self):
        gov = ResourceGovernor(
            GovernorSpec(resources=12, policy="hetero-prediction",
                         topology=PE),
            clock=lambda: 0.0)
        mgr = gov.manager
        workers = list(range(12))
        # slow-first parking: E cores (ids 4..11) trimmed first
        assert mgr.park_first(workers)[:8] == list(range(4, 12))
        # waking brings P cores (ids 0..3) back first
        assert mgr.wake_first(workers)[:4] == [0, 1, 2, 3]

    def test_fast_first_park_order(self):
        gov = ResourceGovernor(
            GovernorSpec(resources=12, policy="hetero-prediction",
                         topology=PE, park_order="fast-first"),
            clock=lambda: 0.0)
        assert gov.manager.park_first(list(range(12)))[:4] == [0, 1, 2, 3]

    def test_per_type_energy_and_report(self):
        gov = ResourceGovernor(
            GovernorSpec(resources=12, policy="hetero-prediction",
                         topology=PE),
            clock=lambda: 0.0)
        gov.finish(0.0)
        rep = gov.report()
        assert set(rep.state_seconds_by_type) == {"P", "E"}
        assert rep.freq_by_type == {"P": 1.0, "E": 1.0}


class TestHomogeneousParity:
    """With one core type, per-type prediction must reproduce today's Δ
    sequence and reports exactly (acceptance criterion)."""

    def _run(self, policy: str):
        deltas = []
        bus = EventBus()
        bus.subscribe(lambda ev: deltas.append(ev.data["delta"]),
                      kinds=(EventKind.PREDICTION,))
        g = TaskGraph()
        prev = None
        for i in range(120):
            t = Task(type_name=("a" if i % 3 else "b"),
                     cost=1.0 + (i % 5), service_time=1e-4 * (1 + i % 4))
            if prev is not None and i % 7 == 0:
                t.depends_on(prev)
            g.add(t)
            prev = t
        spec = GovernorSpec(resources=MN4.n_cores, policy=policy,
                            monitoring=True)
        report = SimExecutor(MN4, spec=spec, bus=bus).run(g)
        assert deltas, "no PREDICTION events captured"
        return report, deltas

    def test_delta_sequence_and_report_match(self):
        base, base_deltas = self._run("prediction")
        het, het_deltas = self._run("hetero-prediction")
        assert het_deltas == base_deltas
        assert het.makespan == base.makespan
        assert het.energy == base.energy
        assert het.edp == base.edp
        assert het.tasks_completed == base.tasks_completed
        assert het.resumes == base.resumes
        assert het.idles == base.idles
        assert het.predictions == base.predictions
        assert het.state_seconds == base.state_seconds
        # homogeneous stacks report no per-type split and no made-up
        # frequency entry for the synthesized type
        assert het.state_seconds_by_type == {}
        assert het.freq_by_type == base.freq_by_type == {}


class TestAsymmetricSim:
    def test_hetero_beats_busy_on_edp(self):
        """On an asymmetric preset the frequency-aware prediction policy
        must cut EDP vs busy without giving up >10% makespan."""
        reports = {}
        for policy in ("busy", "hetero-prediction"):
            spec = GovernorSpec(resources=HYBRID_PE.n_cores, policy=policy,
                                monitoring=True)
            reports[policy] = SimExecutor(HYBRID_PE, spec=spec).run(
                _wide_graph(n=400))
        busy, het = reports["busy"], reports["hetero-prediction"]
        assert het.edp < busy.edp
        assert het.makespan <= 1.10 * busy.makespan
        # the asymmetric report carries the per-type split
        assert set(het.state_seconds_by_type) == {"P", "E"}

    def test_dvfs_machine_runs_and_reports_steps(self):
        spec = GovernorSpec(resources=DVFS2.n_cores,
                            policy="hetero-prediction", monitoring=True)
        rep = SimExecutor(DVFS2, spec=spec).run(_wide_graph(n=150))
        assert rep.tasks_completed == 150
        assert set(rep.freq_by_type) == {"S0", "S1"}
        for q in rep.freq_by_type.values():
            assert q in (0.75, 0.875, 1.0)

    def test_dvfs_stretch_fires_under_partial_load(self):
        """Micro-tasks at ~30% of capacity: the plan widens each socket
        and downclocks it — lower energy and EDP than busy at the same
        makespan (the scenario BENCH_heterogeneous tracks)."""
        from repro.workloads.arrivals import PoissonArrivals

        def make_graph():
            g = TaskGraph()
            for _ in range(4000):
                g.add(Task(type_name="micro", cost=1.0, service_time=2e-5))
            return g

        arrivals = PoissonArrivals(rate=0.3 * DVFS2.n_cores / 2e-5, seed=1)
        reports = {}
        for policy in ("busy", "hetero-prediction"):
            spec = GovernorSpec(resources=DVFS2.n_cores, policy=policy,
                                monitoring=True)
            reports[policy] = SimExecutor(DVFS2, spec=spec).run(
                make_graph(), arrivals=arrivals)
        busy, het = reports["busy"], reports["hetero-prediction"]
        assert any(q < 1.0 for q in het.freq_by_type.values())
        assert het.energy < busy.energy
        assert het.edp < busy.edp
        assert het.makespan <= 1.10 * busy.makespan

    def test_subset_job_gets_sliced_topology_power(self):
        """A job pinned to a cpu subset of an asymmetric machine must
        account energy with the same per-core types the machine uses
        for service times (regression: it used to bill E-cores at
        P-core power while running them at E-core speed)."""
        from repro.runtime import SimCluster, SimJobSpec

        cl = SimCluster(HYBRID_PE)
        # the 16 E-cores only (machine ids 8..23)
        cl.add_job(SimJobSpec(name="e-only", graph=_wide_graph(n=64),
                              policy="busy", cpus=list(range(8, 24))))
        rep = cl.run()["e-only"]
        assert set(rep.state_seconds_by_type) == {"E"}
        # busy on E-cores: everything active/spin at the E power (0.4)
        total_s = sum(rep.state_seconds.values())
        assert rep.energy == pytest.approx(0.4 * total_s)
        # and the service times are E-speed (0.55×)
        assert rep.makespan >= 64 * 2e-4 / 0.55 / 16

    def test_subset_job_mixed_types(self):
        from repro.runtime import SimCluster, SimJobSpec

        cl = SimCluster(HYBRID_PE)
        cl.add_job(SimJobSpec(name="mix", graph=_wide_graph(n=40),
                              policy="busy", cpus=[6, 7, 8, 9]))
        rep = cl.run()["mix"]
        assert set(rep.state_seconds_by_type) == {"P", "E"}

    def test_borrowed_core_billed_with_machine_type(self):
        """DLB on an asymmetric machine: a core borrowed across the
        type boundary is announced with its *machine* identity, so the
        borrower bills it under the right type and power."""
        from repro.core import ResourceBroker
        from repro.runtime import SimCluster, SimJobSpec

        broker = ResourceBroker()
        cl = SimCluster(HYBRID_PE, broker=broker)
        # jobs split along the type boundary: "p-job" owns the P cores
        # and finishes long after "e-job", so it borrows E cores
        cl.add_job(SimJobSpec(name="p-job", graph=_wide_graph(n=400),
                              policy="dlb-lewi", cpus=list(range(8))))
        cl.add_job(SimJobSpec(name="e-job", graph=_wide_graph(n=10),
                              policy="dlb-lewi", cpus=list(range(8, 24))))
        reports = cl.run()
        by_type = reports["p-job"].state_seconds_by_type
        assert "E" in by_type          # borrowed E cores billed as E
        assert by_type["E"]["active"] > 0

    def test_plain_policies_work_on_asymmetric_machines(self):
        # every registered non-sharing policy must run on a hetero preset
        for policy in ("busy", "idle", "hybrid", "prediction"):
            spec = GovernorSpec(resources=HYBRID_PE.n_cores, policy=policy,
                                monitoring=True)
            rep = SimExecutor(HYBRID_PE, spec=spec).run(_wide_graph(n=60))
            assert rep.tasks_completed == 60
            assert set(rep.state_seconds_by_type) == {"P", "E"}


class TestEnergyMeterFrequency:
    def test_cubic_power_scaling(self):
        pm = PowerModel()
        assert pm.power(CoreState.ACTIVE, 1.0) == 1.0
        assert pm.power(CoreState.ACTIVE, 0.5) == \
            pytest.approx(0.1 + 0.9 * 0.125)
        # idle/off power is static — no frequency scaling
        assert pm.power(CoreState.IDLE, 0.5) == 0.1
        assert pm.power(CoreState.OFF, 0.5) == 0.0

    def test_meter_integrates_frequency_segments(self):
        em = EnergyMeter(1)
        em.set_state(0, CoreState.ACTIVE, 0.0)
        em.set_frequency(0, 0.5, 1.0)   # 1s at q=1, then 1s at q=0.5
        em.finish(2.0)
        expected = 1.0 * 1.0 + 1.0 * (0.1 + 0.9 * 0.125)
        assert em.energy() == pytest.approx(expected)
        assert em.state_seconds()[CoreState.ACTIVE] == pytest.approx(2.0)

    def test_per_core_power_models(self):
        em = EnergyMeter(0)
        em.add_core(0, CoreState.SPIN, 0.0, core_type="P")
        em.add_core(1, CoreState.SPIN, 0.0,
                    power=PowerModel(active=0.4, spin=0.4), core_type="E")
        em.finish(1.0)
        by_type = em.energy_by_type()
        assert by_type["P"] == pytest.approx(1.0)
        assert by_type["E"] == pytest.approx(0.4)
