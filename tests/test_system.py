"""End-to-end behaviour: the paper's headline claims, asserted on the
simulator (the benchmarks print the full tables; these tests pin the
qualitative orderings so regressions fail CI)."""

import pytest

from repro.core import ResourceBroker
from repro.runtime import MN4, SimCluster, SimExecutor, SimJobSpec
from repro.workloads import WORKLOADS, build_gauss_seidel, build_stream


@pytest.fixture(scope="module")
def gauss_reports():
    out = {}
    for pol in ("busy", "idle", "prediction"):
        g = build_gauss_seidel(steps=20, bi=8, bj=8, seed=0)
        out[pol] = SimExecutor(MN4, policy=pol, monitoring=True).run(g)
    return out


class TestPolicyClaims:
    def test_prediction_matches_busy_performance(self, gauss_reports):
        """Claim 1: prediction ≈ busy wall-clock (within 10%)."""
        r = gauss_reports
        assert r["prediction"].makespan <= r["busy"].makespan * 1.10

    def test_prediction_beats_busy_energy(self, gauss_reports):
        """Claim 2: prediction saves substantial energy vs busy."""
        r = gauss_reports
        assert r["prediction"].energy < r["busy"].energy * 0.6

    def test_prediction_best_edp(self, gauss_reports):
        """Claim 3 (Fig. 4): prediction wins EDP on imbalanced loads."""
        r = gauss_reports
        assert r["prediction"].edp < r["busy"].edp
        assert r["prediction"].edp < r["idle"].edp

    def test_idle_pays_resume_overhead(self, gauss_reports):
        r = gauss_reports
        assert r["idle"].makespan > r["prediction"].makespan
        assert r["idle"].resumes > 0

    def test_accuracy_in_paper_band(self, gauss_reports):
        """Table 2: Gauss-Seidel accuracy is the best of all benchmarks
        (99.9% in the paper; jitter here is synthetic but the ordering
        and >70% band must hold)."""
        acc = gauss_reports["prediction"].accuracy
        assert acc is not None and acc.average_pct > 70.0


class TestSharingClaims:
    def _run(self, policy):
        broker = ResourceBroker()
        cl = SimCluster(MN4, broker=broker)
        cl.add_job(SimJobSpec(
            name="gauss",
            graph=build_gauss_seidel(steps=10, bi=8, bj=8, seed=0),
            policy=policy, cpus=list(range(24))))
        # paper regime: STREAM is fine-grained ⇒ task boundaries ≫ ticks
        cl.add_job(SimJobSpec(
            name="stream", graph=build_stream(rounds=12, blocks=2000,
                                              block_elems=40_000, seed=1),
            policy=policy, cpus=list(range(24, 48))))
        reps = cl.run()
        return reps, broker.total_calls

    def test_prediction_sharing_fewer_calls(self):
        """Table 3: DLB-prediction makes ≥4× fewer broker calls."""
        _, calls_lewi = self._run("dlb-lewi")
        _, calls_pred = self._run("dlb-prediction")
        assert calls_pred * 4 <= calls_lewi

    def test_stream_speedup_from_sharing(self):
        """Table 3: STREAM borrows Gauss-Seidel's idle CPUs."""
        reps, _ = self._run("dlb-prediction")
        stream_alone = SimExecutor(MN4, policy="busy", n_cpus=24).run(
            build_stream(rounds=12, blocks=2000, block_elems=40_000,
                         seed=1))
        assert reps["stream"].makespan < stream_alone.makespan


def test_monitoring_overhead_below_3pct():
    """§5: monitoring adds ≤3% to execution time (fine-grained worst
    case). The simulator charges the per-event overhead explicitly."""
    g1 = WORKLOADS["multisaxpy-fine"](generations=20, blocks=100, seed=0)
    g2 = WORKLOADS["multisaxpy-fine"](generations=20, blocks=100, seed=0)
    t_plain = SimExecutor(MN4, policy="busy", monitoring=False).run(g1)
    t_mon = SimExecutor(MN4, policy="busy", monitoring=True).run(g2)
    overhead = t_mon.makespan / t_plain.makespan - 1.0
    assert overhead < 0.03, overhead
