"""Elastic controller + straggler mitigation."""

from _hypothesis_compat import given, settings, st

from repro.train.elastic import ElasticController, ReplicaSet
from repro.train.straggler import StragglerMonitor


class TestReplicaSet:
    @given(st.integers(1, 64), st.integers(1, 1024))
    @settings(max_examples=100, deadline=None)
    def test_shards_conserve_batch(self, n, batch):
        rs = ReplicaSet(list(range(n)), batch)
        shards = rs.shards()
        assert sum(shards.values()) == batch
        assert max(shards.values()) - min(shards.values()) <= 1


class TestElasticController:
    def _seed(self, c: ElasticController, step_time=0.1, n=6):
        for i in range(n):
            c.on_batches_queued(1, tokens_per_batch=1000.0)
            c.on_step_done(c._task_seq, 1000.0, step_time)

    def test_failure_shrinks_and_rebalances(self):
        c = ElasticController(max_replicas=8, global_batch=256)
        new = c.fail_replica(3, step=10)
        assert 3 not in new.replicas and len(new.replicas) == 7
        assert sum(new.shards().values()) == 256

    def test_prediction_shrinks_when_idle(self):
        c = ElasticController(max_replicas=8, global_batch=64,
                              rate_s=0.1)
        self._seed(c)
        # no queued work ⇒ Δ collapses to 1
        rs = c.resize_to_prediction(step=1)
        assert len(rs.replicas) == 1

    def test_prediction_grows_with_backlog(self):
        c = ElasticController(max_replicas=8, global_batch=64,
                              rate_s=0.1)
        self._seed(c, step_time=0.1)
        # 8 batches × 0.1 s backlog over a 0.1 s window ⇒ want 8 replicas
        c.on_batches_queued(8, tokens_per_batch=1000.0)
        c.set = ReplicaSet([0], 64)
        rs = c.resize_to_prediction(step=2)
        assert len(rs.replicas) == 8

    def test_failed_never_readmitted(self):
        c = ElasticController(max_replicas=4, global_batch=32)
        c.fail_replica(2, step=0)
        self._seed(c)
        c.on_batches_queued(16, tokens_per_batch=1000.0)
        rs = c.resize_to_prediction(step=1)
        assert 2 not in rs.replicas
        assert len(rs.replicas) <= 3

    def test_busy_policy_keeps_everything(self):
        c = ElasticController(max_replicas=6, global_batch=32,
                              policy="busy")
        self._seed(c)
        assert len(c.resize_to_prediction(0).replicas) == 6


class TestStraggler:
    def test_detects_slow_worker(self):
        m = StragglerMonitor(threshold=1.5)
        for _ in range(6):
            for w in range(7):
                m.observe(w, 0.10)
            m.observe(7, 0.30)
        assert m.sweep() == {7}
        assert m.is_straggler(7)
        assert not m.is_straggler(0)

    def test_cooldown_readmission(self):
        m = StragglerMonitor(threshold=1.5, cooldown=3)
        for _ in range(6):
            for w in range(3):
                m.observe(w, 0.10)
            m.observe(3, 0.50)
        assert m.sweep() == {3}
        # the worker recovers; EMA drifts back under the threshold
        for _ in range(30):
            for w in range(3):
                m.observe(w, 0.10)
            m.observe(3, 0.10)
        assert 3 not in m.drained

    def test_no_flags_with_uniform_fleet(self):
        m = StragglerMonitor()
        for _ in range(10):
            for w in range(16):
                m.observe(w, 0.1)
        assert m.sweep() == set()
