"""Elastic controller + straggler mitigation + fault-tolerant training."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager
from repro.core.conditions import (ConditionTimeline, core_fail,
                                   core_recover, straggler)
from repro.train.elastic import ElasticController, ReplicaSet
from repro.train.straggler import StragglerMonitor


class TestReplicaSet:
    @given(st.integers(1, 64), st.integers(1, 1024))
    @settings(max_examples=100, deadline=None)
    def test_shards_conserve_batch(self, n, batch):
        rs = ReplicaSet(list(range(n)), batch)
        shards = rs.shards()
        assert sum(shards.values()) == batch
        assert max(shards.values()) - min(shards.values()) <= 1


class TestElasticController:
    def _seed(self, c: ElasticController, step_time=0.1, n=6):
        for i in range(n):
            c.on_batches_queued(1, tokens_per_batch=1000.0)
            c.on_step_done(c._task_seq, 1000.0, step_time)

    def test_failure_shrinks_and_rebalances(self):
        c = ElasticController(max_replicas=8, global_batch=256)
        new = c.fail_replica(3, step=10)
        assert 3 not in new.replicas and len(new.replicas) == 7
        assert sum(new.shards().values()) == 256

    def test_prediction_shrinks_when_idle(self):
        c = ElasticController(max_replicas=8, global_batch=64,
                              rate_s=0.1)
        self._seed(c)
        # no queued work ⇒ Δ collapses to 1
        rs = c.resize_to_prediction(step=1)
        assert len(rs.replicas) == 1

    def test_prediction_grows_with_backlog(self):
        c = ElasticController(max_replicas=8, global_batch=64,
                              rate_s=0.1)
        self._seed(c, step_time=0.1)
        # 8 batches × 0.1 s backlog over a 0.1 s window ⇒ want 8 replicas
        c.on_batches_queued(8, tokens_per_batch=1000.0)
        c.set = ReplicaSet([0], 64)
        rs = c.resize_to_prediction(step=2)
        assert len(rs.replicas) == 8

    def test_failed_never_readmitted(self):
        c = ElasticController(max_replicas=4, global_batch=32)
        c.fail_replica(2, step=0)
        self._seed(c)
        c.on_batches_queued(16, tokens_per_batch=1000.0)
        rs = c.resize_to_prediction(step=1)
        assert 2 not in rs.replicas
        assert len(rs.replicas) <= 3

    def test_busy_policy_keeps_everything(self):
        c = ElasticController(max_replicas=6, global_batch=32,
                              policy="busy")
        self._seed(c)
        assert len(c.resize_to_prediction(0).replicas) == 6


class TestFaultTolerantTraining:
    """CORE_FAIL mid-run → checkpoint-restore → completion with the
    surviving replicas (the dormant straggler/checkpoint hooks wired
    into the controller)."""

    def _run(self, c: ElasticController, timeline: ConditionTimeline,
             steps: int = 10, every: int = 2):
        state = {"w": np.zeros(4, dtype=np.float64)}
        fired = {p.time: p for p in timeline}
        step = 0
        while step < steps:
            state = {"w": state["w"] + 1.0}
            step += 1
            c.on_batches_queued(1, tokens_per_batch=1000.0)
            c.on_step_done(c._task_seq, 1000.0, 0.1,
                           replica=c.set.replicas[0])
            c.maybe_checkpoint(step, state, every=every)
            p = fired.pop(float(step), None)
            if p is not None:
                _, state, step = c.apply_perturbation(p, step, state)
        return state, step

    def test_core_fail_restores_and_completes(self, tmp_path):
        c = ElasticController(max_replicas=4, global_batch=32,
                              checkpoint=CheckpointManager(tmp_path))
        tl = ConditionTimeline([core_fail(5.0, 2)])
        state, step = self._run(c, tl, steps=10, every=2)
        # rolled back from the failure at step 5 to the step-4 save...
        assert c.restores == [(5, 4)]
        # ...and completed the full run on the survivors
        assert step == 10
        assert float(state["w"][0]) == 10.0
        assert 2 not in c.set.replicas
        assert len(c.set.replicas) == 3
        assert sum(c.set.shards().values()) == 32

    def test_core_fail_without_checkpoint_keeps_live_state(self, tmp_path):
        c = ElasticController(max_replicas=4, global_batch=32)
        tl = ConditionTimeline([core_fail(5.0, 1)])
        state, step = self._run(c, tl, steps=8)
        assert c.restores == []          # nothing to roll back to
        assert float(state["w"][0]) == 8.0
        assert 1 not in c.set.replicas

    def test_recover_rejoins_candidate_pool(self, tmp_path):
        c = ElasticController(max_replicas=4, global_batch=32, rate_s=0.1,
                              checkpoint=CheckpointManager(tmp_path))
        tl = ConditionTimeline([core_fail(3.0, 2), core_recover(6.0, 2)])
        self._run(c, tl, steps=8)
        assert 2 not in c.failed          # recovered
        # backlog-driven growth may now re-admit it
        c.on_batches_queued(16, tokens_per_batch=1000.0)
        rs = c.resize_to_prediction(step=9)
        assert len(rs.replicas) == 4

    def test_straggler_perturbation_drains_replica(self):
        c = ElasticController(max_replicas=4, global_batch=32,
                              straggler=StragglerMonitor())
        p = straggler(2.0, 3, 4.0)
        rs, _, _ = c.apply_perturbation(p, step=2, state=None)
        assert 3 not in rs.replicas
        assert 3 in c.straggler.drained
        # not a permanent failure: grows may re-admit after cooldown
        assert 3 not in c.failed

    def test_sweep_drains_observed_straggler(self):
        c = ElasticController(max_replicas=8, global_batch=64,
                              straggler=StragglerMonitor(threshold=1.5))
        for _ in range(6):
            for r in range(7):
                c.straggler.observe(r, 0.10)
            c.straggler.observe(7, 0.40)
        rs = c.sweep_stragglers(step=6)
        assert 7 not in rs.replicas
        assert len(rs.replicas) == 7
        # drained replicas are skipped by prediction-driven growth
        c.on_batches_queued(16, tokens_per_batch=1000.0)
        for i in range(6):
            c.on_step_done(c._task_seq - i, 1000.0, 0.1)
        rs = c.resize_to_prediction(step=7)
        assert 7 not in rs.replicas


class TestStraggler:
    def test_detects_slow_worker(self):
        m = StragglerMonitor(threshold=1.5)
        for _ in range(6):
            for w in range(7):
                m.observe(w, 0.10)
            m.observe(7, 0.30)
        assert m.sweep() == {7}
        assert m.is_straggler(7)
        assert not m.is_straggler(0)

    def test_cooldown_readmission(self):
        m = StragglerMonitor(threshold=1.5, cooldown=3)
        for _ in range(6):
            for w in range(3):
                m.observe(w, 0.10)
            m.observe(3, 0.50)
        assert m.sweep() == {3}
        # the worker recovers; EMA drifts back under the threshold
        for _ in range(30):
            for w in range(3):
                m.observe(w, 0.10)
            m.observe(3, 0.10)
        assert 3 not in m.drained

    def test_no_flags_with_uniform_fleet(self):
        m = StragglerMonitor()
        for _ in range(10):
            for w in range(16):
                m.observe(w, 0.1)
        assert m.sweep() == set()
