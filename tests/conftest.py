import os

import pytest

# Tests run on the single real CPU device — the 512-device dry-run flag
# must NOT leak here (only repro.launch.dryrun sets it, in-process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture(scope="session", autouse=True)
def _lock_order_witness():
    """Run the whole suite under the runtime lock-order witness.

    Every ``@guarded_by`` object constructed during the session gets an
    instrumented lock; at teardown the orders actually observed across
    all threaded tests are cross-checked against the declared
    ``LOCK_ORDER`` — an inversion anywhere fails the session (the
    teardown assertion reliably propagates to a nonzero pytest exit).

    Disable with ``REPRO_LOCK_WITNESS=0`` (e.g. for profiling runs);
    measurement-only tests opt out locally via ``witness_paused()``.
    """
    if os.environ.get("REPRO_LOCK_WITNESS", "1") == "0":
        yield None
        return
    from repro.analysis import install_witness, uninstall_witness

    witness = install_witness(strict=False)
    yield witness
    uninstall_witness()
    problems = witness.check_declared()
    assert not witness.violations, (
        "lock-order inversions observed during the test suite:\n"
        + "\n".join(witness.violations))
    assert not problems, (
        "observed lock nestings contradict the declared LOCK_ORDER:\n"
        + "\n".join(problems))
