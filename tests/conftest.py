import os

# Tests run on the single real CPU device — the 512-device dry-run flag
# must NOT leak here (only repro.launch.dryrun sets it, in-process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
