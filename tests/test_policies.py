"""Policies (busy/idle/hybrid/prediction) + Algorithm 2 mechanics."""

from _hypothesis_compat import given, settings, st

from repro.core.manager import WorkerManager
from repro.core.monitoring import TaskMonitor
from repro.core.governor import GovernorSpec, ResourceGovernor, \
    registered_policies
from repro.core.policies import (BusyPolicy, HybridPolicy, IdlePolicy,
                                 PollDecision, PredictionPolicy)
from repro.core.prediction import CPUPredictor, PredictionConfig


def test_busy_never_idles():
    p = BusyPolicy()
    for spin in range(1000):
        assert p.on_poll_empty(0, 8, spin) is PollDecision.SPIN


def test_idle_immediately():
    p = IdlePolicy()
    assert p.on_poll_empty(0, 8, 1) is PollDecision.IDLE
    assert p.workers_to_resume(active=2, idle=6, ready_tasks=4) == 2


def test_hybrid_budget_boundary():
    p = HybridPolicy(spin_budget=100)
    assert p.on_poll_empty(0, 8, 99) is PollDecision.SPIN
    assert p.on_poll_empty(0, 8, 100) is PollDecision.IDLE


def _predictor_with_delta(delta: int, n: int = 16) -> CPUPredictor:
    m = TaskMonitor(min_samples=1)
    # α = rate ⇒ each live task ⇒ one CPU-window of work
    for i in range(3):
        m.on_task_ready(i, "t", 1.0)
        m.on_task_execute(i, "t", 1.0)
        m.on_task_completed(i, "t", 1.0, 50e-6)
    for i in range(delta):
        m.on_task_ready(100 + i, "t", 1.0)
    p = CPUPredictor(m, n_cpus=n, config=PredictionConfig(
        rate_s=50e-6, min_samples=1))
    p.tick()
    assert p.delta == delta
    return p


class TestAlgorithm2:
    def test_poll_idles_only_above_delta(self):
        pred = _predictor_with_delta(4)
        pol = PredictionPolicy(pred)
        assert pol.on_poll_empty(0, active=5, spin_count=1) \
            is PollDecision.IDLE
        assert pol.on_poll_empty(0, active=4, spin_count=99) \
            is PollDecision.SPIN

    def test_resume_up_to_delta(self):
        pred = _predictor_with_delta(6)
        pol = PredictionPolicy(pred)
        assert pol.workers_to_resume(active=2, idle=10, ready_tasks=9) == 4
        assert pol.workers_to_resume(active=6, idle=10, ready_tasks=9) == 0

    def test_manager_delta_transitions(self):
        pred = _predictor_with_delta(2)
        mgr = WorkerManager(4, PredictionPolicy(pred), clock=lambda: 0.0)
        # All four workers spin; two empty polls should idle two of them
        assert mgr.poll_empty(0) is PollDecision.IDLE   # δ 4 > 2
        assert mgr.poll_empty(1) is PollDecision.IDLE   # δ 3 > 2
        assert mgr.poll_empty(2) is PollDecision.SPIN   # δ 2 == Δ
        assert mgr.active == 2
        # Work arrives; Δ=2 already met ⇒ no resumes
        assert mgr.notify_added(5) == []

    def test_manager_counts_transitions(self):
        mgr = WorkerManager(2, IdlePolicy(), clock=lambda: 0.0)
        mgr.poll_empty(0)
        mgr.poll_empty(1)
        assert mgr.idles == 2
        woken = mgr.notify_added(2)
        assert sorted(woken) == [0, 1]
        assert mgr.resumes == 2

    def test_target_capped_at_owned_resources(self):
        """Regression: an oversubscribing predictor (the DLB Alg.-1
        variant) must not let a non-sharing pull-style frontend scale
        beyond what it owns."""
        m = TaskMonitor(min_samples=1)
        for i in range(3):
            m.on_task_ready(i, "t", 1.0)
            m.on_task_execute(i, "t", 1.0)
            m.on_task_completed(i, "t", 1.0, 50e-6)
        for i in range(10):                     # Δ would be 10
            m.on_task_ready(100 + i, "t", 1.0)
        pred = CPUPredictor(m, n_cpus=4, config=PredictionConfig(
            rate_s=50e-6, min_samples=1, allow_oversubscription=True,
            oversubscription_cap=4.0))
        pred.tick()
        assert pred.delta == 10                 # oversubscribed Δ
        pol = PredictionPolicy(pred)
        assert pol.target(queued=10, active=0, n_resources=4) == 4
        assert pol.target(queued=0, active=0, n_resources=4) == 0


@given(active=st.integers(0, 64), idle=st.integers(0, 64),
       ready=st.integers(0, 256), delta=st.integers(1, 64))
@settings(max_examples=200, deadline=None)
def test_prediction_resume_invariants(active, idle, ready, delta):
    """Property: resumes never exceed idle count, ready tasks, or Δ−δ."""
    pred = _predictor_with_delta(delta, n=64)
    pol = PredictionPolicy(pred)
    n = pol.workers_to_resume(active, idle, ready)
    assert 0 <= n <= idle
    assert n <= max(0, delta - active)
    assert n <= ready


def test_registry_factory():
    def build(name, **kw):
        return ResourceGovernor(GovernorSpec(resources=8, policy=name,
                                             **kw)).policy

    assert build("busy").name == "busy"
    assert build("idle").name == "idle"
    assert build("hybrid", spin_budget=5).spin_budget == 5
    pred_policy = build("prediction")
    assert pred_policy.uses_predictions
    assert pred_policy.predictor is not None   # governor supplied it
    for name in ("busy", "idle", "hybrid", "prediction",
                 "dlb-lewi", "dlb-hybrid", "dlb-prediction"):
        assert name in registered_policies()
