"""Pallas kernels vs pure-jnp oracles (interpret mode), swept over
shapes/dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)
pytestmark = pytest.mark.kernels


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,K,D", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 256, 4, 1, 128),     # MQA
    (2, 128, 2, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, S, H, K, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    o_ref = ref.attention_ref(q, k, v)
    o = ops.attention(q, k, v, impl="interpret", block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))
    o_ref = ref.attention_ref(q, k, v, window=window)
    o = ops.attention(q, k, v, window=window, impl="interpret",
                      block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64)) * 3
    k = jax.random.normal(ks[1], (1, 128, 2, 64)) * 3
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    o_ref = ref.attention_ref(q, k, v, softcap=20.0)
    o = ops.attention(q, k, v, softcap=20.0, impl="interpret",
                      block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_block_shape_independence():
    """Different tilings must give identical results."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    o1 = ops.attention(q, k, v, impl="interpret", block_q=64, block_k=64)
    o2 = ops.attention(q, k, v, impl="interpret", block_q=128,
                       block_k=256)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,H,S,N", [(1, 2, 64, 64), (2, 4, 128, 64)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6_shapes(B, H, S, N, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, H, S, N)) * 0.5
    k = jax.random.normal(ks[1], (B, H, S, N)) * 0.5
    v = jax.random.normal(ks[2], (B, H, S, N)) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, H, S, N)) - 1.0))
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    y_ref, s_ref = ref.wkv6_ref(r, k, v, w, u)
    y, s = ops.wkv(r, k, v, w, u, impl="interpret", chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_initial_state_continuation():
    """Chunked kernel over [0:S] == kernel over halves with carried
    state (exactness of the cross-chunk recurrence)."""
    B, H, S, N = 1, 2, 128, 64
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, H, S, N)) * 0.5
    k = jax.random.normal(ks[1], (B, H, S, N)) * 0.5
    v = jax.random.normal(ks[2], (B, H, S, N)) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, H, S, N)) - 1.0))
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    y_full, s_full = ops.wkv(r, k, v, w, u, impl="interpret")
    h = S // 2
    y1, s1 = ops.wkv(r[:, :, :h], k[:, :, :h], v[:, :, :h], w[:, :, :h],
                     u, impl="interpret")
    y2, s2 = ops.wkv(r[:, :, h:], k[:, :, h:], v[:, :, h:], w[:, :, h:],
                     u, s0=s1, impl="interpret")
    np.testing.assert_allclose(np.asarray(y_full[:, :, h:]),
                               np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_extreme_decay_stable():
    """Strong decay (w → 0) must not produce inf/nan (the clamp)."""
    B, H, S, N = 1, 1, 64, 64
    ks = jax.random.split(KEY, 4)
    r = jax.random.normal(ks[0], (B, H, S, N))
    k = jax.random.normal(ks[1], (B, H, S, N))
    v = jax.random.normal(ks[2], (B, H, S, N))
    w = jnp.full((B, H, S, N), 1e-6)         # near-total decay per step
    u = jnp.zeros((H, N))
    y, s = ops.wkv(r, k, v, w, u, impl="interpret")
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(s)).all()
    y_ref, _ = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,S,R,t_blk,r_blk", [
    (1, 128, 256, 64, 256),
    (2, 256, 512, 128, 128),
    (1, 64, 1024, 64, 512),
])
def test_rglru_shapes(B, S, R, t_blk, r_blk):
    ks = jax.random.split(KEY, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, R)))
    b = jax.random.normal(ks[1], (B, S, R)) * 0.1
    h_ref = ref.rglru_ref(a, b)
    h, hf = ops.rglru(a, b, impl="interpret", t_blk=t_blk, r_blk=r_blk)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_rglru_initial_state():
    B, S, R = 2, 64, 256
    ks = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, R)))
    b = jax.random.normal(ks[1], (B, S, R)) * 0.1
    h0 = jax.random.normal(ks[2], (B, R))
    h_full, _ = ops.rglru(a, b, impl="interpret", t_blk=32, r_blk=256)
    # continuation: run first half, carry, run second half
    h1, hf1 = ops.rglru(a[:, :32], b[:, :32], impl="interpret",
                        t_blk=32, r_blk=256)
    h2, _ = ops.rglru(a[:, 32:], b[:, 32:], hf1, impl="interpret",
                      t_blk=32, r_blk=256)
    np.testing.assert_allclose(np.asarray(h_full[:, 32:]),
                               np.asarray(h2), rtol=1e-5, atol=1e-5)


def test_model_uses_same_math_as_kernels():
    """The model-side chunked WKV (XLA path) equals the kernel and the
    scan reference — three-way agreement."""
    from repro.models.rwkv import wkv6_chunked
    B, H, S, N = 1, 2, 64, 64
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, H, S, N)) * 0.5
    k = jax.random.normal(ks[1], (B, H, S, N)) * 0.5
    v = jax.random.normal(ks[2], (B, H, S, N)) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, H, S, N)) - 1.0))
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    y1, s1 = ref.wkv6_ref(r, k, v, w, u)
    y2, s2 = wkv6_chunked(r, k, v, w, u)
    y3, s3 = ops.wkv(r, k, v, w, u, impl="interpret")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y3),
                               rtol=1e-4, atol=1e-4)
