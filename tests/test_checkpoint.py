"""Checkpointing: roundtrip, atomicity, GC, async, elastic re-shard."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, restore_checkpoint,
                              save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16), jnp.bfloat16),
                   "b": jnp.zeros((16,), jnp.float32)},
        "opt": {"mu": jnp.ones((8, 16)), "count": jnp.asarray(7)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 3, t)
    restored, step = restore_checkpoint(tmp_path, None, t)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert restored["params"]["w"].dtype == jnp.bfloat16


def test_atomic_commit_no_tmp_left(tmp_path):
    save_checkpoint(tmp_path, 1, _tree())
    assert not list(pathlib.Path(tmp_path).glob(".tmp*"))
    manifest = json.loads(
        (tmp_path / "step_000000001" / "manifest.json").read_text())
    assert manifest["step"] == 1


def test_manifest_uses_monotonic_clock(tmp_path):
    """Repo clock convention: perf_counter / virtual time, never wall
    clock.  Wall-clock epochs are ~1.7e9 s; perf_counter starts near 0
    at boot, and consecutive stamps must be monotonic."""
    import time

    lo = time.perf_counter()
    save_checkpoint(tmp_path, 1, _tree())
    save_checkpoint(tmp_path, 2, _tree())
    hi = time.perf_counter()
    m1 = json.loads((tmp_path / "step_000000001" /
                     "manifest.json").read_text())
    m2 = json.loads((tmp_path / "step_000000002" /
                     "manifest.json").read_text())
    assert lo <= m1["time"] <= m2["time"] <= hi
    # durable provenance: a labelled wall-clock stamp survives restarts
    assert m1["unix_time"] > 1e9


def test_manager_gc_keeps_last(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, _tree(s))
    steps = sorted(int(p.name.split("_")[1])
                   for p in pathlib.Path(tmp_path).glob("step_*"))
    assert steps == [3, 4]
    assert m.latest_step() == 4


def test_async_save(tmp_path):
    m = CheckpointManager(tmp_path)
    m.save(5, _tree(), blocking=False)
    m.wait()
    assert m.latest_step() == 5


def test_restore_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, _tree())
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, 1, {"just_one": jnp.zeros(3)})


def test_elastic_reshard_on_restore(tmp_path):
    """Restore with explicit (different) shardings — single-device here,
    but exercises the device_put re-shard path end-to-end."""
    t = _tree()
    save_checkpoint(tmp_path, 2, t)
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), t)
    restored, _ = restore_checkpoint(tmp_path, 2, t, shardings=shardings)
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding == jax.sharding.SingleDeviceSharding(dev)


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(tmp_path, None, _tree())
