"""Arrival processes: determinism, shapes, assignment."""

import pytest

from repro.runtime import Task, TaskGraph
from repro.workloads import (BurstArrivals, DiurnalArrivals, FixedTimeline,
                             PoissonArrivals, assign_release_times)


def assert_sorted(ts):
    assert all(b >= a for a, b in zip(ts, ts[1:]))


class TestProcesses:
    def test_poisson_seeded_and_reusable(self):
        p = PoissonArrivals(rate=100.0, seed=7)
        assert p.times(50) == p.times(50)       # same object, same times
        assert p.times(50) != PoissonArrivals(rate=100.0, seed=8).times(50)
        assert_sorted(p.times(50))
        assert all(t > 0 for t in p.times(50))

    def test_poisson_mean_rate(self):
        ts = PoissonArrivals(rate=1000.0, seed=0).times(2000)
        assert ts[-1] == pytest.approx(2.0, rel=0.15)   # n/rate seconds

    def test_burst_shape(self):
        b = BurstArrivals(burst_size=4, gap=1.0, spacing=0.0)
        ts = b.times(10)
        assert ts[:4] == [0.0] * 4              # first burst together
        assert ts[4:8] == [1.0] * 4             # next after the gap
        assert ts[8:] == [2.0] * 2
        assert b.times(10) == ts                # deterministic

    def test_burst_jitter_seeded(self):
        b = BurstArrivals(burst_size=2, gap=1.0, jitter=0.5, seed=3)
        assert b.times(20) == b.times(20)
        assert_sorted(b.times(20))

    def test_diurnal_rate_envelope_and_determinism(self):
        d = DiurnalArrivals(period=10.0, low_rate=1.0, high_rate=50.0,
                            seed=1)
        assert d.times(100) == d.times(100)
        assert_sorted(d.times(100))
        assert d.rate_at(0.0) == pytest.approx(1.0)          # trough
        assert d.rate_at(5.0) == pytest.approx(50.0)         # peak

    def test_fixed_timeline_pads_and_validates(self):
        f = FixedTimeline((0.0, 1.0, 2.0))
        assert f.times(5) == [0.0, 1.0, 2.0, 2.0, 2.0]
        assert f.times(2) == [0.0, 1.0]
        assert FixedTimeline(()).times(3) == [0.0, 0.0, 0.0]
        with pytest.raises(ValueError):
            FixedTimeline((1.0, 0.5))


class TestAssignment:
    def test_assign_release_times_stamps_tasks(self):
        g = TaskGraph()
        for _ in range(5):
            g.add(Task("w", service_time=1e-5))
        ts = assign_release_times(g, BurstArrivals(burst_size=2, gap=0.5))
        assert [t.release_time for t in g.tasks] == ts
        assert_sorted(ts)
        # None clears back to a closed graph
        assign_release_times(g, None)
        assert all(t.release_time is None for t in g.tasks)
