"""Model substrate: per-arch smoke + decode/forward parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_runnable, get_config, \
    get_smoke_config
from repro.models import (decode_step, forward, init_params,
                          lm_loss, prefill)

KEY = jax.random.PRNGKey(0)

# Whole-module: per-arch forward/decode/train-step sweeps dominate the
# suite's wall clock (~2 min of the ~3.5); CI's fast lane skips them
# (-m "not slow"), the tests-full job still runs everything.
pytestmark = pytest.mark.slow


def _batch(cfg, B=2, S=32):
    F = cfg.frontend_len
    toks = jax.random.randint(KEY, (B, S - F), 0, cfg.vocab)
    prefix = (jax.random.normal(KEY, (B, F, cfg.d_model), jnp.bfloat16)
              if F else None)
    return toks, prefix


@pytest.mark.parametrize("arch", list(ARCH_IDS))
class TestArchSmoke:
    def test_forward_shapes_no_nans(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(KEY, cfg)
        toks, prefix = _batch(cfg)
        logits, aux = forward(params, toks, cfg, prefix=prefix)
        assert logits.shape == (2, 32, cfg.padded_vocab())
        assert not np.isnan(np.asarray(logits, np.float32)).any()
        assert float(aux) >= 0.0

    def test_train_step_decreases_loss(self, arch):
        from repro.optim import AdamWConfig, adamw_init
        from repro.train.steps import StepConfig, make_train_step
        cfg = get_smoke_config(arch)
        params = init_params(KEY, cfg)
        opt = AdamWConfig(lr=5e-3)
        step_fn = jax.jit(make_train_step(cfg, None, opt,
                                          StepConfig(accum=2, warmup=1)))
        opt_state = adamw_init(params, opt)
        toks, prefix = _batch(cfg, B=4)
        F = cfg.frontend_len
        labels = jnp.concatenate(
            [jnp.full((4, F), -1, jnp.int32), toks], axis=1) if F else toks
        batch = {"tokens": toks.reshape(2, 2, -1),
                 "labels": labels.reshape(2, 2, -1)}
        if prefix is not None:
            batch["prefix"] = prefix.reshape(2, 2, F, -1)
        losses = []
        for i in range(5):
            params, opt_state, m = step_fn(
                params, opt_state, jnp.asarray(i, jnp.int32), batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses

    def test_long_500k_flags(self, arch):
        cfg = get_config(arch)
        ok, why = cell_runnable(cfg, SHAPES["long_500k"])
        expect = arch in ("mixtral-8x22b", "recurrentgemma-2b", "rwkv6-7b")
        assert ok == expect, (arch, why)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-9b",
                                  "mixtral-8x22b", "recurrentgemma-2b",
                                  "rwkv6-7b", "qwen1.5-110b"])
def test_decode_matches_forward(arch):
    """Prefill T tokens then decode the rest one-by-one: logits must
    match the full-sequence forward at every step — this pins the cache
    indexing, ring masking, RoPE positions and recurrent states.
    (capacity_factor is raised so MoE archs are dropless: capacity
    dropping legitimately differs between 24-token and 1-token calls.)"""
    cfg = get_smoke_config(arch).replace(frontend_len=0,
                                         capacity_factor=16.0)
    params = init_params(KEY, cfg)
    B, S, T = 2, 24, 16
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab)
    full_logits, _ = forward(params, toks, cfg)

    _, cache = prefill(params, toks[:, :T], cfg, max_len=S)
    for t in range(T, S):
        step_logits, cache = decode_step(
            params, toks[:, t], jnp.asarray(t, jnp.int32), cache, cfg)
        np.testing.assert_allclose(
            np.asarray(step_logits, np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=2e-2, atol=2e-2,
        )


def test_vector_pos_decode_matches_scalar():
    """Continuous-batching path: per-slot positions equal homogeneous
    decode when all slots share the position."""
    cfg = get_smoke_config("llama3.2-1b")
    params = init_params(KEY, cfg)
    B, T = 2, 8
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    _, c1 = prefill(params, toks, cfg, max_len=16)
    _, c2 = prefill(params, toks, cfg, max_len=16)
    l1, _ = decode_step(params, toks[:, -1], jnp.asarray(T, jnp.int32),
                        c1, cfg)
    l2, _ = decode_step(params, toks[:, -1],
                        jnp.full((B,), T, jnp.int32), c2, cfg)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=1e-5)


def test_ce_chunking_invariant():
    """Chunked CE == unchunked CE."""
    cfg = get_smoke_config("llama3.2-1b")
    params = init_params(KEY, cfg)
    toks, _ = _batch(cfg, B=2, S=32)
    l0 = lm_loss(params, toks, toks, cfg.replace(ce_seq_chunk=0))
    l1 = lm_loss(params, toks, toks, cfg.replace(ce_seq_chunk=8))
    assert float(l0) == pytest.approx(float(l1), rel=1e-4)


def test_moe_seq_chunking_invariant():
    """MoE sequence chunking changes capacity locality, not correctness
    of the dispatch math; with generous capacity results must agree."""
    cfg = get_smoke_config("mixtral-8x22b").replace(capacity_factor=8.0)
    params = init_params(KEY, cfg)
    toks, _ = _batch(cfg, B=2, S=32)
    l0, _ = forward(params, toks, cfg.replace(moe_seq_chunk=0))
    l1, _ = forward(params, toks, cfg.replace(moe_seq_chunk=16))
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_param_count_sanity():
    cfg = get_config("llama3.2-1b")
    total, active = cfg.param_count()
    assert total == active
    assert 1.1e9 < total < 1.6e9
    cfg = get_config("mixtral-8x22b")
    total, active = cfg.param_count()
    assert 1.2e11 < total < 1.6e11            # ~141B
    assert 3.0e10 < active < 4.5e10           # ~39B active
