"""Monitoring infrastructure (paper §3.1): EMA, workload accounting,
accuracy, parent–child subtraction."""

import math

from _hypothesis_compat import given, settings, st

from repro.core.monitoring import EMA, TaskMonitor


class TestEMA:
    def test_warmup_is_mean(self):
        e = EMA(decay=0.5, warmup=3)
        for v in (1.0, 2.0, 3.0):
            e.update(v)
        assert math.isclose(e.value, 2.0)

    def test_post_warmup_tracks_recent(self):
        e = EMA(decay=0.5, warmup=1)
        for v in [1.0] * 5 + [10.0] * 20:
            e.update(v)
        assert 9.0 < e.value <= 10.0

    @given(st.lists(st.floats(min_value=0.001, max_value=1e6),
                    min_size=1, max_size=200),
           st.floats(min_value=0.01, max_value=1.0))
    @settings(max_examples=200, deadline=None)
    def test_bounded_by_range(self, samples, decay):
        """EMA stays within [min, max] of its inputs — any decay."""
        e = EMA(decay=decay, warmup=4)
        for s in samples:
            e.update(s)
        assert min(samples) - 1e-9 <= e.value <= max(samples) + 1e-9

    @given(st.lists(st.floats(min_value=0.5, max_value=2.0),
                    min_size=8, max_size=64))
    @settings(max_examples=100, deadline=None)
    def test_reliability_monotone(self, samples):
        e = EMA()
        for i, s in enumerate(samples):
            e.update(s)
            assert e.reliable(i + 1)
            assert not e.reliable(i + 2)


class TestWorkloadAccounting:
    def test_lifecycle_conserves(self):
        m = TaskMonitor(min_samples=1)
        m.on_task_ready(1, "t", 10.0)
        m.on_task_ready(2, "t", 5.0)
        snap = dict((n, (w, mm)) for n, w, _a, mm, _r
                    in m.workload_snapshot())
        assert snap["t"] == (15.0, 2)
        m.on_task_execute(1, "t", 10.0)
        snap = m.workload_snapshot()[0]
        assert snap[1] == 15.0 and snap[3] == 2   # still live
        m.on_task_completed(1, "t", 10.0, elapsed=1.0)
        snap = m.workload_snapshot()[0]
        assert snap[1] == 5.0 and snap[3] == 1
        m.on_task_execute(2, "t", 5.0)
        m.on_task_completed(2, "t", 5.0, elapsed=0.5)
        assert m.workload_snapshot() == []
        assert m.completed_instances() == 2

    def test_unitary_cost_normalizes_across_sizes(self):
        """Tasks of different cost but equal per-unit speed share α."""
        m = TaskMonitor(min_samples=1)
        for tid, (cost, elapsed) in enumerate(
                [(10.0, 1.0), (20.0, 2.0), (40.0, 4.0)]):
            m.on_task_ready(tid, "gemm", cost)
            m.on_task_execute(tid, "gemm", cost)
            m.on_task_completed(tid, "gemm", cost, elapsed)
        assert math.isclose(m.unitary_cost("gemm"), 0.1, rel_tol=1e-9)

    def test_accuracy_perfect_prediction(self):
        m = TaskMonitor(min_samples=1)
        # seed α = 0.1 s/unit
        m.on_task_ready(0, "t", 10.0)
        m.on_task_execute(0, "t", 10.0)
        m.on_task_completed(0, "t", 10.0, 1.0)
        # next instance matches the prediction exactly
        m.on_task_ready(1, "t", 10.0)
        m.on_task_execute(1, "t", 10.0)
        m.on_task_completed(1, "t", 10.0, 1.0)
        rep = m.accuracy_report()
        assert rep.instances == 1
        assert math.isclose(rep.average_pct, 100.0)

    def test_accuracy_na_when_no_predictions(self):
        m = TaskMonitor(min_samples=100)    # α never reliable
        for tid in range(5):
            m.on_task_ready(tid, "t", 1.0)
            m.on_task_execute(tid, "t", 1.0)
            m.on_task_completed(tid, "t", 1.0, 1.0)
        assert m.accuracy_report().average_pct is None   # Table 2 "NA"

    def test_parent_child_subtraction(self):
        m = TaskMonitor(min_samples=1)
        # establish α = 1 s/unit
        m.on_task_ready(0, "p", 4.0)
        m.on_task_execute(0, "p", 4.0)
        m.on_task_completed(0, "p", 4.0, 4.0)
        # parent predicted 4 s; child runs 1.5 s
        m.on_task_ready(1, "p", 4.0)
        assert math.isclose(m._outstanding[1], 4.0)
        m.on_task_ready(2, "c", 1.0)
        m.on_task_execute(2, "c", 1.0)
        m.on_task_completed(2, "c", 1.0, 1.5, parent_id=1)
        assert math.isclose(m._outstanding[1], 2.5)

    @given(st.lists(st.tuples(st.floats(0.1, 100.0), st.floats(0.01, 10.0)),
                    min_size=1, max_size=50))
    @settings(max_examples=100, deadline=None)
    def test_snapshot_never_negative(self, tasks):
        """Property: live cost/instances never go negative through any
        ready→execute→complete sequence."""
        m = TaskMonitor()
        for tid, (cost, elapsed) in enumerate(tasks):
            m.on_task_ready(tid, "t", cost)
            m.on_task_execute(tid, "t", cost)
            m.on_task_completed(tid, "t", cost, elapsed)
            for _n, w, _a, mm, _r in m.workload_snapshot():
                assert w >= -1e-9 and mm >= 0
