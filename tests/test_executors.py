"""Executors: threaded correctness + simulator determinism & ordering."""

import random
import threading
import time

import pytest

from repro.core import ResourceBroker
from repro.runtime import (KNL, MN4, SimCluster, SimExecutor, SimJobSpec,
                           Task, TaskGraph, ThreadExecutor)
from repro.workloads import BurstArrivals, FixedTimeline, PoissonArrivals


def chain_graph(n=20, service=1e-5):
    g = TaskGraph()
    prev = None
    order = []
    for i in range(n):
        def fn(i=i):
            order.append(i)
        t = Task("link", cost=1.0, fn=fn, service_time=service)
        if prev is not None:
            t.depends_on(prev)
        g.add(t)
        prev = t
    return g, order


def diamond_graph():
    g = TaskGraph()
    log = []
    a = Task("a", fn=lambda: log.append("a"), service_time=1e-5)
    b = Task("b", fn=lambda: log.append("b"), service_time=1e-5).depends_on(a)
    c = Task("c", fn=lambda: log.append("c"), service_time=1e-5).depends_on(a)
    d = Task("d", fn=lambda: log.append("d"), service_time=1e-5)
    d.depends_on(b, c)
    for t in (a, b, c, d):
        g.add(t)
    return g, log


class TestThreadExecutor:
    @pytest.mark.parametrize("policy", ["busy", "idle", "hybrid",
                                        "prediction"])
    def test_chain_order_preserved(self, policy):
        g, order = chain_graph(30)
        rep = ThreadExecutor(4, policy=policy,
                             prediction_rate_s=1e-3).run(g)
        assert order == list(range(30))
        assert rep.tasks_completed == 30 or rep.accuracy is None

    def test_diamond_dependencies(self):
        g, log = diamond_graph()
        ThreadExecutor(3, policy="idle").run(g)
        assert log[0] == "a" and log[-1] == "d"
        assert set(log[1:3]) == {"b", "c"}

    def test_wide_parallel(self):
        g = TaskGraph()
        done = []
        for i in range(100):
            g.add(Task("w", fn=lambda i=i: done.append(i),
                       service_time=1e-6))
        rep = ThreadExecutor(8, policy="busy").run(g)
        assert sorted(done) == list(range(100))
        assert rep.makespan > 0

    @pytest.mark.parametrize("policy", ["busy", "idle"])
    def test_empty_graph_terminates(self, policy):
        """Regression: run(TaskGraph()) used to hang forever — shutdown
        was only triggered from the task-completion path."""
        result = {}

        def target():
            result["report"] = ThreadExecutor(2, policy=policy).run(
                TaskGraph())

        t = threading.Thread(target=target, daemon=True)
        t.start()
        t.join(timeout=10.0)
        assert not t.is_alive(), "empty-graph run() hung"
        assert result["report"].makespan == 0.0
        assert result["report"].tasks_completed == 0


class TestThreadExecutorOpen:
    def test_incremental_submit_and_close(self):
        ex = ThreadExecutor(3, policy="idle").start()
        done = []
        for i in range(4):
            ex.submit(Task("w", fn=lambda i=i: done.append(i)))
            time.sleep(0.005)           # empty phases between arrivals
        ex.submit([Task("w", fn=lambda: done.append(4)),
                   Task("w", fn=lambda: done.append(5))])
        rep = ex.close()
        assert sorted(done) == list(range(6))
        assert rep.makespan > 0

    def test_run_with_arrivals(self):
        g = TaskGraph()
        out = []
        for i in range(9):
            g.add(Task("w", cost=1.0, fn=lambda i=i: out.append(i)))
        rep = ThreadExecutor(2, policy="hybrid").run(
            g, arrivals=BurstArrivals(burst_size=3, gap=0.01))
        assert sorted(out) == list(range(9))
        # the arrival lulls stretch the makespan past two burst gaps
        assert rep.makespan >= 0.02

    def test_close_without_start_raises(self):
        with pytest.raises(RuntimeError, match="never started"):
            ThreadExecutor(2).close()

    def test_energy_epoch_is_start_not_construction(self):
        """Energy must integrate from start(), not __init__: an executor
        built ahead of its first submission would otherwise charge the
        whole construction-to-start gap at full SPIN power."""
        ex = ThreadExecutor(2, policy="busy")
        time.sleep(0.25)                  # gap before the run begins
        ex.start()
        ex.submit(Task("w", cost=1.0, fn=lambda: None))
        rep = ex.close()
        # 2 spinning cores over the run only: energy ≈ 2 × makespan,
        # nowhere near the 0.5 core-seconds of the pre-start gap
        assert rep.energy < 0.2
        assert rep.energy == pytest.approx(2 * rep.makespan, rel=0.5)


class TestSimExecutor:
    def test_deterministic(self):
        r1 = SimExecutor(MN4, policy="prediction", monitoring=True).run(
            chain_graph(50)[0])
        r2 = SimExecutor(MN4, policy="prediction", monitoring=True).run(
            chain_graph(50)[0])
        assert r1.makespan == r2.makespan
        assert r1.energy == r2.energy
        assert r1.resumes == r2.resumes

    def test_all_tasks_complete(self):
        g, _ = diamond_graph()
        rep = SimExecutor(KNL, policy="idle").run(g)
        assert rep.makespan > 0

    def test_serial_chain_time(self):
        """A chain cannot parallelize: makespan ≈ Σ service."""
        g, _ = chain_graph(100, service=1e-4)
        rep = SimExecutor(MN4, policy="busy").run(g)
        assert rep.makespan == pytest.approx(100 * 1e-4, rel=0.05)

    def test_wide_speedup(self):
        """Independent tasks parallelize over all cores."""
        g = TaskGraph()
        for _ in range(480):
            g.add(Task("w", cost=1.0, service_time=1e-3))
        rep = SimExecutor(MN4, policy="busy").run(g)
        assert rep.makespan == pytest.approx(480 * 1e-3 / 48, rel=0.05)

    def test_energy_ordering_idle_phase(self):
        """With a long low-parallelism phase: busy burns the most energy,
        idle the least; prediction sits between but close to idle
        (Fig. 1's story)."""
        def make():
            g = TaskGraph()
            prev = None
            for _ in range(200):             # serial chain on 48 cores
                t = Task("c", cost=1.0, service_time=2e-4)
                if prev is not None:
                    t.depends_on(prev)
                g.add(t)
                prev = t
            return g
        e = {}
        for pol in ("busy", "idle", "prediction"):
            e[pol] = SimExecutor(MN4, policy=pol, monitoring=True) \
                .run(make()).energy
        assert e["busy"] > e["prediction"] > e["idle"] * 0.9

    def test_knl_slower_per_core(self):
        g1, _ = chain_graph(50, service=1e-4)
        g2, _ = chain_graph(50, service=1e-4)
        t_mn4 = SimExecutor(MN4, policy="busy").run(g1).makespan
        t_knl = SimExecutor(KNL, policy="busy").run(g2).makespan
        assert t_knl > t_mn4 * 1.4           # 1/0.62 ≈ 1.61

    def test_reuse_does_not_mutate_spec(self):
        """Regression: run() used to store the graph on self.spec, so a
        reused SimExecutor carried state across runs."""
        ex = SimExecutor(MN4, policy="busy")
        g1, _ = chain_graph(10)
        ex.run(g1)
        assert len(ex.spec.graph) == 0        # per-run spec was a copy
        assert ex.spec.arrivals is None
        g2 = TaskGraph()
        for _ in range(5):
            g2.add(Task("w", cost=1.0, service_time=1e-5))
        rep = ex.run(g2, arrivals=FixedTimeline((0.0,) * 5))
        assert rep.tasks_completed == 5
        assert ex.spec.arrivals is None       # arrivals did not stick


class TestSimOpenWorkloads:
    def wide(self, n=120, service=1e-4):
        g = TaskGraph()
        for _ in range(n):
            g.add(Task("w", cost=1.0, service_time=service))
        return g

    @pytest.mark.parametrize("policy", ["busy", "idle", "hybrid",
                                        "prediction"])
    def test_burst_arrivals_terminate_and_complete(self, policy):
        """Termination = arrivals exhausted ∧ drained, through empty
        phases that leave the cluster fully idle between bursts."""
        rep = SimExecutor(MN4, policy=policy, monitoring=True).run(
            self.wide(), arrivals=BurstArrivals(burst_size=30, gap=0.05))
        assert rep.tasks_completed == 120
        # three full 50 ms lulls dominate the makespan
        assert rep.makespan >= 0.15

    def test_poisson_determinism(self):
        runs = [SimExecutor(MN4, policy="prediction", monitoring=True).run(
                    self.wide(), arrivals=PoissonArrivals(rate=2000.0,
                                                          seed=3))
                for _ in (0, 1)]
        assert runs[0].makespan == runs[1].makespan
        assert runs[0].energy == runs[1].energy
        assert runs[0].resumes == runs[1].resumes

    def test_idle_cheaper_than_busy_through_lulls(self):
        """The open-workload energy story: busy burns full power through
        every lull; idle parks and pays only resume overhead."""
        e = {}
        for pol in ("busy", "idle"):
            e[pol] = SimExecutor(MN4, policy=pol).run(
                self.wide(),
                arrivals=BurstArrivals(burst_size=30, gap=0.05)).energy
        assert e["busy"] > 2 * e["idle"]

    def test_release_times_honored(self):
        g = TaskGraph()
        for _ in range(4):
            g.add(Task("w", cost=1.0, service_time=1e-5))
        for t, rt in zip(g.tasks, (0.0, 0.01, 0.02, 0.03)):
            t.release_time = rt
        rep = SimExecutor(MN4, policy="busy").run(g)
        assert rep.makespan == pytest.approx(0.03 + 1e-5, rel=0.01)

    def test_dependencies_gate_after_release(self):
        """A dependent task released early still waits for its dep."""
        g = TaskGraph()
        a = g.add(Task("a", cost=1.0, service_time=0.02))
        b = g.add(Task("b", cost=1.0, service_time=1e-5).depends_on(a))
        a.release_time = None                 # at t=0
        b.release_time = 1e-3                 # released mid-flight of a
        rep = SimExecutor(MN4, policy="busy").run(g)
        assert rep.makespan == pytest.approx(0.02 + 1e-5, rel=0.01)


class TestSimDLB:
    def test_two_jobs_share(self):
        rng = random.Random(0)
        broker = ResourceBroker()
        cl = SimCluster(MN4, broker=broker)

        g1 = TaskGraph()        # bursty job: idle gaps lend CPUs
        prev = None
        for _ in range(20):
            t = Task("burst", cost=1.0, service_time=5e-4)
            if prev is not None:
                t.depends_on(prev)
            g1.add(t)
            prev = t
        g2 = TaskGraph()        # saturating job: wants more CPUs
        for _ in range(2000):
            g2.add(Task("sat", cost=1.0,
                        service_time=rng.uniform(4e-5, 6e-5)))
        cl.add_job(SimJobSpec(name="burst", graph=g1, policy="dlb-lewi",
                              cpus=list(range(24))))
        cl.add_job(SimJobSpec(name="sat", graph=g2, policy="dlb-lewi",
                              cpus=list(range(24, 48))))
        reps = cl.run()
        # the saturating job borrowed CPUs ⇒ faster than 24-core ideal
        ideal_24 = 2000 * 5e-5 / 24
        assert reps["sat"].makespan < ideal_24
        assert broker.total_calls > 0

    def test_prediction_fewer_calls_than_lewi(self):
        def run(policy):
            rng = random.Random(1)
            broker = ResourceBroker()
            cl = SimCluster(MN4, broker=broker)
            g1 = TaskGraph()
            prev = None
            for _ in range(30):
                wave = [Task("gs", cost=1.0,
                             service_time=rng.uniform(1e-4, 3e-4))
                        for _ in range(30)]
                for t in wave:
                    if prev is not None:
                        t.depends_on(prev)
                    g1.add(t)
                bar = Task("bar", cost=0.01, service_time=1e-6)
                for t in wave:
                    bar.depends_on(t)
                g1.add(bar)
                prev = bar
            g2 = TaskGraph()
            for _ in range(3000):
                g2.add(Task("st", cost=1.0,
                            service_time=rng.uniform(4e-5, 6e-5)))
            cl.add_job(SimJobSpec(name="g", graph=g1, policy=policy,
                                  cpus=list(range(24))))
            cl.add_job(SimJobSpec(name="s", graph=g2, policy=policy,
                                  cpus=list(range(24, 48))))
            cl.run()
            return broker.total_calls

        assert run("dlb-prediction") < run("dlb-lewi") / 2
