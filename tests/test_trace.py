"""Trace record/replay: the round-trip property and the exports.

The acceptance property: a closed graph executed on ``SimExecutor`` with
a ``TraceRecorder`` attached, replayed via ``TraceReplayer`` under the
same ``GovernorSpec``, reproduces the same per-policy decision sequence
and report.
"""

import json
import random

import pytest

from repro.core import EventBus, EventKind, GovernorSpec
from repro.runtime import MN4, SimExecutor, Task, TaskGraph, ThreadExecutor
from repro.trace import (TraceRecorder, TraceReplayer, decision_sequence,
                         prediction_sequence)
from repro.workloads import BurstArrivals


def mixed_graph(seed=0, n_waves=6, width=8):
    """Waves of parallel tasks separated by barriers — enough phase
    change to make every policy take real decisions."""
    rng = random.Random(seed)
    g = TaskGraph()
    prev = None
    for _ in range(n_waves):
        wave = [Task("wave", cost=1.0,
                     service_time=rng.uniform(5e-5, 2e-4))
                for _ in range(width)]
        for t in wave:
            if prev is not None:
                t.depends_on(prev)
            g.add(t)
        bar = Task("barrier", cost=0.1, service_time=1e-5)
        for t in wave:
            bar.depends_on(t)
        g.add(bar)
        prev = bar
    return g


@pytest.mark.parametrize("policy", ["busy", "idle", "hybrid", "prediction"])
def test_sim_round_trip_reproduces_run(policy):
    spec = GovernorSpec(resources=8, policy=policy, monitoring=True)
    ex = SimExecutor(MN4, spec=spec)
    rec = TraceRecorder(bus=ex.bus)
    r1 = ex.run(mixed_graph())

    replayer = TraceReplayer(rec)
    bus2 = EventBus()
    rec2 = TraceRecorder(bus=bus2)
    r2 = replayer.replay(spec, machine=TraceReplayer.replay_machine(MN4),
                         bus=bus2)

    assert r2.tasks_completed == r1.tasks_completed
    assert r2.makespan == pytest.approx(r1.makespan, rel=1e-12)
    assert r2.energy == pytest.approx(r1.energy, rel=1e-12)
    assert r2.resumes == r1.resumes
    assert r2.idles == r1.idles
    assert decision_sequence(rec2.events) == decision_sequence(rec.events)


def test_prediction_events_published():
    spec = GovernorSpec(resources=8, policy="prediction", monitoring=True)
    ex = SimExecutor(MN4, spec=spec)
    rec = TraceRecorder(bus=ex.bus)
    r = ex.run(mixed_graph())
    deltas = prediction_sequence(rec.events)
    assert len(deltas) == r.predictions
    assert all(isinstance(d, int) for d in deltas)


def test_open_trace_preserves_arrival_timeline():
    g = TaskGraph()
    for _ in range(20):
        g.add(Task("w", cost=1.0, service_time=1e-4))
    ex = SimExecutor(MN4, policy="idle")
    rec = TraceRecorder(bus=ex.bus)
    ex.run(g, arrivals=BurstArrivals(burst_size=5, gap=0.01))
    g2, arrivals = TraceReplayer(rec).build()
    assert arrivals is not None
    assert len(g2) == 20
    # bursts of 5 separated by 10 ms, recorded faithfully
    ts = arrivals.times(20)
    assert ts[0] == pytest.approx(0.0, abs=1e-9)
    assert ts[5] == pytest.approx(0.01, rel=1e-6)


def test_closed_trace_builds_closed_graph():
    ex = SimExecutor(MN4, policy="busy")
    rec = TraceRecorder(bus=ex.bus)
    ex.run(mixed_graph())
    g2, arrivals = TraceReplayer(rec).build()
    assert arrivals is None
    assert all(t.release_time is None for t in g2.tasks)
    # dependency structure survives: per-wave barriers exist
    barriers = [t for t in g2.tasks if t.type_name == "barrier"]
    assert len(barriers) == 6
    assert all(len(b.deps) == 8 for b in barriers)


def test_thread_trace_replays_in_sim():
    ex = ThreadExecutor(3, policy="idle")
    rec = TraceRecorder(bus=ex.bus)
    g = TaskGraph()
    for i in range(12):
        g.add(Task("w", cost=1.0, fn=lambda: None))
    r_live = ex.run(g)
    assert r_live.tasks_completed == 12 or r_live.accuracy is None
    spec = GovernorSpec(resources=3, policy="prediction", monitoring=True)
    r_sim = TraceReplayer(rec).replay(spec)
    assert r_sim.tasks_completed == 12
    assert r_sim.makespan > 0


def test_jsonl_round_trip(tmp_path):
    ex = SimExecutor(MN4, policy="hybrid", monitoring=True)
    rec = TraceRecorder(bus=ex.bus)
    r1 = ex.run(mixed_graph())
    path = rec.to_jsonl(tmp_path / "trace.jsonl")
    rec2 = TraceRecorder.from_jsonl(path)
    assert len(rec2.events) == len(rec.events)
    assert rec2.events[0] == rec.events[0]
    spec = GovernorSpec(resources=8, policy="hybrid", monitoring=True)
    r2 = TraceReplayer(path).replay(
        spec, machine=TraceReplayer.replay_machine(MN4))
    assert r2.makespan == pytest.approx(r1.makespan, rel=1e-12)


def test_chrome_export(tmp_path):
    ex = SimExecutor(MN4, policy="prediction", monitoring=True)
    rec = TraceRecorder(bus=ex.bus)
    r = ex.run(mixed_graph())
    path = rec.to_chrome(tmp_path / "trace.json")
    doc = json.loads(path.read_text())
    slices = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    counters = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert len(slices) == r.tasks_completed
    assert len(counters) == r.predictions
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in slices)


def test_out_of_order_submission_keeps_dependencies():
    """Open-mode submission order need not be topological: a dependent
    submitted before its dependency must keep the edge on replay."""
    ex = ThreadExecutor(2, policy="busy").start()
    rec = TraceRecorder(bus=ex.bus)
    a = Task("a", cost=1.0, fn=lambda: None)
    b = Task("b", cost=1.0, fn=lambda: None).depends_on(a)
    ex.submit(b)          # b first — blocked until a completes
    ex.submit(a)
    ex.close()
    g2, _ = TraceReplayer(rec).build()
    rebuilt_b = next(t for t in g2.tasks if t.type_name == "b")
    rebuilt_a = next(t for t in g2.tasks if t.type_name == "a")
    assert rebuilt_b.deps == [rebuilt_a]


def test_no_prediction_events_for_non_predictive_policies():
    """Thread-recorded busy/idle traces must match the simulator: no
    predictor ⇒ no PREDICTION events (the ticker still runs)."""
    ex = ThreadExecutor(2, policy="busy")
    rec = TraceRecorder(bus=ex.bus)
    g = TaskGraph()
    for _ in range(4):
        g.add(Task("w", cost=1.0, service_time=2e-3))
    rep = ex.run(g)
    assert rep.predictions == 0
    assert prediction_sequence(rec.events) == []


def test_pull_governor_publishes_prediction_on_target():
    """Pull-style frontends (autoscaler) have no tick loop: target()
    decisions are their prediction samples on the bus."""
    from repro.core import EventBus, ResourceGovernor, TaskMonitor

    bus = EventBus()
    rec = TraceRecorder(bus=bus)
    mon = TaskMonitor()
    gov = ResourceGovernor(
        GovernorSpec(resources=4, policy="prediction", monitoring=True),
        monitor=mon, bus=bus)
    gov.target(queued=3, active=1)
    gov.target(queued=0, active=0)
    assert len(prediction_sequence(rec.events)) == 2
    # ...but non-predictive policies stay silent, matching the sim
    rec.clear()
    gov2 = ResourceGovernor(GovernorSpec(resources=4, policy="busy"),
                            bus=bus)
    gov2.target(queued=3, active=1)
    assert prediction_sequence(rec.events) == []


def test_thread_executor_honors_prestamped_release_times():
    """Frontend parity: a graph carrying release_times (e.g. from a
    replayed trace) runs open on threads, like in the simulator."""
    g = TaskGraph()
    out = []
    for i in range(4):
        g.add(Task("w", cost=1.0, fn=lambda i=i: out.append(i)))
    for t, rt in zip(g.tasks, (0.0, 0.0, 0.03, 0.06)):
        t.release_time = rt
    rep = ThreadExecutor(2, policy="busy").run(g)
    assert sorted(out) == list(range(4))
    assert rep.makespan >= 0.06


def test_serving_sojourn_not_replayed_as_service_time():
    """A serving request's COMPLETED elapsed is its sojourn (queueing
    included); replay must use the EXECUTE→COMPLETED holding time."""
    from repro.core import RuntimeEvent

    events = [
        RuntimeEvent(kind=EventKind.TASK_SUBMITTED, time=0.0, task_id=1,
                     type_name="request", cost=4.0, data={"deps": []}),
        # admitted 2 s after submission, finished 1 s later: elapsed
        # publishes the 3 s sojourn, but the slot was held for 1 s
        RuntimeEvent(kind=EventKind.TASK_EXECUTE, time=2.0, task_id=1,
                     type_name="request", cost=4.0),
        RuntimeEvent(kind=EventKind.TASK_COMPLETED, time=3.0, task_id=1,
                     type_name="request", cost=4.0, elapsed=3.0),
    ]
    g, _ = TraceReplayer(events).build()
    assert g.tasks[0].service_time == pytest.approx(1.0)


def test_reused_executor_does_not_accumulate_subscribers():
    ex = SimExecutor(MN4, policy="prediction", monitoring=True)
    rec = TraceRecorder(bus=ex.bus)
    for _ in range(3):
        ex.run(mixed_graph(n_waves=2, width=2))
    # only the recorder remains subscribed; per-run monitors detached
    assert ex.bus.n_subscribers == 1
    assert len(rec.events) > 0


def test_recorder_attach_idempotent():
    ex = SimExecutor(MN4, policy="busy")
    rec = TraceRecorder(bus=ex.bus)
    rec.attach(ex.bus)                     # second attach is a no-op
    g = TaskGraph()
    g.add(Task("w", cost=1.0, service_time=1e-5))
    ex.run(g)
    g2, _ = TraceReplayer(rec).build()
    assert len(g2) == 1                    # not double-recorded


def test_unreplayable_trace_rejected():
    bus = EventBus()
    rec = TraceRecorder(bus=bus)
    from repro.core import RuntimeEvent
    bus.publish(RuntimeEvent(kind=EventKind.TASK_SUBMITTED, time=0.0,
                             task_id=1, type_name="t", cost=1.0,
                             data={"deps": []}))
    with pytest.raises(ValueError, match="never completed"):
        TraceReplayer(rec).build()
