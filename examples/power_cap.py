"""Dynamic machine conditions walkthrough — power caps and faults.

1. A facility power cap lands mid-run on an MN4 machine split between
   two co-tenants (Gauss-Seidel + STREAM).  Busy spins every core
   straight through the cap; the prediction policies have already
   parked or lent the surplus, so their summed draw sits under the
   budget with zero violation seconds.
2. Two cores die mid-run and one recovers.  In-flight tasks are
   re-queued, so the run completes either way — the cost is makespan.
3. The perturbed run round-trips through the trace recorder and
   replays byte-exactly.

    PYTHONPATH=src python examples/power_cap.py
"""

from repro.core import GovernorSpec, ResourceBroker
from repro.core.conditions import (ConditionTimeline, core_fail,
                                   core_recover, power_cap)
from repro.runtime import MN4, SimCluster, SimExecutor, SimJobSpec
from repro.trace import TraceRecorder, TraceReplayer
from repro.workloads import build_gauss_seidel, build_stream


def tenants(policy: str) -> list[SimJobSpec]:
    half = MN4.n_cores // 2
    return [
        SimJobSpec(name="gauss",
                   graph=build_gauss_seidel(steps=12, bi=8, bj=8,
                                            block_elems=300_000, seed=0),
                   policy=policy, cpus=list(range(half))),
        SimJobSpec(name="stream",
                   graph=build_stream(rounds=10, blocks=300, seed=1),
                   policy=policy, cpus=list(range(half, MN4.n_cores))),
    ]


def run_capped(policy: str, timeline: ConditionTimeline | None):
    broker = ResourceBroker() if policy.startswith("dlb-") else None
    cl = SimCluster(MN4, broker=broker, conditions=timeline)
    for spec in tenants(policy):
        cl.add_job(spec)
    reports = cl.run()
    makespan = max(r.makespan for r in reports.values())
    energy = sum(r.energy for r in reports.values())
    return makespan, energy, cl.machine_cap_violation_s


def main() -> None:
    # -- 1. machine-wide power cap --------------------------------------
    # anchor the cap to busy's healthy makespan so it lands while both
    # tenants are live — a curtailment order, not a boot-time constraint
    t_ref, _, _ = run_capped("busy", None)
    tl = ConditionTimeline([power_cap(0.55 * t_ref, 18.0)])
    print(f"18 W cap at t={0.55 * t_ref * 1e3:.1f} ms "
          f"(busy healthy makespan {t_ref * 1e3:.1f} ms):")
    for policy in ("busy", "dlb-lewi", "prediction", "dlb-prediction"):
        mk, energy, violation = run_capped(policy, tl)
        print(f"  {policy:>16}: makespan={mk * 1e3:6.1f} ms  "
              f"EDP={energy * mk:.3f}  over-cap={violation * 1e3:.1f} ms")

    # -- 2. core faults: graceful degradation ---------------------------
    faults = ConditionTimeline([core_fail(0.2 * t_ref, 0),
                                core_fail(0.3 * t_ref, 1),
                                core_recover(0.7 * t_ref, 0)])
    for policy in ("busy", "prediction"):
        healthy, _, _ = run_capped(policy, None)
        hurt, _, _ = run_capped(policy, faults)
        print(f"two cores die, one recovers ({policy}): "
              f"{healthy * 1e3:.1f} ms -> {hurt * 1e3:.1f} ms "
              f"({100 * (hurt / healthy - 1):+.1f}%), all tasks done")

    # -- 3. perturbed runs replay byte-exactly --------------------------
    spec = GovernorSpec(resources=MN4.n_cores, policy="prediction",
                        monitoring=True)
    ex = SimExecutor(MN4, spec=spec, conditions=tl)
    rec = TraceRecorder(bus=ex.bus)
    original = ex.run(build_gauss_seidel(steps=12, bi=8, bj=8,
                                         block_elems=300_000, seed=0))
    fired = TraceReplayer(rec).conditions()
    replayed = TraceReplayer(rec).replay(spec)
    assert replayed.tasks_completed == original.tasks_completed
    print(f"\ntrace round trip: {len(fired)} perturbation(s) recorded, "
          f"{replayed.tasks_completed} tasks replayed byte-exact")


if __name__ == "__main__":
    main()
