"""Trace record/replay walkthrough — record once, what-if everywhere.

1. Run an *open* workload (bursty arrivals) on the real threaded
   executor with a :class:`TraceRecorder` on its event bus.
2. Export the trace: JSONL (replayable) + Chrome JSON (load it in
   chrome://tracing or https://ui.perfetto.dev).
3. Replay the recorded workload — same tasks, same measured durations,
   same arrival timeline — deterministically in the simulator under
   every registered closed-loop policy, and compare the reports.

    PYTHONPATH=src python examples/replay_trace.py
"""

import tempfile
from pathlib import Path

from repro.core import GovernorSpec
from repro.runtime import Task, TaskGraph, ThreadExecutor
from repro.trace import TraceRecorder, TraceReplayer
from repro.workloads import BurstArrivals


def busy_work(n: int = 20_000) -> None:
    sum(i * i for i in range(n))


def main() -> None:
    # -- 1. record a real run -------------------------------------------
    graph = TaskGraph()
    for _ in range(24):
        graph.add(Task("compute", cost=1.0, fn=busy_work))
    executor = ThreadExecutor(4, policy="idle")
    recorder = TraceRecorder(bus=executor.bus)
    live = executor.run(graph,
                        arrivals=BurstArrivals(burst_size=6, gap=0.05))
    print(f"live run: {live.tasks_completed} tasks in "
          f"{live.makespan*1e3:.1f} ms ({len(recorder)} events recorded)")

    # -- 2. export ------------------------------------------------------
    out = Path(tempfile.mkdtemp(prefix="repro-trace-"))
    jsonl = recorder.to_jsonl(out / "run.jsonl")
    chrome = recorder.to_chrome(out / "run.chrome.json")
    print(f"wrote {jsonl}\nwrote {chrome}  (open in chrome://tracing)")

    # -- 3. what-if replay in the simulator -----------------------------
    replayer = TraceReplayer(jsonl)
    rebuilt, timeline = replayer.build()
    print(f"\nrebuilt {len(rebuilt)} tasks "
          f"({'open timeline' if timeline else 'closed graph'})")
    print(f"\n{'policy':12s} {'time_ms':>9s} {'energy':>8s} {'EDP':>10s} "
          f"{'resumes':>8s}")
    for policy in ("busy", "idle", "hybrid", "prediction"):
        spec = GovernorSpec(resources=4, policy=policy, monitoring=True)
        r = replayer.replay(spec)
        print(f"{policy:12s} {r.makespan*1e3:9.1f} {r.energy:8.3f} "
              f"{r.edp:10.5f} {r.resumes:8d}")


if __name__ == "__main__":
    main()
