"""Quickstart — the paper's contribution in one page.

Builds the Gauss-Seidel task graph (barrier per step ⇒ load imbalance),
runs it under the four resource-management policies on the MN4 machine
model, and prints the performance/energy/EDP table (paper Figs. 3-4).
Then repeats the paper's Table 3 experiment: Gauss-Seidel + STREAM
sharing cores through the DLB broker, with and without predictions.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import GovernorSpec, ResourceBroker
from repro.runtime import MN4, SimCluster, SimExecutor, SimJobSpec
from repro.workloads import build_gauss_seidel, build_stream


def policy_table() -> None:
    print("=== policies × Gauss-Seidel (MN4, 48 cores) ===")
    print(f"{'policy':12s} {'time_ms':>9s} {'energy':>8s} {'EDP':>10s} "
          f"{'resumes':>8s}")
    for policy in ("busy", "idle", "hybrid", "prediction"):
        g = build_gauss_seidel(steps=30, seed=0)
        spec = GovernorSpec(resources=MN4.n_cores, policy=policy,
                            monitoring=True)
        r = SimExecutor(MN4, spec=spec).run(g)
        print(f"{policy:12s} {r.makespan*1e3:9.1f} {r.energy:8.2f} "
              f"{r.edp:10.4f} {r.resumes:8d}")


def sharing_table() -> None:
    print("\n=== DLB sharing: Gauss-Seidel + STREAM (24+24 cores) ===")
    print(f"{'policy':16s} {'gauss_ms':>9s} {'stream_ms':>10s} "
          f"{'DLB calls':>10s}")
    for policy in ("dlb-lewi", "dlb-hybrid", "dlb-prediction"):
        broker = ResourceBroker()
        cl = SimCluster(MN4, broker=broker)
        cl.add_job(SimJobSpec(name="gauss",
                              graph=build_gauss_seidel(steps=20, seed=0),
                              policy=policy, cpus=list(range(24))))
        cl.add_job(SimJobSpec(name="stream",
                              graph=build_stream(rounds=10, seed=1),
                              policy=policy, cpus=list(range(24, 48))))
        reps = cl.run()
        print(f"{policy:16s} {reps['gauss'].makespan*1e3:9.1f} "
              f"{reps['stream'].makespan*1e3:10.1f} "
              f"{broker.total_calls:10d}")


if __name__ == "__main__":
    policy_table()
    sharing_table()
