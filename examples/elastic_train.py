"""Fault-tolerance scenario: elastic data parallelism with a node
failure mid-run.

A 4-replica training job loses replica 2 at step 10: the controller
shrinks the set, re-balances the global batch over survivors, training
continues from the same parameters (no restart needed), and a checkpoint
restore proves state durability.

    PYTHONPATH=src python examples/elastic_train.py
"""

import tempfile

import numpy as np

from repro.configs import get_smoke_config
from repro.train.elastic import ElasticController
from repro.train.steps import StepConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    cfg = get_smoke_config("llama3.2-1b")
    with tempfile.TemporaryDirectory() as tmp:
        tcfg = TrainerConfig(steps=0, global_batch=8, seq_len=64,
                             checkpoint_dir=tmp, checkpoint_every=5,
                             log_every=10, step=StepConfig(accum=1,
                                                           warmup=5))
        tr = Trainer(cfg, tcfg)
        ctl = ElasticController(max_replicas=4, global_batch=8)
        print(f"replicas: {ctl.set.replicas}  shards: {ctl.set.shards()}")

        tr.run(10)                       # healthy phase
        print(f"step 10 loss {tr.history[-1]['loss']:.4f} — "
              f"replica 2 FAILS")
        new_set = ctl.fail_replica(2, step=10)
        print(f"replicas: {new_set.replicas}  shards: {new_set.shards()}")
        assert sum(new_set.shards().values()) == 8   # batch conserved

        tr.run(10)                       # degraded but training
        print(f"step 20 loss {tr.history[-1]['loss']:.4f} — "
              f"restore-from-checkpoint drill")

        tr2 = Trainer(cfg, tcfg)
        assert tr2.maybe_restore()
        print(f"restored at step {tr2.step}; continuing 5 steps")
        tr2.run(5)
        losses = [h["loss"] for h in tr2.history]
        print(f"post-restore losses: {np.round(losses, 4)}")
        tr.close()
        tr2.close()
        print("elastic shrink + restart drill complete")


if __name__ == "__main__":
    main()
