"""Multi-node cluster walkthrough — placement, locality, migration.

1. Build a 3-node cluster of MN4 machines and co-schedule four apps,
   comparing demand-blind round-robin placement against the arbiter's
   prediction-driven best-fit-decreasing.
2. Relax the locality guard so a saturated app borrows cores across
   nodes, paying the remote penalty and network transfers.
3. Migrate an app to a free node with the explicit costed verb.

    PYTHONPATH=src python examples/multi_node.py
"""

from repro.core import GovernorSpec
from repro.runtime import (MN4, ClusterModel, SimCluster, SimJobSpec,
                           predicted_demand, run_multi_node)
from repro.workloads import (build_gauss_seidel, build_hpccg,
                             build_multisaxpy)


def app_graphs():
    return {
        "saxpyA": build_multisaxpy(grain="coarse", generations=10,
                                   blocks=96, block_elems=400_000,
                                   seed=0),
        "gauss": build_gauss_seidel(steps=4, bi=8, bj=8,
                                    block_elems=150_000, seed=1),
        "saxpyB": build_multisaxpy(grain="coarse", generations=10,
                                   blocks=96, block_elems=400_000,
                                   seed=2),
        "hpccg": build_hpccg(iterations=4, blocks=24,
                             rows_per_block=16_384, seed=3),
    }


def main() -> None:
    cluster = ClusterModel.symmetric(MN4, 2)
    print(f"cluster: {cluster.n_nodes} nodes, {cluster.n_cores} cores, "
          f"remote penalty x{cluster.penalty(0, 1):.2f}, "
          f"transfer {cluster.transfer_time(0, 1)*1e6:.0f} us/edge")

    # -- 1. placement: demand-driven vs round-robin ---------------------
    demands = {name: predicted_demand(
        SimJobSpec(name=name, graph=g, policy="busy"))
        for name, g in app_graphs().items()}
    print("\npredicted per-app demand (mean parallelism):",
          {k: round(v, 1) for k, v in demands.items()})

    for placement in ("round-robin", "predicted"):
        specs = [SimJobSpec(name=name, graph=g, policy="dlb-prediction")
                 for name, g in app_graphs().items()]
        rep = run_multi_node(cluster, specs, placement=placement)
        print(f"{placement:>12}: homes={rep.placement}  "
              f"makespan={rep.makespan*1e3:.1f} ms  "
              f"aggregate EDP={rep.aggregate_edp:.4f}")

    # -- 2. remote borrowing: relax the locality guard ------------------
    # min_borrow_speed defaults to 1.0: on a homogeneous cluster every
    # remote core is penalty-slower than an own core, so the guard
    # refuses all of them.  A throughput-bound app can opt in.
    gov = GovernorSpec(resources=MN4.n_cores, policy="dlb-prediction",
                       min_borrow_speed=0.0)
    graphs = app_graphs()
    specs = [SimJobSpec(name=name, graph=graphs[name], governor=gov)
             for name in ("saxpyA", "hpccg")]
    rep = run_multi_node(ClusterModel.symmetric(MN4, 2), specs,
                         placement="predicted")
    sax = rep.apps["saxpyA"]
    print(f"\nguard relaxed: saxpyA borrowed across nodes -> "
          f"{sax.transfers} transfers, "
          f"{sax.transfer_seconds*1e3:.2f} ms on the wire, "
          f"refusals={sax.sharing['guard_refusals']}")

    # -- 3. migration: the explicit costed verb -------------------------
    two = ClusterModel.symmetric(MN4, 2)
    sim = SimCluster(two)
    sim.add_job(SimJobSpec(name="gauss", graph=build_gauss_seidel(
        steps=4, bi=8, bj=8, block_elems=150_000, seed=1),
        policy="prediction", node=0))
    sim.migrate_job("gauss", 1)          # each core pays migration_latency
    report = sim.run()["gauss"]
    print(f"\nmigrated gauss to node {report.node} "
          f"({report.migrations} migration), "
          f"makespan={report.makespan*1e3:.1f} ms")


if __name__ == "__main__":
    main()
