"""Serving scenario: continuous batching under a bursty arrival trace,
comparing busy / idle / prediction autoscaling (the paper's policies at
replica granularity).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import AutoScaler, Request, ServingEngine


def run_policy(policy: str, cfg, params) -> dict:
    engine = ServingEngine(cfg, params, max_batch=4, max_len=96)
    scaler = AutoScaler(engine.monitor, max_replicas=4, policy=policy,
                        bus=engine.bus)
    rng = np.random.default_rng(0)
    bursts = {0: 5, 60: 5, 120: 5}
    reqs, deltas, replica_ticks, tick = [], [], 0, 0
    t0 = time.perf_counter()
    while tick < 400 and (tick < 180 or engine.load):
        for _ in range(bursts.get(tick, 0)):
            prompt = rng.integers(0, cfg.vocab, size=8).tolist()
            reqs.append(engine.submit(Request(prompt=prompt,
                                              max_new_tokens=10)))
        d = scaler.target(len(engine.queue),
                          sum(r is not None for r in engine.active))
        deltas.append(d)
        replica_ticks += d
        engine.tick()
        tick += 1
    wall = time.perf_counter() - t0
    lat = [r.done_at - r.submitted_at for r in reqs if r.done]
    return {
        "policy": policy,
        "tok/s": engine.tokens_out / wall,
        "p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "replica_ticks": replica_ticks,      # energy proxy
        "delta_trace": deltas[:12],
    }


def main() -> None:
    cfg = get_smoke_config("llama3.2-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"{'policy':12s} {'tok/s':>8s} {'p50_ms':>8s} "
          f"{'replica·ticks':>14s}")
    for policy in ("busy", "idle", "prediction"):
        r = run_policy(policy, cfg, params)
        print(f"{r['policy']:12s} {r['tok/s']:8.1f} {r['p50_ms']:8.0f} "
              f"{r['replica_ticks']:14d}   Δ={r['delta_trace']}")


if __name__ == "__main__":
    main()
