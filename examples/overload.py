"""Serving under overload walkthrough — SLO admission, retries/hedging,
brownout, and what happens without them.

1. A diurnal ramp whose peak overshoots capacity, with a facility power
   cap landing mid-run: the protected prediction stack sheds infeasible
   work at arrival, brownouts best-effort traffic, shrinks its
   hot-replica allowance to the cap (zero violation seconds) — and
   still beats the unprotected FIFO baseline on p99, attainment and
   aggregate EDP.
2. A straggling replica: the hedged duplicate wins and the loser is
   cancelled; a failing replica trips its circuit breaker, is
   quarantined, and re-admitted through half-open probes.
3. The protected run round-trips through the trace recorder and
   replays byte-exactly.

    PYTHONPATH=src python examples/overload.py
"""

from repro.core.conditions import (ConditionTimeline, core_fail,
                                   core_recover, power_cap, straggler)
from repro.core.events import EventBus
from repro.runtime import MN4, MachineModel
from repro.serving import (SLOClass, ServingModel, SimRequest,
                           SimServing, build_requests, replay_serving)
from repro.trace import TraceRecorder
from repro.workloads.arrivals import DiurnalArrivals

N = 100_000
CAPACITY = 395.0   # MN4: 192 slots / ~0.49 s mean service


def diurnal_scenario(protection: bool):
    """Two day/night cycles peaking at 1.6x capacity; a 30 W cap lands
    during the first peak and lifts on the second climb."""
    low, high = 0.25 * CAPACITY, 1.60 * CAPACITY
    span = N / ((low + high) / 2.0)
    process = DiurnalArrivals(period=span / 2.0, low_rate=low,
                              high_rate=high, seed=7)
    timeline = ConditionTimeline([power_cap(0.35 * span, 30.0),
                                  power_cap(0.70 * span, None)])
    sim = SimServing(ServingModel(machine=MN4),
                     build_requests(process, N, seed=7),
                     policy="prediction" if protection else "idle",
                     protection=protection, conditions=timeline, seed=7)
    return sim.run().report("protected" if protection else "baseline")


def main() -> None:
    # -- 1. overload + power cap: protection on vs off ------------------
    print(f"{N} requests, diurnal ramp to 1.6x capacity, 30 W cap "
          "mid-run (MN4, 48 replicas):")
    for protection in (True, False):
        rep = diurnal_scenario(protection)
        s = rep.serving
        stack = "prediction+protect" if protection else "FIFO baseline"
        print(f"  {stack:>18}: attainment={s['attainment']:.3f}  "
              f"p50={s['p50_ms']:7.0f} ms  p99={s['p99_ms']:7.0f} ms  "
              f"shed={s['shed']:5d}  EDP={rep.edp:10.0f}  "
              f"over-cap={rep.cap_violation_s:.1f} s")

    # -- 2. hedging + circuit breaker on sick silicon --------------------
    duo = ServingModel(machine=MachineModel(name="duo", n_cores=2),
                       slots_per_replica=1)
    slo = SLOClass("hedgy", deadline_s=60.0, timeout_s=50.0,
                   hedge_after_s=0.2)
    sick = ConditionTimeline([straggler(0.0, core=0, slowdown=20.0)])
    sim = SimServing(duo, [SimRequest(rid=0, release=0.0, prompt=160,
                                      new=80, slo=slo)],
                     policy="busy", conditions=sick).run()
    s = sim.report("hedge").serving
    r = sim.requests[0]
    print(f"\nreplica 0 straggles 20x: hedge fired after 0.2 s and won "
          f"({s['hedge_wins']}/{s['hedges']}), done at t={r.done_at:.2f} s"
          f" (primary alone needed 10.8 s); loser cancelled")

    dead = ConditionTimeline([core_fail(0.3, core=0),
                              core_recover(5.0, core=0)])
    sim = SimServing(duo, [SimRequest(rid=0, release=0.0, prompt=160,
                                      new=160,
                                      slo=SLOClass("std", deadline_s=60.0,
                                                   timeout_s=50.0))],
                     policy="busy", conditions=dead).run()
    s = sim.report("breaker").serving
    print(f"replica 0 dies mid-attempt: breaker quarantines it, the "
          f"attempt requeues uncharged (requeues={s['requeues']}, "
          f"retries={s['retries']}) and completes on replica 1")

    # -- 3. byte-exact trace round trip ----------------------------------
    model = ServingModel(machine=MN4)
    reqs = build_requests(DiurnalArrivals(period=10.0, low_rate=100.0,
                                          high_rate=500.0, seed=3),
                          2000, seed=3)
    tl = ConditionTimeline([power_cap(2.0, 30.0), power_cap(6.0, None)])
    bus = EventBus()
    rec = TraceRecorder(bus)
    SimServing(model, reqs, conditions=tl, bus=bus, seed=3).run()
    bus2 = EventBus()
    rec2 = TraceRecorder(bus2)
    replay_serving(rec.merged_events(), model, bus=bus2, seed=3).run()
    assert [e.to_dict() for e in rec.merged_events()] \
        == [e.to_dict() for e in rec2.merged_events()]
    print(f"\ntrace round trip: {len(rec.events)} events recorded, "
          "rebuilt from the trace alone, replayed byte-exact")


if __name__ == "__main__":
    main()
