"""End-to-end training driver: a ~100M-parameter llama-family model
trained for a few hundred steps on synthetic data, with checkpointing
and restart support.

    PYTHONPATH=src python examples/train_lm.py             # full run
    PYTHONPATH=src python examples/train_lm.py --tiny      # CI-sized

Interrupt it and re-run: it restores from the last checkpoint and
reproduces the uninterrupted loss curve exactly (deterministic data +
bitwise checkpoints).
"""

import argparse

from repro.models.config import LayerKind, ModelConfig
from repro.train.steps import StepConfig
from repro.train.trainer import Trainer, TrainerConfig

#: ~103M params: 12L × d512 (8 heads, GQA kv=4) + 32k vocab
MODEL_100M = ModelConfig(
    name="example-100m",
    n_layers=12, d_model=512, n_heads=8, kv_heads=4, d_ff=2048,
    vocab=32_000, head_dim=64,
    pattern=(LayerKind.ATTN,),
    remat="none",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="2-layer width-64 config for smoke testing")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = MODEL_100M.replace(n_layers=2, d_model=64, n_heads=4,
                             kv_heads=2, head_dim=16, d_ff=256,
                             vocab=512) if args.tiny else MODEL_100M
    total, _ = cfg.param_count()
    print(f"model: {cfg.name} ({total/1e6:.1f}M params)")
    tcfg = TrainerConfig(
        steps=args.steps if not args.tiny else min(args.steps, 30),
        global_batch=8, seq_len=256 if not args.tiny else 64,
        checkpoint_dir=args.checkpoint_dir, checkpoint_every=50,
        log_every=10, step=StepConfig(accum=2, warmup=20))
    tr = Trainer(cfg, tcfg)
    if tr.maybe_restore():
        print(f"restored checkpoint at step {tr.step}")
    try:
        hist = tr.run()
        print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    finally:
        tr.close()


if __name__ == "__main__":
    main()
