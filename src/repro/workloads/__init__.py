"""The paper's benchmarks as task graphs (§4):

* Cholesky factorization — coarse (C) and fine (F) grained tiled DAG
* HPCCG — CG mini-app (SpMV / dot / axpy per iteration)
* Gauss-Seidel — heat diffusion, barrier per time step (load imbalance)
* MultiSAXPY — BLAS-1 SAXPY blocks, coarse and fine
* STREAM — memory-transfer triad, highly parallel and balanced

Each builder returns a :class:`~repro.runtime.task.TaskGraph` whose tasks
carry a *cost clause* value (the paper's normalization input), a virtual
``service_time`` for the simulator, and optionally a real numpy payload
for the threaded executor.
"""

from .arrivals import (ArrivalProcess, BurstArrivals, DiurnalArrivals,
                       FixedTimeline, PoissonArrivals, assign_release_times)
from .cholesky import build_cholesky
from .hpccg import build_hpccg
from .gauss_seidel import build_gauss_seidel
from .multisaxpy import build_multisaxpy
from .stream import build_stream

WORKLOADS = {
    "cholesky-fine": lambda **kw: build_cholesky(grain="fine", **kw),
    "cholesky-coarse": lambda **kw: build_cholesky(grain="coarse", **kw),
    "hpccg": build_hpccg,
    "gauss-seidel": build_gauss_seidel,
    "multisaxpy-fine": lambda **kw: build_multisaxpy(grain="fine", **kw),
    "multisaxpy-coarse": lambda **kw: build_multisaxpy(grain="coarse", **kw),
    "stream": build_stream,
}

__all__ = ["build_cholesky", "build_hpccg", "build_gauss_seidel",
           "build_multisaxpy", "build_stream", "WORKLOADS",
           "ArrivalProcess", "BurstArrivals", "DiurnalArrivals",
           "FixedTimeline", "PoissonArrivals", "assign_release_times"]
