"""STREAM — memory-bandwidth triad, highly parallel and balanced.

Independent ``a ← b + s·c`` block tasks, repeated for ``rounds`` rounds
(block-wise chained like the reference STREAM loop).  Used concurrently
with Gauss-Seidel in the paper's DLB experiments: STREAM soaks up the CPUs
Gauss-Seidel cannot use at the tail of each wavefront step.
"""

from __future__ import annotations

import random

from ..runtime.task import Task, TaskGraph
from .common import memory_time

__all__ = ["build_stream"]


def build_stream(rounds: int = 40, blocks: int = 750,
                 block_elems: int = 131_072, seed: int = 0,
                 with_payload: bool = False) -> TaskGraph:
    rng = random.Random(seed)
    g = TaskGraph()
    nbytes = block_elems * 8.0 * 3

    payload = None
    if with_payload:
        import numpy as np
        b = np.ones(block_elems)
        c = np.ones(block_elems)

        def payload():  # noqa: ANN202
            (b + 2.0 * c).sum()

    for r in range(rounds):
        for blk in range(blocks):
            t = Task("triad", cost=nbytes / 1e6, fn=payload,
                     service_time=memory_time(nbytes, rng, jitter=0.05))
            g.add(t, in_=[("a", blk)], out=[("a", blk)])
    return g
