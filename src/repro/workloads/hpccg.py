"""HPCCG — High Performance Computing Conjugate Gradients mini-app.

CG over a 3D chimney domain decomposed into row blocks.  Each iteration:

    SpMV   q ← A·p        (one task per row block; memory-bound)
    DOT    α ← pᵀq        (block partials + one reduction task)
    AXPY   x ← x + αp ; r ← r − αq   (one task per block)
    DOT    β ← rᵀr        (block partials + reduction)
    AXPY   p ← r + βp

The reductions serialize the iteration (the low-parallelism phases the
prediction policy exploits).  Paper Table 2 reports 15 000 instances: 75
iterations × 40 blocks × (2 SpMV-ish + 2 axpy + 1 partial) ≈ 15 k.
"""

from __future__ import annotations

import random

from ..runtime.task import Task, TaskGraph
from .common import memory_time

__all__ = ["build_hpccg"]


def build_hpccg(iterations: int = 75, blocks: int = 40,
                rows_per_block: int = 16_384, seed: int = 0,
                with_payload: bool = False) -> TaskGraph:
    rng = random.Random(seed)
    g = TaskGraph()
    nnz_per_row = 27                      # 3D 27-point stencil
    spmv_bytes = rows_per_block * nnz_per_row * 12.0   # val + col idx
    vec_bytes = rows_per_block * 8.0

    payload = None
    if with_payload:
        import numpy as np
        a = np.ones(4096)

        def payload():  # noqa: ANN202
            (a * 1.0001).sum()

    def task(kind: str, nbytes: float, in_: list, out: list) -> Task:
        t = Task(kind, cost=nbytes / 1e6, fn=payload,
                 service_time=memory_time(nbytes, rng))
        return g.add(t, in_=in_, out=out)

    for it in range(iterations):
        for b in range(blocks):
            # SpMV reads the halo of p (dep on previous p-update barrier)
            task("spmv", spmv_bytes, in_=[("p", b)], out=[("q", b)])
        for b in range(blocks):
            task("dot_partial", 2 * vec_bytes,
                 in_=[("p", b), ("q", b)], out=[("pq", b)])
        task("reduce", blocks * 16.0,
             in_=[("pq", b) for b in range(blocks)], out=["alpha"])
        for b in range(blocks):
            task("axpy", 3 * vec_bytes,
                 in_=["alpha", ("q", b)], out=[("x", b), ("r", b)])
        for b in range(blocks):
            task("dot_partial", vec_bytes, in_=[("r", b)], out=[("rr", b)])
        task("reduce", blocks * 16.0,
             in_=[("rr", b) for b in range(blocks)], out=["beta"])
        for b in range(blocks):
            task("axpy", 2 * vec_bytes,
                 in_=["beta", ("r", b)], out=[("p", b)])
    return g
