"""Tiled Cholesky factorization task graph (right-looking variant).

The classic four-kernel DAG over a ``p × p`` grid of ``b × b`` tiles:

    for k in 0..p-1:
        POTRF A[k,k]
        for i in k+1..p-1:          TRSM  A[i,k] ← A[k,k]
        for i in k+1..p-1:          SYRK  A[i,i] ← A[i,k]
        for i,j (k<j<i):            GEMM  A[i,j] ← A[i,k], A[j,k]

Dependencies are expressed through the in/out *data tokens* of
:class:`~repro.runtime.task.TaskGraph` — exactly how OmpSs-2 users write
it.  Cost clauses are the kernel flop counts (the natural ``cost`` filler
an application developer knows): POTRF b³/3, TRSM b³, SYRK b³, GEMM 2 b³.

* **coarse** (paper: 600 instances): p=14, b=2048 → 560 tasks, each
  O(10 ms).  Too few instances per type for timing predictions — the
  paper's count-based fallback engages (Table 2 shows "NA").
* **fine** (paper: 3·10⁶ instances): p scaled so tasks are O(10 µs).
"""

from __future__ import annotations

import random

from ..runtime.task import Task, TaskGraph
from .common import compute_time

__all__ = ["build_cholesky", "cholesky_task_count"]


def cholesky_task_count(p: int) -> int:
    return p + 2 * (p * (p - 1) // 2) + p * (p - 1) * (p - 2) // 6


def build_cholesky(grain: str = "coarse", p: int | None = None,
                   tile: int | None = None, seed: int = 0,
                   with_payload: bool = False) -> TaskGraph:
    if grain == "coarse":
        p = 14 if p is None else p          # 560 tasks ≈ paper's 600
        tile = 2048 if tile is None else tile
    elif grain == "fine":
        p = 40 if p is None else p          # 10 660 tasks (scaled-down 3e6)
        tile = 384 if tile is None else tile  # ~1.6 ms GEMM-unit tasks
    else:
        raise ValueError(f"grain must be coarse|fine, got {grain!r}")
    rng = random.Random(seed)
    g = TaskGraph()
    b3 = float(tile) ** 3

    payload = None
    if with_payload:
        import numpy as np
        n = min(tile, 64)
        mat = np.eye(n) * n + np.ones((n, n))

        def payload():  # noqa: ANN202 - tiny numpy kernel stand-in
            np.linalg.cholesky(mat)

    def add(kind: str, flops: float, in_: list, out: list) -> Task:
        t = Task(kind, cost=flops / 1e6, fn=payload,
                 service_time=compute_time(flops, rng))
        g.add(t, in_=in_, out=out)
        return t

    for k in range(p):
        add("potrf", b3 / 3, in_=[], out=[(k, k)])
        for i in range(k + 1, p):
            add("trsm", b3, in_=[(k, k)], out=[(i, k)])
        for i in range(k + 1, p):
            add("syrk", b3, in_=[(i, k)], out=[(i, i)])
            for j in range(k + 1, i):
                add("gemm", 2 * b3, in_=[(i, k), (j, k)], out=[(i, j)])
    return g
