"""Gauss-Seidel heat diffusion with a barrier per time step.

The OmpSs-2 version in the paper inserts a barrier after each time step to
match the OpenMP structure — "this produces load imbalance but makes it an
ideal candidate to be combined with STREAM".  Within a step the blocks
form a wavefront (block (i,j) depends on (i-1,j) and (i,j-1) of the same
step), so parallelism ramps 1 → min(bi,bj) → 1: the tail of each step
leaves most CPUs without work.

Paper Table 2: 25 600 instances — e.g. 100 steps × 16×16 blocks.
"""

from __future__ import annotations

import random

from ..runtime.task import Task, TaskGraph
from .common import memory_time

__all__ = ["build_gauss_seidel"]


def build_gauss_seidel(steps: int = 100, bi: int = 16, bj: int = 16,
                       block_elems: int = 1024 * 1024, seed: int = 0,
                       with_payload: bool = False) -> TaskGraph:
    rng = random.Random(seed)
    g = TaskGraph()
    nbytes = block_elems * 8.0 * 2          # read + write the block

    payload = None
    if with_payload:
        import numpy as np
        a = np.ones(block_elems // 64)

        def payload():  # noqa: ANN202
            (a * 0.25).sum()

    prev_barrier: Task | None = None
    for s in range(steps):
        wave: list[Task] = []
        for i in range(bi):
            for j in range(bj):
                t = Task("gs_block", cost=nbytes / 1e6, fn=payload,
                         service_time=memory_time(nbytes, rng))
                deps_in = [("blk", i - 1, j)] if i > 0 else []
                if j > 0:
                    deps_in.append(("blk", i, j - 1))
                if prev_barrier is not None:
                    t.depends_on(prev_barrier)
                g.add(t, in_=deps_in, out=[("blk", i, j)])
                wave.append(t)
        barrier = Task("barrier", cost=0.01, service_time=5e-7,
                       fn=(lambda: None) if with_payload else None)
        for t in wave:
            barrier.depends_on(t)
        g.add(barrier, out=[("blk", i, j) for i in range(bi)
                            for j in range(bj)])
        prev_barrier = barrier
    return g
