"""Shared helpers for workload builders.

Service times derive from per-task flop/byte counts over nominal per-core
rates; multiplicative jitter (seeded, deterministic) models the run-to-run
variability the paper's EMA smoothing is designed to absorb.
"""

from __future__ import annotations

import random

#: nominal per-core compute rate for compute-bound tasks (MN4 Skylake-ish)
CORE_GFLOPS = 35.0
#: nominal per-core memory bandwidth for memory-bound tasks
CORE_GBS = 5.0


def compute_time(flops: float, rng: random.Random,
                 jitter: float = 0.15) -> float:
    """Seconds for a compute-bound task of ``flops`` on one core."""
    base = flops / (CORE_GFLOPS * 1e9)
    return base * rng.uniform(1.0 - jitter, 1.0 + jitter)


def memory_time(bytes_moved: float, rng: random.Random,
                jitter: float = 0.2) -> float:
    """Seconds for a memory-bound task moving ``bytes_moved``."""
    base = bytes_moved / (CORE_GBS * 1e9)
    return base * rng.uniform(1.0 - jitter, 1.0 + jitter)
