"""MultiSAXPY — repeated blocked SAXPY (BLAS-1) generations.

Each generation runs one SAXPY task per block followed by a ``taskwait``
(as in the OmpSs-2 reference benchmark), so parallelism ramps down to zero
at every generation boundary — the fine-grained churn the paper's policies
differ on.  Fine-grained: many small blocks (paper: 10⁵ instances);
coarse: fewer, larger blocks (paper: 2·10⁴).
"""

from __future__ import annotations

import random

from ..runtime.task import Task, TaskGraph
from .common import memory_time

__all__ = ["build_multisaxpy"]


def build_multisaxpy(grain: str = "coarse", generations: int | None = None,
                     blocks: int | None = None,
                     block_elems: int | None = None, seed: int = 0,
                     with_payload: bool = False) -> TaskGraph:
    if grain == "fine":
        generations = 250 if generations is None else generations
        blocks = 400 if blocks is None else blocks          # 100 000 tasks
        block_elems = 409_600 if block_elems is None else block_elems  # ~1 ms
    elif grain == "coarse":
        generations = 50 if generations is None else generations
        blocks = 400 if blocks is None else blocks          # 20 000 tasks
        block_elems = 2_097_152 if block_elems is None else block_elems  # ~5 ms
    else:
        raise ValueError(f"grain must be coarse|fine, got {grain!r}")
    rng = random.Random(seed)
    g = TaskGraph()
    nbytes = block_elems * 4.0 * 3          # y ← a·x + y (2 reads, 1 write)

    payload = None
    if with_payload:
        import numpy as np
        x = np.ones(block_elems, dtype=np.float32)
        y = np.zeros(block_elems, dtype=np.float32)

        def payload():  # noqa: ANN202
            y.__iadd__(2.0 * x)

    prev_wait: Task | None = None
    for gen in range(generations):
        wave: list[Task] = []
        for b in range(blocks):
            t = Task("saxpy", cost=nbytes / 1e6, fn=payload,
                     service_time=memory_time(nbytes, rng))
            if prev_wait is not None:
                t.depends_on(prev_wait)
            g.add(t, in_=[("y", b)], out=[("y", b)])
            wave.append(t)
        taskwait = Task("taskwait", cost=0.01, service_time=5e-7,
                        fn=(lambda: None) if with_payload else None)
        for t in wave:
            taskwait.depends_on(t)
        g.add(taskwait)
        prev_wait = taskwait
    return g
