"""Arrival processes — open-workload release timelines.

The paper's premise is parallelism that *varies over time*, yet a closed
graph submitted at t=0 only exercises the shapes the DAG itself encodes.
An :class:`ArrivalProcess` generates the release times of an open
workload — bursts, lulls, diurnal ramps — so prediction/idle policies are
stress-tested through empty-then-bursty phases (the serving story of the
ROADMAP at task granularity).

All processes are explicitly seeded and wall-clock-free: ``times(n)``
builds a fresh ``random.Random(seed)`` every call, so the same process
object can be reused across runs/policies and always yields the same
timeline (the property the policy benchmarks rely on).
"""

from __future__ import annotations

import math
import random
from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Iterable, Sequence

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from ..runtime.task import Task

__all__ = [
    "ArrivalProcess",
    "FixedTimeline",
    "PoissonArrivals",
    "BurstArrivals",
    "DiurnalArrivals",
    "assign_release_times",
]


class ArrivalProcess(ABC):
    """Generates monotone non-decreasing release times (virtual seconds)."""

    @abstractmethod
    def times(self, n: int) -> list[float]:
        """Release times for ``n`` submissions, sorted ascending."""

    def assign(self, tasks: Iterable["Task"]) -> list[float]:
        """Stamp ``release_time`` onto ``tasks`` in order; returns times."""
        tasks = list(tasks)
        ts = self.times(len(tasks))
        for task, t in zip(tasks, ts):
            task.release_time = t
        return ts


@dataclass(frozen=True)
class FixedTimeline(ArrivalProcess):
    """Explicit release times (e.g. replayed from a recorded trace).

    If fewer times than tasks are given, the last time is repeated (the
    tail arrives together); an empty timeline releases everything at 0.
    """

    release_times: Sequence[float] = field(default_factory=tuple)

    def __post_init__(self) -> None:
        ts = tuple(self.release_times)
        if any(b < a for a, b in zip(ts, ts[1:])):
            raise ValueError("release_times must be non-decreasing")
        object.__setattr__(self, "release_times", ts)

    def times(self, n: int) -> list[float]:
        ts = list(self.release_times[:n])
        if len(ts) < n:
            last = ts[-1] if ts else 0.0
            ts += [last] * (n - len(ts))
        return ts


@dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Memoryless arrivals at ``rate`` tasks/second from ``start``."""

    rate: float
    seed: int = 0
    start: float = 0.0

    def __post_init__(self) -> None:
        if self.rate <= 0:
            raise ValueError(f"rate must be > 0, got {self.rate}")

    def times(self, n: int) -> list[float]:
        rng = random.Random(self.seed)
        t = self.start
        out = []
        for _ in range(n):
            t += rng.expovariate(self.rate)
            out.append(t)
        return out


@dataclass(frozen=True)
class BurstArrivals(ArrivalProcess):
    """On/off process: ``burst_size`` tasks ``spacing`` apart, then an
    off-phase ``gap`` before the next burst — the shape that makes idle
    policies pay resume latency at every burst front and busy policies
    burn energy through every lull."""

    burst_size: int
    gap: float
    spacing: float = 0.0
    seed: int = 0
    jitter: float = 0.0   # ± fraction of gap/spacing, seeded

    def __post_init__(self) -> None:
        if self.burst_size < 1:
            raise ValueError("burst_size must be >= 1")
        if self.gap < 0 or self.spacing < 0:
            raise ValueError("gap and spacing must be >= 0")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError("jitter must be in [0, 1)")

    def times(self, n: int) -> list[float]:
        rng = random.Random(self.seed)

        def j(base: float) -> float:
            if self.jitter == 0.0 or base == 0.0:
                return base
            return base * rng.uniform(1.0 - self.jitter, 1.0 + self.jitter)

        out: list[float] = []
        t = 0.0
        in_burst = 0
        for _ in range(n):
            out.append(t)
            in_burst += 1
            if in_burst >= self.burst_size:
                t += j(self.gap)
                in_burst = 0
            else:
                t += j(self.spacing)
        return out


@dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Nonhomogeneous Poisson with a sinusoidal rate ramp:

        rate(t) = low + (high - low) · (1 + sin(2πt/period - π/2)) / 2

    (starts at the ``low`` trough, peaks at ``period/2``) — the diurnal
    load shape of a user-facing service, via Lewis thinning."""

    period: float
    low_rate: float
    high_rate: float
    seed: int = 0

    def __post_init__(self) -> None:
        if self.period <= 0:
            raise ValueError("period must be > 0")
        if not 0 < self.low_rate <= self.high_rate:
            raise ValueError("need 0 < low_rate <= high_rate")

    def rate_at(self, t: float) -> float:
        phase = (1.0 + math.sin(2.0 * math.pi * t / self.period
                                - math.pi / 2.0)) / 2.0
        return self.low_rate + (self.high_rate - self.low_rate) * phase

    def times(self, n: int) -> list[float]:
        rng = random.Random(self.seed)
        out: list[float] = []
        t = 0.0
        while len(out) < n:
            t += rng.expovariate(self.high_rate)
            if rng.random() <= self.rate_at(t) / self.high_rate:
                out.append(t)
        return out


def assign_release_times(graph, process: ArrivalProcess | None,
                         ) -> list[float]:
    """Stamp a graph's tasks with ``process`` release times (in task
    order) and return them; a ``None`` process clears release times
    (closed-world graph)."""
    if process is None:
        for t in graph.tasks:
            t.release_time = None
        return [0.0] * len(graph.tasks)
    return process.assign(graph.tasks)
