"""jit-able step functions: train (loss→grad→clip→AdamW) and serve
(one decode token, greedy).

The train step consumes a *microbatched* batch ``(accum, micro_B, S)`` and
scans over the accumulation dimension, so activation residuals are bounded
by the microbatch while the gradient all-reduce (DP) happens once — the
standard large-scale arrangement.  Gradients accumulate in
``cfg.grad_dtype`` (f32 default; bf16 for the 400B config to fit HBM).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from ..models import ModelConfig, Rules, decode_step, lm_loss
from ..optim import AdamWConfig, adamw_update, clip_by_global_norm, \
    cosine_warmup

__all__ = ["StepConfig", "make_train_step", "make_serve_step"]


@dataclass(frozen=True)
class StepConfig:
    accum: int = 1                 # gradient-accumulation steps
    grad_dtype: str = "float32"    # accumulation dtype
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000
    #: int8-quantize gradients (with error feedback) before the DP
    #: all-reduce / optimizer — opt_state must carry an "ef" tree
    compress: bool = False


def make_train_step(cfg: ModelConfig, rules: Rules | None,
                    opt_cfg: AdamWConfig, step_cfg: StepConfig):
    """Returns ``train_step(params, opt_state, step, batch) ->
    (params, opt_state, metrics)``.

    ``batch``: {"tokens": (A, B, S_tok) i32, "labels": (A, B, S) i32
    [, "prefix": (A, B, F, d) bf16]} — A = accumulation steps.
    """
    gdt = jnp.dtype(step_cfg.grad_dtype)

    def loss_fn(params, tokens, labels, prefix):
        return lm_loss(params, tokens, labels, cfg, rules, prefix=prefix)

    def train_step(params, opt_state, step, batch):
        prefix_all = batch.get("prefix")

        if step_cfg.accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(
                params, batch["tokens"][0], batch["labels"][0],
                None if prefix_all is None else prefix_all[0])
            grads = jax.tree.map(lambda g: g.astype(gdt), grads)
        else:
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, gdt), params)

            def micro(carry, xs):
                g_acc, l_acc = carry
                if prefix_all is None:
                    toks, labs = xs
                    pfx = None
                else:
                    toks, labs, pfx = xs
                l, g = jax.value_and_grad(loss_fn)(params, toks, labs, pfx)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(gdt), g_acc, g)
                return (g_acc, l_acc + l), None

            xs = (batch["tokens"], batch["labels"]) if prefix_all is None \
                else (batch["tokens"], batch["labels"], prefix_all)
            (grads, loss), _ = lax.scan(
                micro, (zero, jnp.zeros((), jnp.float32)), xs)
            inv = 1.0 / step_cfg.accum
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss * inv

        ef_new = None
        if step_cfg.compress:
            from .compression import compress_grads
            grads, ef_new = compress_grads(grads, opt_state["ef"])
        grads, gnorm = clip_by_global_norm(grads, step_cfg.clip_norm)
        lr_scale = cosine_warmup(step, warmup=step_cfg.warmup,
                                 total=step_cfg.total_steps)
        adam_state = {k: v for k, v in opt_state.items() if k != "ef"}
        params, adam_state = adamw_update(grads, adam_state, params,
                                          opt_cfg, lr_scale)
        if ef_new is not None:
            adam_state["ef"] = ef_new
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr_scale": lr_scale}
        return params, adam_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, rules: Rules | None):
    """Returns ``serve_step(params, token, pos, cache) ->
    (next_token, cache)`` — one greedy decode step."""

    def serve_step(params, token, pos, cache):
        logits, cache = decode_step(params, token, pos, cache, cfg, rules)
        # Mask the padded vocab tail before argmax.
        Vp = logits.shape[-1]
        if Vp != cfg.vocab:
            neg = jnp.full((Vp - cfg.vocab,), -jnp.inf, logits.dtype)
            logits = logits.at[..., cfg.vocab:].set(neg)
        return jnp.argmax(logits, axis=-1).astype(token.dtype), cache

    return serve_step
