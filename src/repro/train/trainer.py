"""End-to-end trainer: data pipeline → jit'd step → checkpoint/restart,
with straggler monitoring, elastic hooks and optional gradient
compression.  This is what ``examples/train_lm.py`` and
``python -m repro.launch.train`` drive.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..data import SyntheticLM
from ..models import ModelConfig, Rules, init_params
from ..optim import AdamWConfig, adamw_init
from .compression import init_error_feedback
from .steps import StepConfig, make_train_step
from .straggler import StragglerMonitor

__all__ = ["TrainerConfig", "Trainer"]


@dataclass
class TrainerConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    checkpoint_dir: str | None = None
    checkpoint_every: int = 50
    log_every: int = 10
    seed: int = 0
    compress: bool = False
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    step: StepConfig = field(default_factory=StepConfig)


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 rules: Rules | None = None) -> None:
        self.cfg = cfg
        self.tcfg = tcfg
        key = jax.random.PRNGKey(tcfg.seed)
        self.params = init_params(key, cfg)
        self.opt_state = adamw_init(self.params, tcfg.opt)
        step_cfg = tcfg.step
        if tcfg.compress:
            step_cfg = StepConfig(**{**step_cfg.__dict__,
                                     "compress": True})
            self.opt_state["ef"] = init_error_feedback(self.params)
        self.step = 0
        self.straggler = StragglerMonitor()
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir) \
            if tcfg.checkpoint_dir else None
        self._step = make_train_step(cfg, rules, tcfg.opt, step_cfg)
        self._jit_step = jax.jit(self._step, donate_argnums=(0, 1))
        self.data = SyntheticLM(
            vocab=cfg.vocab, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, accum=tcfg.step.accum,
            frontend_len=cfg.frontend_len, d_model=cfg.d_model,
            seed=tcfg.seed)
        self.history: list[dict] = []

    # -- restart ----------------------------------------------------------

    def maybe_restore(self) -> bool:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        state, step = self.ckpt.restore(state)
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step = step
        return True

    # -- main loop -----------------------------------------------------------

    def run(self, steps: int | None = None) -> list[dict]:
        steps = steps if steps is not None else self.tcfg.steps
        target = self.step + steps
        while self.step < target:
            batch_np = next(self.data)
            batch = {"tokens": jnp.asarray(batch_np.tokens),
                     "labels": jnp.asarray(batch_np.labels)}
            if batch_np.prefix is not None:
                batch["prefix"] = jnp.asarray(batch_np.prefix,
                                              jnp.bfloat16)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._jit_step(
                self.params, self.opt_state,
                jnp.asarray(self.step, jnp.int32), batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.straggler.observe(0, dt)
            self.step += 1
            rec = {"step": self.step, "loss": loss, "dt": dt,
                   "grad_norm": float(metrics["grad_norm"])}
            self.history.append(rec)
            if self.step % self.tcfg.log_every == 0:
                print(f"step {self.step:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)")
            if (self.ckpt is not None
                    and self.step % self.tcfg.checkpoint_every == 0):
                self.ckpt.save(self.step,
                               {"params": self.params,
                                "opt": self.opt_state},
                               blocking=False)
        if self.ckpt is not None:
            self.ckpt.wait()
        return self.history

    def close(self) -> None:
        self.data.close()
