"""int8 gradient compression with error feedback.

For the DP gradient all-reduce: each leaf is quantized to int8 with a
per-leaf fp32 scale before the reduce (4× wire reduction vs f32, 2× vs
bf16) and dequantized after; the quantization residual is carried in an
*error-feedback* buffer added to the next step's gradient, which keeps
SGD/Adam convergence unbiased in the long run (Karimireddy et al. 2019).

``compress_grads`` is jit-compatible — inserted between the microbatch
accumulation and the optimizer, so under pjit the all-reduce GSPMD emits
moves int8.  ``tests/test_compression.py`` checks quantization error
bounds and EF accumulation; the roofline win shows in §Perf (collective
term ÷4 for DP-dominant cells).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_error_feedback", "compress_grads", "quantize_int8",
           "dequantize_int8"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127) \
        .astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, ef):
    """Returns (compressed-then-decompressed grads, new error feedback)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
