"""Straggler detection — the monitoring infrastructure's per-worker EMAs
applied to step times.

A worker whose EMA'd step time exceeds ``threshold ×`` the median of the
fleet is flagged; the trainer drains it (its data shard is re-assigned —
same mechanics as an elastic shrink) and optionally re-admits it after
``cooldown`` healthy probes.  At 1000+ nodes this is the difference
between fleet throughput tracking the median machine vs. the slowest.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..core.monitoring import EMA

__all__ = ["StragglerMonitor"]


@dataclass
class StragglerMonitor:
    threshold: float = 1.5
    min_samples: int = 4
    cooldown: int = 3
    _emas: dict[int, EMA] = field(default_factory=dict)
    _cool: dict[int, int] = field(default_factory=dict)
    drained: set[int] = field(default_factory=set)

    def observe(self, worker: int, step_time: float) -> None:
        self._emas.setdefault(worker, EMA(decay=0.3, warmup=2)) \
            .update(step_time)
        if worker in self.drained:
            # probe while drained: count healthy observations
            if not self.is_straggler(worker):
                self._cool[worker] = self._cool.get(worker, 0) + 1
                if self._cool[worker] >= self.cooldown:
                    self.drained.discard(worker)
                    self._cool.pop(worker, None)
            else:
                self._cool[worker] = 0

    def median(self) -> float | None:
        vals = sorted(e.value for e in self._emas.values()
                      if e.reliable(self.min_samples))
        if not vals:
            return None
        return vals[len(vals) // 2]

    def is_straggler(self, worker: int) -> bool:
        med = self.median()
        e = self._emas.get(worker)
        if med is None or e is None or not e.reliable(self.min_samples):
            return False
        return e.value > self.threshold * med

    def mark(self, worker: int) -> None:
        """Externally flag a worker (e.g. a machine-conditions
        ``STRAGGLER`` perturbation observed by the runtime): drained
        immediately, re-admitted through the usual cooldown."""
        self.drained.add(worker)
        self._cool[worker] = 0

    def sweep(self) -> set[int]:
        """Flag-and-drain pass; returns newly drained workers."""
        new = set()
        for w in self._emas:
            if w not in self.drained and self.is_straggler(w):
                self.drained.add(w)
                self._cool[w] = 0
                new.add(w)
        return new
