"""Training tier: step functions, the elastic controller (the paper's
predictor applied to DP replica scaling), straggler mitigation, and
gradient compression."""

from .steps import StepConfig, make_train_step, make_serve_step

__all__ = ["StepConfig", "make_train_step", "make_serve_step"]
