"""Elastic data parallelism driven by the paper's predictor.

The controller treats DP replicas the way the paper's CPU manager treats
cores: the *workload* is the backlog of pending global batches (each a
task with cost = tokens), α is the EMA'd per-replica step time, and
Algorithm 1 yields the replica count Δ for the next window.  Alg. 2's
poll/add hooks become ``on_step_done`` / ``on_batches_queued``.

Node failures are forced shrinks: the failed replica leaves the set and
the global batch is re-balanced over survivors (batch size per replica
grows; the gradient all-reduce group shrinks).  Growth re-admits
replicas up to Δ.  ``tests/test_elastic.py`` exercises shrink/regrow and
loss continuity across a failure.

Fault tolerance wiring (machine conditions): the controller can carry a
:class:`~repro.train.straggler.StragglerMonitor` (per-replica step-time
EMAs; flagged replicas are drained out of the active set and re-admitted
after the cooldown) and a
:class:`~repro.checkpoint.CheckpointManager`.  A ``CORE_FAIL``
perturbation mid-run (:meth:`apply_perturbation` /
:meth:`recover_from_failure`) shrinks to the survivors and rolls the
training state back to the latest checkpoint, so the trainer completes
with the surviving workers instead of dying with the core.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING

from ..core.conditions import Perturbation, PerturbationKind
from ..core.governor import GovernorSpec, ResourceGovernor
from ..core.prediction import PredictionConfig
from .straggler import StragglerMonitor

if TYPE_CHECKING:
    from ..checkpoint import CheckpointManager

__all__ = ["ElasticController", "ReplicaSet"]


@dataclass
class ReplicaSet:
    """Active replica ids + the batch split they own."""

    replicas: list[int]
    global_batch: int

    def shards(self) -> dict[int, int]:
        n = len(self.replicas)
        base = self.global_batch // n
        extra = self.global_batch % n
        return {r: base + (1 if i < extra else 0)
                for i, r in enumerate(self.replicas)}


class ElasticController:
    def __init__(self, max_replicas: int, global_batch: int,
                 policy: str = "prediction", rate_s: float = 1.0,
                 min_replicas: int = 1,
                 spec: GovernorSpec | None = None,
                 straggler: StragglerMonitor | None = None,
                 checkpoint: "CheckpointManager | None" = None) -> None:
        if spec is None:
            spec = GovernorSpec(
                resources=max_replicas, policy=policy,
                min_resources=min_replicas,
                prediction=PredictionConfig(rate_s=rate_s),
                monitoring=True)
        self.spec = spec
        self.max_replicas = spec.resources
        self.min_replicas = max(spec.min_resources, 1)
        self.policy = spec.policy
        self.governor = ResourceGovernor(spec)
        self.monitor = self.governor.monitor
        self.predictor = self.governor.predictor
        self.set = ReplicaSet(list(range(self.max_replicas)), global_batch)
        self.failed: set[int] = set()
        self._task_seq = 0
        self.resizes: list[tuple[int, int]] = []   # (step, new_count)
        self.straggler = straggler
        self.checkpoint = checkpoint
        self.restores: list[tuple[int, int]] = []  # (fail step, resume step)

    # -- workload hooks (Alg. 2's POLL/ADD analogues) -----------------------

    def on_batches_queued(self, n: int, tokens_per_batch: float) -> None:
        for _ in range(n):
            self._task_seq += 1
            self.monitor.on_task_ready(self._task_seq, "global_batch",
                                       tokens_per_batch)
            self.monitor.on_task_execute(self._task_seq, "global_batch",
                                         tokens_per_batch)

    def on_step_done(self, task_id_offset: int, tokens: float,
                     elapsed: float, replica: int | None = None) -> None:
        self.monitor.on_task_completed(task_id_offset, "global_batch",
                                       tokens, elapsed)
        if self.straggler is not None and replica is not None:
            self.straggler.observe(replica, elapsed)

    # -- membership ------------------------------------------------------------

    def fail_replica(self, rid: int, step: int) -> ReplicaSet:
        """Node loss: forced shrink + rebalance."""
        self.failed.add(rid)
        survivors = [r for r in self.set.replicas if r != rid]
        if len(survivors) < self.min_replicas:
            raise RuntimeError("lost too many replicas")
        self.set = ReplicaSet(survivors, self.set.global_batch)
        self.resizes.append((step, len(survivors)))
        return self.set

    def resize_to_prediction(self, step: int) -> ReplicaSet:
        """Ask the governor for the policy's replica target and apply it.

        The backlog of live global batches is the load signal; the
        governor ticks the predictor and lets the policy object decide
        (busy keeps everything, prediction tracks Δ) — no policy-name
        branching here."""
        want = self.governor.target(self.governor.live_load(), 0)
        want = max(self.min_replicas,
                   min(want, self.max_replicas - len(self.failed)))
        cur = self.set.replicas
        drained = (self.straggler.drained if self.straggler is not None
                   else ())
        if want < len(cur):
            new = cur[:want]
        elif want > len(cur):
            pool = [r for r in range(self.max_replicas)
                    if r not in self.failed and r not in cur
                    and r not in drained]
            new = cur + pool[:want - len(cur)]
        else:
            return self.set
        self.set = ReplicaSet(new, self.set.global_batch)
        self.resizes.append((step, len(new)))
        return self.set

    # -- fault tolerance (machine conditions) -------------------------------

    def sweep_stragglers(self, step: int) -> ReplicaSet:
        """Drain replicas the straggler monitor currently flags (their
        data shard re-balances over the rest — the same forced-shrink
        mechanics as a failure, but *re-admittable*: once the monitor's
        cooldown clears a drained replica, :meth:`resize_to_prediction`
        may grow back onto it).  A no-op without an attached monitor."""
        if self.straggler is None:
            return self.set
        self.straggler.sweep()
        drained = self.straggler.drained
        keep = [r for r in self.set.replicas if r not in drained]
        if len(keep) < self.min_replicas:
            return self.set   # refuse to drain below the floor
        if keep != self.set.replicas:
            self.set = ReplicaSet(keep, self.set.global_batch)
            self.resizes.append((step, len(keep)))
        return self.set

    def maybe_checkpoint(self, step: int, state, every: int = 1) -> bool:
        """Save ``state`` through the attached
        :class:`~repro.checkpoint.CheckpointManager` every ``every``
        steps; returns True when a save happened."""
        if self.checkpoint is None or step % every != 0:
            return False
        self.checkpoint.save(step, state)
        return True

    def recover_from_failure(self, rid: int, step: int, like_state):
        """``CORE_FAIL`` mid-run: shrink to the survivors and roll the
        training state back to the latest checkpoint.

        Returns ``(replica_set, state, resume_step)``.  Without an
        attached checkpoint manager (or before the first save) the live
        state continues forward — the shrink alone keeps the run alive.
        """
        rs = self.fail_replica(rid, step)
        if (self.checkpoint is None
                or self.checkpoint.latest_step() is None):
            return rs, like_state, step
        state, ck_step = self.checkpoint.restore(like_state)
        self.restores.append((step, ck_step))
        return rs, state, ck_step

    def apply_perturbation(self, p: Perturbation, step: int, state):
        """Map a machine-condition perturbation onto the replica fleet:
        ``CORE_FAIL`` → checkpoint-restore shrink, ``CORE_RECOVER`` →
        the replica rejoins the candidate pool (the next grow re-admits
        it), ``STRAGGLER`` → pre-seed the monitor's suspicion.  Returns
        ``(replica_set, state, resume_step)`` like
        :meth:`recover_from_failure`."""
        if p.kind is PerturbationKind.CORE_FAIL and p.core is not None \
                and p.core in self.set.replicas:
            return self.recover_from_failure(p.core, step, state)
        if p.kind is PerturbationKind.CORE_RECOVER and p.core is not None:
            self.failed.discard(p.core)
        elif (p.kind is PerturbationKind.STRAGGLER
              and self.straggler is not None and p.core is not None
              and p.slowdown is not None and p.slowdown > 1.0):
            self.straggler.mark(p.core)
            keep = [r for r in self.set.replicas if r != p.core]
            if len(keep) >= self.min_replicas and keep != self.set.replicas:
                self.set = ReplicaSet(keep, self.set.global_batch)
                self.resizes.append((step, len(keep)))
        return self.set, state, step
