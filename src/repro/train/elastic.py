"""Elastic data parallelism driven by the paper's predictor.

The controller treats DP replicas the way the paper's CPU manager treats
cores: the *workload* is the backlog of pending global batches (each a
task with cost = tokens), α is the EMA'd per-replica step time, and
Algorithm 1 yields the replica count Δ for the next window.  Alg. 2's
poll/add hooks become ``on_step_done`` / ``on_batches_queued``.

Node failures are forced shrinks: the failed replica leaves the set and
the global batch is re-balanced over survivors (batch size per replica
grows; the gradient all-reduce group shrinks).  Growth re-admits
replicas up to Δ.  ``tests/test_elastic.py`` exercises shrink/regrow and
loss continuity across a failure.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.governor import GovernorSpec, ResourceGovernor
from ..core.prediction import PredictionConfig

__all__ = ["ElasticController", "ReplicaSet"]


@dataclass
class ReplicaSet:
    """Active replica ids + the batch split they own."""

    replicas: list[int]
    global_batch: int

    def shards(self) -> dict[int, int]:
        n = len(self.replicas)
        base = self.global_batch // n
        extra = self.global_batch % n
        return {r: base + (1 if i < extra else 0)
                for i, r in enumerate(self.replicas)}


class ElasticController:
    def __init__(self, max_replicas: int, global_batch: int,
                 policy: str = "prediction", rate_s: float = 1.0,
                 min_replicas: int = 1,
                 spec: GovernorSpec | None = None) -> None:
        if spec is None:
            spec = GovernorSpec(
                resources=max_replicas, policy=policy,
                min_resources=min_replicas,
                prediction=PredictionConfig(rate_s=rate_s),
                monitoring=True)
        self.spec = spec
        self.max_replicas = spec.resources
        self.min_replicas = max(spec.min_resources, 1)
        self.policy = spec.policy
        self.governor = ResourceGovernor(spec)
        self.monitor = self.governor.monitor
        self.predictor = self.governor.predictor
        self.set = ReplicaSet(list(range(self.max_replicas)), global_batch)
        self.failed: set[int] = set()
        self._task_seq = 0
        self.resizes: list[tuple[int, int]] = []   # (step, new_count)

    # -- workload hooks (Alg. 2's POLL/ADD analogues) -----------------------

    def on_batches_queued(self, n: int, tokens_per_batch: float) -> None:
        for _ in range(n):
            self._task_seq += 1
            self.monitor.on_task_ready(self._task_seq, "global_batch",
                                       tokens_per_batch)
            self.monitor.on_task_execute(self._task_seq, "global_batch",
                                         tokens_per_batch)

    def on_step_done(self, task_id_offset: int, tokens: float,
                     elapsed: float) -> None:
        self.monitor.on_task_completed(task_id_offset, "global_batch",
                                       tokens, elapsed)

    # -- membership ------------------------------------------------------------

    def fail_replica(self, rid: int, step: int) -> ReplicaSet:
        """Node loss: forced shrink + rebalance."""
        self.failed.add(rid)
        survivors = [r for r in self.set.replicas if r != rid]
        if len(survivors) < self.min_replicas:
            raise RuntimeError("lost too many replicas")
        self.set = ReplicaSet(survivors, self.set.global_batch)
        self.resizes.append((step, len(survivors)))
        return self.set

    def resize_to_prediction(self, step: int) -> ReplicaSet:
        """Ask the governor for the policy's replica target and apply it.

        The backlog of live global batches is the load signal; the
        governor ticks the predictor and lets the policy object decide
        (busy keeps everything, prediction tracks Δ) — no policy-name
        branching here."""
        want = self.governor.target(self.governor.live_load(), 0)
        want = max(self.min_replicas,
                   min(want, self.max_replicas - len(self.failed)))
        cur = self.set.replicas
        if want < len(cur):
            new = cur[:want]
        elif want > len(cur):
            pool = [r for r in range(self.max_replicas)
                    if r not in self.failed and r not in cur]
            new = cur + pool[:want - len(cur)]
        else:
            return self.set
        self.set = ReplicaSet(new, self.set.global_batch)
        self.resizes.append((step, len(new)))
        return self.set
