"""AdamW with ZeRO-sharded states.

State lives on the same PartitionSpecs as the parameters (FSDP+TP), so
optimizer memory shards with the weights — the ZeRO-3 arrangement.  State
dtype is configurable: ``f32`` (default) or ``bf16`` (halves optimizer
HBM; used by the 400B llama4 config to fit a single v5e pod — recorded in
DESIGN.md §7).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "opt_state_specs"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0):
    """Returns (new_params, new_state).  Math in f32, cast on store."""
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m32 / b1c
        vhat = v32 / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return (new_p.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])
    out = [upd(g, m, v, p) for g, m, v, p
           in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "count": count}


def opt_state_specs(param_specs) -> dict:
    from jax.sharding import PartitionSpec as P
    return {
        "mu": param_specs,
        "nu": param_specs,
        "count": P(),
    }
