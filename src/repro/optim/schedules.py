"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_warmup"]


def cosine_warmup(step, *, warmup: int = 100, total: int = 10_000,
                  floor: float = 0.1):
    """Linear warmup → cosine decay to ``floor`` of peak.  Returns the
    multiplicative scale in [0, 1]."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                    0.0, 1.0)
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, cos)
