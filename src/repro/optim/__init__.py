"""Optimizers and schedules (built here, no external deps)."""

from .adamw import AdamWConfig, adamw_init, adamw_update, opt_state_specs
from .schedules import cosine_warmup
from .clipping import global_norm, clip_by_global_norm

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "opt_state_specs",
           "cosine_warmup", "global_norm", "clip_by_global_norm"]
