"""Discrete-event serving simulator — overload robustness at 10⁵-request
scale.

The live :class:`~repro.serving.engine.ServingEngine` decodes real
tokens, so an overload study on it is bounded by wall clock.  This
module is the serving twin of :mod:`repro.runtime.sim`: request service
is *modelled* (prefill + decode token rates on a
:class:`~repro.runtime.machine.MachineModel`), time is virtual, and the
whole SLO/robustness surface runs in one thread with the simulator's
flattened-heap idioms (``(t, seq, kind, a, b)`` tuples, int event kinds,
epoch guards for stale-event cancellation) — 10⁵ requests in seconds.

What it exercises, end to end:

* **SLO classes** (:mod:`repro.serving.slo`): priority-ordered
  admission, deadline shedding, per-attempt timeouts with seeded
  exponential-backoff retries, and hedged duplicates for the
  latency-critical tail (first completion wins, the loser is
  cancelled).
* **Overload protection** (:mod:`repro.serving.admission`): an
  :class:`AdmissionController` sheds at arrival on queue depth and
  deadline infeasibility (estimated wait comes from the live queue's
  predicted work — the prediction stack deciding *what not to serve*);
  a per-replica :class:`CircuitBreaker` quarantines a failing replica
  and re-admits it through half-open probes.
* **Graceful degradation** under live
  :class:`~repro.core.conditions.MachineConditions`: an active power
  cap shrinks the hot-replica allowance (:func:`cap_allowance`,
  worst-case draw, so a protected run logs **zero** cap-violation
  seconds) and *brownouts* best-effort requests (``max_new_tokens``
  truncation) instead of shedding them; core failures tear attempts off
  the replica and requeue them *uncharged* (no retry-budget debit).
* **Prediction-based autoscaling**: the same
  :class:`~repro.serving.autoscale.AutoScaler` stack (Algorithm 1 over
  per-class request costs) decides how many replicas stay hot; replicas
  park to the idle power floor and pay ``spinup_s`` to come back.

Every decision is deterministic given (requests, timeline, seed):
arrival processes and SLO backoff are seeded, there is no wall clock,
and the published event stream (TASK_* lifecycle plus
SHED/RETRY/HEDGE/DEGRADE/PERTURBATION/PREDICTION) carries enough data
for :func:`replay_serving` to rebuild and re-run the scenario
byte-exactly from a recorded trace.
"""

from __future__ import annotations

import heapq
import itertools
import math
import random
from dataclasses import dataclass
from typing import Any, Iterable, Sequence

from ..core.conditions import ConditionTimeline, MachineConditions, \
    PerturbationKind
from ..core.energy import CoreState, EnergyMeter, PowerModel
from ..core.events import EventBus, EventKind, RuntimeEvent
from ..core.governor import GovernorReport
from ..core.monitoring import TaskMonitor
from ..runtime.machine import MachineModel
from ..workloads.arrivals import ArrivalProcess
from .admission import AdmissionController, CircuitBreaker, cap_allowance
from .autoscale import AutoScaler
from .slo import BATCH, INTERACTIVE, STANDARD, SLOClass

__all__ = ["ServingModel", "SimRequest", "build_requests", "SimServing",
           "replay_serving"]


# ---------------------------------------------------------------------------
# Service model + workload
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServingModel:
    """Cost model for one simulated serving deployment.

    Each core of ``machine`` hosts one engine replica with
    ``slots_per_replica`` concurrent request slots (continuous-batching
    capacity).  A request of ``prompt`` input tokens and ``new`` output
    tokens costs ``prompt/prefill_rate + new/decode_rate`` reference
    seconds, then dilates through the machine's per-core speed, the
    thermal frequency cap and any straggler slowdown — exactly the
    :meth:`MachineModel.service_time` contract the task simulator uses.
    """

    machine: MachineModel
    slots_per_replica: int = 4
    prefill_rate: float = 4000.0   # prompt tokens / reference second
    decode_rate: float = 160.0     # new tokens / reference second
    spinup_s: float = 0.05         # parked → serving (model/cache warmup)

    def __post_init__(self) -> None:
        if self.slots_per_replica < 1:
            raise ValueError("slots_per_replica must be >= 1")
        if self.prefill_rate <= 0 or self.decode_rate <= 0:
            raise ValueError("token rates must be > 0")
        if self.spinup_s < 0:
            raise ValueError("spinup_s must be >= 0")

    @property
    def n_replicas(self) -> int:
        return self.machine.n_cores

    def base_seconds(self, prompt: int, new: int) -> float:
        """Reference-core service seconds for one attempt."""
        return prompt / self.prefill_rate + new / self.decode_rate


@dataclass(slots=True)
class SimRequest:
    """One simulated request and its eventual fate."""

    rid: int
    release: float
    prompt: int
    new: int
    slo: SLOClass | None = None
    #: "completed" | "shed" | "timed_out" (None while live)
    outcome: str | None = None
    done_at: float | None = None
    tries: int = 1
    tokens_out: int = 0
    # Filled by SimServing at setup (derived, not part of the workload):
    type_name: str = ""
    cost: float = 0.0
    est_s: float = 0.0


#: default traffic mix (class, weight): half standard, a quarter each of
#: interactive and batch — the shape of a user-facing service with a
#: background analytics tail
DEFAULT_MIX: tuple[tuple[SLOClass, float], ...] = (
    (INTERACTIVE, 1.0), (STANDARD, 2.0), (BATCH, 1.0))


def build_requests(process: ArrivalProcess, n: int, *,
                   mix: Sequence[tuple[SLOClass, float]] = DEFAULT_MIX,
                   prompt_range: tuple[int, int] = (16, 256),
                   new_range: tuple[int, int] = (16, 128),
                   seed: int = 0) -> list[SimRequest]:
    """``n`` seeded requests released by ``process``: SLO classes drawn
    from the weighted ``mix``, token counts uniform over the ranges.
    Fresh ``random.Random(seed)`` per call (arrivals.py discipline)."""
    rng = random.Random(seed)
    times = process.times(n)
    classes = [s for s, _ in mix]
    weights = [w for _, w in mix]
    slos = rng.choices(classes, weights=weights, k=n)
    p_lo, p_hi = prompt_range
    n_lo, n_hi = new_range
    return [SimRequest(rid=i, release=times[i],
                       prompt=rng.randint(p_lo, p_hi),
                       new=rng.randint(n_lo, n_hi), slo=slos[i])
            for i in range(n)]


# ---------------------------------------------------------------------------
# The discrete-event serving frontend
# ---------------------------------------------------------------------------

# Flattened heap entries (t, seq, kind, a, b) with int kinds — the
# PR-5 sim hot-path idiom (tuple compare never reaches `kind`; `seq` is
# unique per push).
_ARRIVE, _FINISH, _TIMEOUT, _RETRY, _HEDGE, _SCALE, _WARM, _PERT = range(8)


class SimServing:
    """Virtual-time serving frontend over a :class:`ServingModel`.

    Parameters
    ----------
    model, requests:
        The deployment cost model and the (release-sorted) workload.
    policy:
        Autoscaler policy name (``prediction`` / ``idle`` / ``busy`` /
        any registered policy) — slots are the governed resource.
    rate_s:
        Prediction tick period *and* Algorithm 1's planning horizon
        (clear the outstanding predicted work within ``rate_s``).
    protection:
        Master switch for the overload-protection layer: admission
        control, SLO-priority queue ordering, dead-request reaping at
        dispatch, hedging, circuit breakers, power-cap enforcement and
        brownout.  SLO timeouts/retries are the *client's* contract and
        stay active either way — ``protection=False`` is the
        "unprotected reactive baseline" of the benchmarks: a FIFO
        server that burns slots on requests whose deadline is already
        lost.
    admission:
        Override the default :class:`AdmissionController` (queue bound
        ``queue_factor × total slots``); ignored when protection is off.
    conditions:
        A :class:`ConditionTimeline` of machine perturbations.
    brownout_tokens:
        ``max_new_tokens`` ceiling applied to best-effort requests while
        a power cap is active (None disables brownout).
    bus:
        Event bus for trace recording; quiet buses cost nothing.
    """

    def __init__(self, model: ServingModel,
                 requests: Iterable[SimRequest], *,
                 policy: str = "prediction",
                 rate_s: float = 0.5,
                 min_replicas: int = 1,
                 protection: bool = True,
                 admission: AdmissionController | None = None,
                 queue_factor: int = 4,
                 conditions: ConditionTimeline | None = None,
                 brownout_tokens: int | None = 16,
                 breaker_failures: int = 3,
                 breaker_reset_s: float = 0.5,
                 breaker_probes: int = 2,
                 bus: EventBus | None = None,
                 seed: int = 0) -> None:
        self.model = model
        self.machine = model.machine
        self.protection = protection
        self.brownout_tokens = brownout_tokens
        self.seed = seed
        self.bus = bus if bus is not None else EventBus()

        reqs = sorted(requests, key=lambda r: (r.release, r.rid))
        self._reqs: dict[int, SimRequest] = {r.rid: r for r in reqs}
        if len(self._reqs) != len(reqs):
            raise ValueError("duplicate request ids")
        self._n = len(reqs)

        spr = model.slots_per_replica
        n_rep = model.n_replicas
        self.slots_total = n_rep * spr
        topo = self.machine.topology()
        self._typed = self.machine.core_types is not None
        # Replica state (lists indexed by replica id — never sets, the
        # determinism lint covers this package).
        self._ctype = [topo.core_type_at(r).name for r in range(n_rep)]
        self._power = [topo.core_type_at(r).power or PowerModel()
                       for r in range(n_rep)]
        self._hot = [True] * n_rep       # serving (or warming) now
        self._warming = [False] * n_rep
        self._wepoch = [0] * n_rep
        self._failed = [False] * n_rep
        self._busy = [0] * n_rep         # attempts in flight per replica
        # Dispatch/wake order: fastest silicon first, id as tie-break.
        self._order = sorted(range(n_rep),
                             key=lambda r: (-self.machine.speed_of(r), r))
        self._nhot = n_rep

        self._conditions = MachineConditions(conditions)
        self._meter = EnergyMeter(0)
        for r in range(n_rep):
            self._meter.add_core(r, CoreState.SPIN, 0.0,
                                 power=self._power[r],
                                 core_type=self._ctype[r]
                                 if self._typed else "")

        self.monitor = TaskMonitor()
        self.monitor.mark_direct_driven(self.bus)
        self.scaler = AutoScaler(self.monitor, max_replicas=self.slots_total,
                                 policy=policy,
                                 min_replicas=min_replicas * spr,
                                 rate_s=rate_s)
        self._breakers = ([CircuitBreaker(breaker_failures, breaker_reset_s,
                                          breaker_probes)
                           for _ in range(n_rep)] if protection else None)
        if protection and admission is None:
            admission = AdmissionController(
                max_queue_depth=queue_factor * self.slots_total)
        self._admission = admission if protection else None

        # Event heap + priority queue (lazy staleness on both).
        self._heap: list[tuple] = []
        self._seq = itertools.count()
        self._aids = itertools.count()
        self._q: list[tuple[int, int, int]] = []      # (-pri, seq, rid)
        self._vq: list[tuple[int, int, int]] = []     # (pri, -seq, rid)
        self._inq: dict[int, tuple[int, int]] = {}    # rid -> (pri, seq)
        self._qdepth = 0
        self._qwork = 0.0
        self._qwork_by_pri: dict[int, float] = {}
        # Attempt registry: aid -> (rid, replica, served_new, t_start,
        # hedge?, freq); popping an aid IS the cancellation.
        self._att: dict[int, tuple[int, int, int, float, bool, float]] = {}
        self._rid_att: dict[int, list[int]] = {}
        self._tepoch: dict[int, int] = {}
        self._active = 0
        self._sleeping = 0     # requests waiting out a retry backoff

        self._now = 0.0
        self._done = 0
        self._completed = 0
        self._idles = 0
        self._retries = 0
        self._requeues = 0
        self._hedges = 0
        self._hedge_wins = 0
        self._degrades = 0
        self._shed_by_reason: dict[str, int] = {}
        self._cap_active = False
        self._allowance: int | None = None
        self._stall = 0
        self._stall_done = -1
        self._finished = False

        fastest = self._order[0]
        for req in reqs:
            req.type_name = (f"request:{req.slo.name}" if req.slo
                             else "request")
            req.cost = float(req.prompt + req.new)
            req.est_s = self.machine.service_time(
                model.base_seconds(req.prompt, req.new), core=fastest)
            self._tepoch[req.rid] = 0

        # Seed the heap: first scale tick, then the perturbation
        # timeline, then arrivals (seq breaks same-time ties in this
        # order — control plane before data plane at t=0).
        self._push(0.0, _SCALE, 0, 0)
        for i, p in enumerate(self._conditions.timeline):
            self._push(p.time, _PERT, i, 0)
        for req in reqs:
            self._push(req.release, _ARRIVE, req.rid, 0)

    # -- plumbing ------------------------------------------------------------

    def _push(self, t: float, kind: int, a: int, b: int) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, a, b))

    def _publish(self, kind: EventKind, *, task_id: int | None = None,
                 type_name: str | None = None, cost: float | None = None,
                 worker_id: int | None = None, elapsed: float | None = None,
                 data: dict | None = None) -> None:
        if not self.bus.interested(kind):
            return
        self.bus.publish(RuntimeEvent(
            kind=kind, time=self._now, task_id=task_id,
            type_name=type_name, cost=cost, worker_id=worker_id,
            elapsed=elapsed, data=data or {}))

    # -- main loop -----------------------------------------------------------

    def run(self) -> "SimServing":
        """Process events until every request has an outcome."""
        heap = self._heap
        while self._done < self._n:
            if not heap:
                raise RuntimeError(
                    f"serving sim drained its event heap with "
                    f"{self._n - self._done} of {self._n} requests "
                    f"unresolved (queued={self._qdepth}, "
                    f"active={self._active})")
            t, _, kind, a, b = heapq.heappop(heap)
            self._now = t
            if kind == _FINISH:
                self._on_finish(a, t)
            elif kind == _ARRIVE:
                self._on_arrive(a, t)
            elif kind == _TIMEOUT:
                self._on_timeout(a, b, t)
            elif kind == _RETRY:
                self._on_retry(a, t)
            elif kind == _HEDGE:
                self._on_hedge(a, b, t)
            elif kind == _SCALE:
                self._on_scale(t)
            elif kind == _WARM:
                self._on_warm(a, b, t)
            else:
                self._on_pert(a, t)
        if not self._finished:
            self._meter.finish(self._now)
            self._finished = True
        return self

    # -- queue ---------------------------------------------------------------

    def _pri(self, req: SimRequest) -> int:
        # SLO-priority ordering is part of the protection layer: the
        # unprotected baseline is a plain FIFO server
        return req.slo.priority if req.slo and self.protection else 0

    def _enqueue(self, req: SimRequest) -> None:
        pri = self._pri(req)
        seq = next(self._seq)
        self._inq[req.rid] = (pri, seq)
        heapq.heappush(self._q, (-pri, seq, req.rid))
        heapq.heappush(self._vq, (pri, -seq, req.rid))
        self._qdepth += 1
        self._qwork += req.est_s
        self._qwork_by_pri[pri] = \
            self._qwork_by_pri.get(pri, 0.0) + req.est_s

    def _pop_queue(self) -> int | None:
        q = self._q
        while q:
            negpri, seq, rid = heapq.heappop(q)
            if self._inq.get(rid) == (-negpri, seq):
                del self._inq[rid]
                self._qdepth -= 1
                est = self._reqs[rid].est_s
                self._qwork -= est
                self._qwork_by_pri[-negpri] -= est
                return rid
        return None

    def _evict_lowest(self, above: int) -> int | None:
        """Drop the lowest-priority (youngest at ties) queued request if
        its priority is strictly below ``above``; returns its rid."""
        vq = self._vq
        while vq:
            pri, negseq, rid = vq[0]
            if self._inq.get(rid) != (pri, -negseq):
                heapq.heappop(vq)          # stale
                continue
            if pri >= above:
                return None
            heapq.heappop(vq)
            del self._inq[rid]
            self._qdepth -= 1
            est = self._reqs[rid].est_s
            self._qwork -= est
            self._qwork_by_pri[pri] -= est
            return rid
        return None

    # -- arrival / admission -------------------------------------------------

    def _on_arrive(self, rid: int, now: float) -> None:
        req = self._reqs[rid]
        data: dict[str, Any] = {"prompt": req.prompt, "new": req.new}
        if req.slo is not None:
            data["slo"] = req.slo.to_dict()
        self._publish(EventKind.TASK_SUBMITTED, task_id=rid,
                      type_name=req.type_name, cost=req.cost, data=data)
        self.monitor.on_task_ready(rid, req.type_name, req.cost)
        self._publish(EventKind.TASK_READY, task_id=rid,
                      type_name=req.type_name, cost=req.cost)
        reason = None
        pri = self._pri(req)
        if self._admission is not None:
            # Priority-aware wait estimate: the newcomer only queues
            # behind work at its own priority or above — charging it
            # for the batch backlog it would jump over would shed
            # latency-critical traffic that is perfectly feasible.
            ahead = sum(w for p, w in self._qwork_by_pri.items()
                        if p >= pri)
            est_wait = ahead / max(
                1, self._nhot * self.model.slots_per_replica)
            reason = self._admission.shed_reason(
                now=now, queue_depth=self._qdepth, slo=req.slo,
                submitted_at=now, est_wait_s=est_wait,
                est_service_s=req.est_s)
        if reason == "queue":
            victim = self._evict_lowest(pri)
            if victim is not None:
                self._shed(self._reqs[victim], "queue", now)
                reason = None
        if reason is not None:
            self._shed(req, reason, now)
            return
        self._enqueue(req)
        self._dispatch(now)

    def _shed(self, req: SimRequest, reason: str, now: float) -> None:
        """Terminal shed of a *ready* (queued or never-admitted) request."""
        self.monitor.on_task_shed(req.rid, req.type_name, req.cost)
        req.outcome = "shed" if reason != "timeout" else "timed_out"
        req.done_at = now
        self._shed_by_reason[reason] = \
            self._shed_by_reason.get(reason, 0) + 1
        self._publish(EventKind.SHED, task_id=req.rid,
                      type_name=req.type_name, cost=req.cost,
                      data={"reason": reason})
        self._done += 1

    # -- dispatch ------------------------------------------------------------

    def _pick_replica(self, now: float) -> int | None:
        spr = self.model.slots_per_replica
        breakers = self._breakers
        for r in self._order:
            if (not self._hot[r] or self._warming[r] or self._failed[r]
                    or self._busy[r] >= spr):
                continue
            if breakers is not None:
                st = breakers[r].state(now)
                if st == CircuitBreaker.OPEN:
                    continue
                if st == CircuitBreaker.HALF_OPEN and self._busy[r] > 0:
                    continue   # one probe in flight at a time
            return r
        return None

    def _dispatch(self, now: float) -> None:
        while self._qdepth > 0:
            r = self._pick_replica(now)
            if r is None:
                return
            rid = self._pop_queue()
            if rid is None:
                return
            req = self._reqs[rid]
            slo = req.slo
            if (self.protection and slo is not None
                    and slo.deadline_s is not None
                    and now > req.release + slo.deadline_s):
                # deadline lost while queued: cheapest failure is now.
                # The unprotected baseline serves these dead requests
                # anyway — the wasted slots are exactly the congestion
                # collapse admission control exists to prevent.
                self._shed(req, "deadline", now)
                continue
            self._start_attempt(req, r, now, hedge=False)

    def _start_attempt(self, req: SimRequest, r: int, now: float,
                       hedge: bool) -> None:
        served = req.new
        if (self.protection and self._cap_active
                and self.brownout_tokens is not None
                and req.slo is not None and req.slo.best_effort):
            served = min(served, self.brownout_tokens)
        freq = self._conditions.thermal_cap(self._ctype[r])
        svc = (self.machine.service_time(
                   self.model.base_seconds(req.prompt, served),
                   core=r, freq=freq)
               * self._conditions.slowdown_of(r))
        aid = next(self._aids)
        self._att[aid] = (req.rid, r, served, now, hedge, freq)
        self._rid_att.setdefault(req.rid, []).append(aid)
        self._busy[r] += 1
        self._active += 1
        if self._busy[r] == 1:
            self._meter.set_state(r, CoreState.ACTIVE, now)
        self._push(now + svc, _FINISH, aid, 0)
        if hedge:
            self._hedges += 1
            self._publish(EventKind.HEDGE, task_id=req.rid,
                          type_name=req.type_name, cost=req.cost,
                          worker_id=r)
            return
        self.monitor.on_task_execute(req.rid, req.type_name, req.cost)
        self._publish(EventKind.TASK_EXECUTE, task_id=req.rid,
                      type_name=req.type_name, cost=req.cost, worker_id=r)
        slo = req.slo
        if slo is not None:
            epoch = self._tepoch[req.rid]
            tmo = slo.attempt_timeout_s
            if tmo is not None:
                self._push(now + tmo, _TIMEOUT, req.rid, epoch)
            if self.protection and slo.hedge_after_s is not None:
                self._push(now + slo.hedge_after_s, _HEDGE, req.rid, epoch)

    # -- completion / cancellation -------------------------------------------

    def _release_slot(self, r: int, now: float) -> None:
        self._busy[r] -= 1
        self._active -= 1
        if (self._busy[r] == 0 and self._hot[r] and not self._warming[r]
                and not self._failed[r]):
            self._meter.set_state(r, CoreState.SPIN, now)

    def _on_finish(self, aid: int, now: float) -> None:
        ent = self._att.pop(aid, None)
        if ent is None:
            return   # cancelled attempt; stale event
        rid, r, served, t0, hedge, freq = ent
        req = self._reqs[rid]
        for aid2 in self._rid_att.pop(rid, ()):
            ent2 = self._att.pop(aid2, None)
            if ent2 is None:
                continue   # the finishing attempt itself, or long gone
            self._release_slot(ent2[1], now)
        self._release_slot(r, now)
        self._tepoch[rid] += 1
        if hedge:
            self._hedge_wins += 1
        if self._breakers is not None:
            self._record_breaker_success(r, now)
        self.monitor.on_task_completed(
            rid, req.type_name, req.cost, now - t0,
            core_type=self._ctype[r] if self._typed else None,
            freq=freq, suspect=self._conditions.is_suspect(r))
        req.outcome = "completed"
        req.done_at = now
        req.tokens_out = served
        self._completed += 1
        self._done += 1
        self._publish(EventKind.TASK_COMPLETED, task_id=rid,
                      type_name=req.type_name, cost=req.cost, worker_id=r,
                      elapsed=now - req.release)
        self._dispatch(now)

    def _record_breaker_success(self, r: int, now: float) -> None:
        brk = self._breakers[r]
        was_half = brk.state(now) == CircuitBreaker.HALF_OPEN
        brk.record_success(now)
        if was_half and brk.state(now) == CircuitBreaker.CLOSED:
            self._degrades += 1
            self._publish(EventKind.DEGRADE, worker_id=r,
                          data={"mode": "restored"})

    def _on_timeout(self, rid: int, epoch: int, now: float) -> None:
        if epoch != self._tepoch[rid]:
            return   # attempt finished / was torn down before the bell
        req = self._reqs[rid]
        for aid in self._rid_att.pop(rid, ()):
            ent = self._att.pop(aid, None)
            if ent is None:
                continue
            r = ent[1]
            self._release_slot(r, now)
            if self._breakers is not None:
                brk = self._breakers[r]
                brk.record_failure(now)
                if brk.state(now) == CircuitBreaker.OPEN:
                    self._quarantine(r, now)
        self._tepoch[rid] += 1
        self.monitor.on_task_abort(rid, req.type_name, req.cost)
        slo = req.slo
        if slo is not None and req.tries <= slo.retry_budget:
            backoff = slo.backoff(req.tries, seed=self.seed, request_id=rid)
            retry_at = now + backoff
            if (slo.deadline_s is None
                    or retry_at <= req.release + slo.deadline_s):
                req.tries += 1
                self._retries += 1
                self._sleeping += 1
                self._publish(EventKind.RETRY, task_id=rid,
                              type_name=req.type_name, cost=req.cost,
                              data={"try": req.tries,
                                    "backoff_s": backoff})
                self._push(retry_at, _RETRY, rid, 0)
                self._dispatch(now)
                return
        self._shed(req, "timeout", now)
        self._dispatch(now)

    def _on_retry(self, rid: int, now: float) -> None:
        self._sleeping -= 1
        req = self._reqs[rid]
        if req.outcome is not None:
            return
        self._enqueue(req)
        self._dispatch(now)

    def _on_hedge(self, rid: int, epoch: int, now: float) -> None:
        if epoch != self._tepoch[rid] or rid not in self._rid_att:
            return   # finished / retried — the tail is gone
        primary_replicas = [self._att[a][1] for a in self._rid_att[rid]
                            if a in self._att]
        if not primary_replicas:
            return
        spr = self.model.slots_per_replica
        breakers = self._breakers
        for r in self._order:
            if (r in primary_replicas or not self._hot[r]
                    or self._warming[r] or self._failed[r]
                    or self._busy[r] >= spr):
                continue
            if breakers is not None \
                    and breakers[r].state(now) != CircuitBreaker.CLOSED:
                continue   # never hedge onto suspect silicon
            self._start_attempt(self._reqs[rid], r, now, hedge=True)
            return

    # -- replica lifecycle ---------------------------------------------------

    def _wake(self, r: int, now: float) -> None:
        self._hot[r] = True
        self._warming[r] = True
        self._wepoch[r] += 1
        self._nhot += 1
        self._meter.set_state(r, CoreState.SPIN, now)
        self._push(now + self.model.spinup_s, _WARM, r, self._wepoch[r])

    def _park(self, r: int, now: float) -> None:
        self._hot[r] = False
        if self._warming[r]:
            self._warming[r] = False
            self._wepoch[r] += 1   # cancel the in-flight _WARM
        self._nhot -= 1
        self._idles += 1
        self._meter.set_state(r, CoreState.IDLE, now)

    def _on_warm(self, r: int, epoch: int, now: float) -> None:
        if not self._warming[r] or self._wepoch[r] != epoch:
            return
        self._warming[r] = False
        self._dispatch(now)

    def _on_scale(self, now: float) -> None:
        if self._done >= self._n:
            return
        target = self.scaler.target(self._qdepth + self._sleeping,
                                    self._active)
        if self.scaler.governor.predictor is not None:
            self._publish(EventKind.PREDICTION, data={"delta": target})
        spr = self.model.slots_per_replica
        need = -(-target // spr)   # ceil in replicas
        if self.protection and self._allowance is not None:
            need = min(need, self._allowance)
        self._apply_replica_target(need, now)
        self._check_stall(now)
        self._push(now + self.scaler.rate_s, _SCALE, 0, 0)

    def _apply_replica_target(self, need: int, now: float) -> None:
        breakers = self._breakers
        if self._nhot < need:
            for r in self._order:
                if self._nhot >= need:
                    break
                if self._hot[r] or self._failed[r]:
                    continue
                if breakers is not None \
                        and not breakers[r].allow(now):
                    continue
                self._wake(r, now)
        elif self._nhot > need:
            for r in reversed(self._order):
                if self._nhot <= need:
                    break
                if self._hot[r] and self._busy[r] == 0:
                    self._park(r, now)
        self._dispatch(now)

    def _check_stall(self, now: float) -> None:
        if (self._done == self._stall_done and self._active == 0
                and self._sleeping == 0
                and not any(self._warming)):
            self._stall += 1
            if self._stall > 10_000:
                raise RuntimeError(
                    f"serving sim stalled at t={now:.3f}: "
                    f"{self._done}/{self._n} resolved, "
                    f"queued={self._qdepth}, hot={self._nhot}, "
                    f"failed={sum(self._failed)} — no attempt, retry "
                    f"or warmup in flight for {self._stall} scale ticks")
        else:
            self._stall = 0
            self._stall_done = self._done

    # -- degradation: quarantine, capacity shrink, brownout ------------------

    def _evict_replica(self, r: int, now: float) -> None:
        """Tear every attempt off replica ``r`` and requeue the affected
        requests *uncharged* (no retry-budget debit — the machine, not
        the request, failed).  A request whose hedge twin survives on
        another replica just loses this one attempt."""
        doomed = [aid for aid, ent in self._att.items() if ent[1] == r]
        for aid in doomed:
            rid = self._att.pop(aid)[0]
            self._release_slot(r, now)
            aids = self._rid_att.get(rid)
            if aids is not None:
                aids = [a for a in aids if a != aid and a in self._att]
                if aids:
                    self._rid_att[rid] = aids
                    continue   # a sibling attempt survives
                del self._rid_att[rid]
            req = self._reqs[rid]
            self._tepoch[rid] += 1
            self.monitor.on_task_abort(rid, req.type_name, req.cost)
            self._requeues += 1
            self._publish(EventKind.RETRY, task_id=rid,
                          type_name=req.type_name, cost=req.cost,
                          data={"requeued": True})
            self._enqueue(req)

    def _quarantine(self, r: int, now: float) -> None:
        """Circuit breaker opened on ``r``: park it out of rotation (it
        re-enters through half-open probes after the reset window)."""
        self._evict_replica(r, now)
        if self._hot[r]:
            self._park(r, now)
        self._degrades += 1
        self._publish(EventKind.DEGRADE, worker_id=r,
                      data={"mode": "quarantine"})

    def _shrink_to(self, allowance: int, now: float) -> None:
        """Enforce a hot-replica ceiling *now* (power-cap compliance):
        park empty replicas slowest-first, then evict busy ones."""
        if self._nhot <= allowance:
            return
        for r in reversed(self._order):
            if self._nhot <= allowance:
                return
            if self._hot[r] and self._busy[r] == 0:
                self._park(r, now)
        for r in reversed(self._order):
            if self._nhot <= allowance:
                return
            if self._hot[r]:
                self._evict_replica(r, now)
                self._park(r, now)

    def _on_pert(self, index: int, now: float) -> None:
        p = self._conditions.timeline.events[index]
        self._conditions.apply(p)
        self._publish(EventKind.PERTURBATION, data=p.to_dict())
        k = p.kind
        if k is PerturbationKind.POWER_CAP:
            self._meter.set_power_cap(now, p.watts)
            if p.watts is None:
                self._cap_active = False
                self._allowance = None
                if self.protection:
                    self._degrades += 1
                    self._publish(EventKind.DEGRADE,
                                  data={"mode": "brownout_release"})
            else:
                self._cap_active = True
                if self.protection:
                    draws = [(self._power[r].power(CoreState.ACTIVE),
                              self._power[r].power(CoreState.IDLE))
                             for r in self._order if not self._failed[r]]
                    self._allowance = cap_allowance(p.watts, draws)
                    self._degrades += 1
                    self._publish(EventKind.DEGRADE,
                                  data={"mode": "brownout",
                                        "allowance": self._allowance})
                    self._shrink_to(self._allowance, now)
        elif k is PerturbationKind.CORE_FAIL:
            r = p.core
            self._evict_replica(r, now)
            self._failed[r] = True
            if self._hot[r]:
                self._park(r, now)
                self._idles -= 1   # a crash is not a policy idle
            self._meter.set_state(r, CoreState.OFF, now)
            if self._breakers is not None:
                self._breakers[r].force_open(now)
                self._degrades += 1
                self._publish(EventKind.DEGRADE, worker_id=r,
                              data={"mode": "quarantine"})
        elif k is PerturbationKind.CORE_RECOVER:
            r = p.core
            self._failed[r] = False
            self._meter.set_state(r, CoreState.IDLE, now)
            # parked; the scaler re-admits it (through the breaker's
            # half-open probes when protection is on)
        elif k is PerturbationKind.THERMAL_THROTTLE:
            q = p.freq if p.freq is not None else 1.0
            for r in range(self.model.n_replicas):
                if self._ctype[r] == p.core_type:
                    self._meter.set_frequency(r, q, now)
        self._dispatch(now)

    # -- reporting -----------------------------------------------------------

    @property
    def requests(self) -> list[SimRequest]:
        return [self._reqs[rid] for rid in sorted(self._reqs)]

    def report(self, name: str = "") -> GovernorReport:
        """Unified :class:`GovernorReport` with the ``serving`` extras."""
        if not self._finished:
            self._meter.finish(self._now)
            self._finished = True
        meter = self._meter
        makespan = self._now
        energy = meter.energy()
        reqs = self.requests
        lat = sorted(r.done_at - r.release for r in reqs
                     if r.outcome == "completed")
        by_class: dict[str, dict[str, Any]] = {}
        attained_total = 0
        for r in reqs:
            cname = r.slo.name if r.slo else "none"
            row = by_class.setdefault(
                cname, {"requests": 0, "attained": 0})
            row["requests"] += 1
            dl = r.slo.deadline_s if r.slo else None
            ok = (r.outcome == "completed"
                  and (dl is None or r.done_at - r.release <= dl))
            if ok:
                row["attained"] += 1
                attained_total += 1
        for row in by_class.values():
            row["attainment"] = row["attained"] / row["requests"]
        timed_out = sum(1 for r in reqs if r.outcome == "timed_out")
        shed = sum(1 for r in reqs if r.outcome == "shed")
        truncated = sum(r.new - r.tokens_out for r in reqs
                        if r.outcome == "completed")
        serving = {
            "requests": self._n,
            "completed": self._completed,
            "shed": shed,
            "timed_out": timed_out,
            "shed_by_reason": dict(self._shed_by_reason),
            "retries": self._retries,
            "requeues": self._requeues,
            "hedges": self._hedges,
            "hedge_wins": self._hedge_wins,
            "degrades": self._degrades,
            "truncated_tokens": truncated,
            "p50_ms": _pct(lat, 0.50) * 1e3,
            "p99_ms": _pct(lat, 0.99) * 1e3,
            "attainment": attained_total / self._n if self._n else 0.0,
            "attainment_by_class": by_class,
            "goodput_rps": (attained_total / makespan
                            if makespan > 0 else 0.0),
        }
        predictor = self.scaler.governor.predictor
        return GovernorReport(
            policy=self.scaler.policy,
            makespan=makespan,
            energy=energy,
            edp=energy * makespan,
            tasks_completed=self._completed,
            resumes=meter.resumes(),
            idles=self._idles,
            predictions=(predictor.predictions_made
                         if predictor is not None else 0),
            accuracy=self.monitor.accuracy_report(),
            name=name,
            state_seconds={s.value: v
                           for s, v in meter.state_seconds().items()},
            state_seconds_by_type={
                ct: {s.value: v for s, v in acc.items()}
                for ct, acc in meter.state_seconds_by_type().items()},
            cap_violation_s=meter.cap_violation_s,
            serving=serving,
        )


def _pct(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile over an ascending list (0.0 if empty)."""
    if not sorted_vals:
        return 0.0
    idx = max(0, min(len(sorted_vals) - 1,
                     int(math.ceil(q * len(sorted_vals))) - 1))
    return sorted_vals[idx]


# ---------------------------------------------------------------------------
# Trace round trip
# ---------------------------------------------------------------------------


def replay_serving(events: Iterable[RuntimeEvent], model: ServingModel,
                   **kwargs: Any) -> SimServing:
    """Rebuild a :class:`SimServing` run from its recorded event stream.

    ``TASK_SUBMITTED`` events carry each request's full contract
    (release = event time; prompt/new/SLO in ``data``) and
    ``PERTURBATION`` events carry the condition timeline, so the
    returned sim — constructed with the *same* ``kwargs`` (policy,
    protection, seed, …) as the original — re-runs the scenario and
    publishes a byte-identical trace.
    """
    reqs: list[SimRequest] = []
    perts: list[dict] = []
    for ev in events:
        if ev.kind is EventKind.TASK_SUBMITTED:
            d = ev.data
            slo = (SLOClass.from_dict(d["slo"]) if "slo" in d else None)
            reqs.append(SimRequest(rid=ev.task_id, release=ev.time,
                                   prompt=d["prompt"], new=d["new"],
                                   slo=slo))
        elif ev.kind is EventKind.PERTURBATION:
            perts.append(dict(ev.data))
    kwargs.setdefault("conditions", ConditionTimeline.from_dicts(perts))
    return SimServing(model, reqs, **kwargs)
