"""Continuous-batching serving engine.

Fixed-slot continuous batching: a batched decode step runs every tick;
slots hold independent requests at their own depths (vector positions).
Arriving prompts are prefetched (B=1 prefill) and their caches scattered
into a free slot; finished slots free immediately — no head-of-line
blocking on long generations.

The engine feeds the paper's monitoring infrastructure: every request is
a *task* with a cost clause (prompt_len + max_new_tokens), prefill and
decode timings are aggregated per type, and the
:class:`~repro.serving.autoscale.AutoScaler` turns Algorithm 1 into a
replica/slot target Δ.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.events import EventBus, EventKind, RuntimeEvent
from ..core.governor import GovernorSpec, ResourceGovernor
from ..core.monitoring import TaskMonitor
from ..models import ModelConfig, decode_step, init_cache, prefill
from .admission import AdmissionController
from .slo import SLOClass

__all__ = ["Request", "ServingEngine"]


@dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int | None = None
    #: service contract (deadline/priority/…); None = plain best-effort
    #: FIFO request, byte-identical to the pre-SLO engine
    slo: SLOClass | None = None
    #: assigned by the engine at submit (ids are *per engine* — two
    #: engines in one process no longer interleave a global counter)
    request_id: int | None = None
    # -- filled by the engine ------------------------------------------
    output: list[int] = field(default_factory=list)
    submitted_at: float = 0.0
    done_at: float | None = None

    @property
    def cost(self) -> float:
        return float(len(self.prompt) + self.max_new_tokens)

    @property
    def type_name(self) -> str:
        return f"request:{self.slo.name}" if self.slo else "request"

    @property
    def priority(self) -> int:
        return self.slo.priority if self.slo else 0

    @property
    def done(self) -> bool:
        return self.done_at is not None


def _scatter_cache(dst: dict, src: dict, slot: int) -> dict:
    """Insert the B=1 cache ``src`` into batch slot ``slot`` of ``dst``.

    Stacked block caches carry batch at axis 1, remainder caches at 0.
    """
    def ins(axis):
        def f(d, s):
            idx = [0] * d.ndim
            idx[axis] = slot
            return jax.lax.dynamic_update_slice(d, s.astype(d.dtype),
                                                tuple(idx))
        return f

    return {
        "blocks": jax.tree.map(ins(1), dst["blocks"], src["blocks"]),
        "rest": jax.tree.map(ins(0), dst["rest"], src["rest"]),
    }


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 256, monitor: TaskMonitor | None = None,
                 governor: ResourceGovernor | None = None,
                 bus: EventBus | None = None,
                 clock: Callable[[], float] | None = None,
                 admission: AdmissionController | None = None,
                 brownout_tokens: int | None = None) -> None:
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        # Injected time source (tests/sims pass virtual clocks; the
        # default is the wall clock, referenced — never called — here).
        self._clock = clock if clock is not None else time.perf_counter
        # Overload protection (both default off = pre-SLO behaviour):
        # an AdmissionController sheds at submit; ``brownout_tokens``,
        # when set, truncates best-effort generations at admit time.
        self.admission = admission
        self.brownout_tokens = brownout_tokens
        #: requests refused by admission control (terminal; not queued)
        self.shed: list[Request] = []
        # Per-engine id stream for requests and decode ticks (was a
        # module global, which interleaved ids across engines and made
        # single-engine traces depend on process history).
        self._ids = itertools.count()
        # The engine is the workload side of the paper's loop: it
        # publishes request lifecycle events on ``self.bus``; the monitor
        # (owned by a governor — either one passed in and shared with an
        # AutoScaler, or a minimal monitoring-only stack assembled here)
        # subscribes, and so can a TraceRecorder for record/replay.
        self.bus = bus if bus is not None else EventBus()
        if governor is None:
            governor = ResourceGovernor(
                GovernorSpec(resources=max_batch, monitoring=True),
                monitor=monitor, bus=self.bus)
        elif monitor is not None and governor.monitor is not monitor:
            raise ValueError(
                "conflicting monitor and governor arguments: the engine "
                "feeds events to governor.monitor, so pass one or the "
                "other (or a governor built over that monitor)")
        if governor.bus is None:
            # Pull-style governors carry no worker manager, so adopting
            # the engine's bus late only affects where PREDICTION
            # samples are published — serving traces then show the
            # autoscaler's Δ decisions like every other frontend.
            governor.bus = self.bus
        self.governor = governor
        if governor.monitor is None:
            raise ValueError(
                "ServingEngine needs a monitoring governor — build it "
                "from a GovernorSpec with monitoring=True")
        self.monitor = governor.monitor
        self.monitor.subscribe(self.bus)
        self.queue: list[Request] = []
        self.active: list[Request | None] = [None] * max_batch
        self.cache = init_cache(cfg, max_batch, max_len)
        self.tokens = jnp.zeros((max_batch,), jnp.int32)
        self.pos = jnp.zeros((max_batch,), jnp.int32)
        self.remaining = np.zeros((max_batch,), np.int64)
        self._decode = jax.jit(
            lambda p, t, pos, c: decode_step(p, t, pos, c, cfg))
        # Prompt-length bucketing avoids a recompile per length.  Right-
        # padding is safe for attention archs (pad slots sit after `pos`
        # and are causally invisible); recurrent states would absorb the
        # padding, so those archs prefill at exact length.
        from ..models.config import LayerKind
        self._bucketing = all(k in (LayerKind.ATTN, LayerKind.MOE)
                              for k in cfg.pattern)
        self._prefill = jax.jit(
            lambda p, t: prefill(p, t, cfg, max_len=max_len,
                                 return_all_logits=self._bucketing))
        self.ticks = 0
        self.tokens_out = 0

    # -- request lifecycle ---------------------------------------------------

    def _publish(self, kind: EventKind, task_id: int, type_name: str,
                 cost: float, elapsed: float | None = None,
                 data: dict | None = None) -> None:
        self.bus.publish(RuntimeEvent(
            kind=kind, time=self._clock(), task_id=task_id,
            type_name=type_name, cost=cost, elapsed=elapsed,
            data=data or {}))

    def submit(self, req: Request) -> Request:
        if req.request_id is None:
            req.request_id = next(self._ids)
        req.submitted_at = self._clock()
        browned = False
        if (self.brownout_tokens is not None and req.slo is not None
                and req.slo.best_effort
                and req.max_new_tokens > self.brownout_tokens):
            # Brownout: truncate best-effort generations instead of
            # shedding them (graceful degradation under a cap).  Applied
            # before any event so the monitor accounts the served cost.
            req.max_new_tokens = self.brownout_tokens
            browned = True
        self._publish(EventKind.TASK_SUBMITTED, req.request_id,
                      req.type_name, req.cost)
        if browned:
            self._publish(EventKind.DEGRADE, req.request_id,
                          req.type_name, req.cost,
                          data={"mode": "brownout"})
        self._publish(EventKind.TASK_READY, req.request_id,
                      req.type_name, req.cost)
        if self.admission is not None:
            reason = self.admission.shed_reason(
                now=req.submitted_at, queue_depth=len(self.queue),
                slo=req.slo, submitted_at=req.submitted_at,
                est_wait_s=self._est_wait_s(),
                est_service_s=self._est_service_s(req))
            if reason is not None:
                # Monitor saw the READY above (bus-subscribed); reverse
                # it so shed work stops inflating Δ.
                self.monitor.on_task_shed(req.request_id, req.type_name,
                                          req.cost)
                req.done_at = req.submitted_at
                self.shed.append(req)
                self._publish(EventKind.SHED, req.request_id,
                              req.type_name, req.cost,
                              data={"reason": reason})
                return req
        self.queue.append(req)
        return req

    def _est_service_s(self, req: Request) -> float:
        """Predicted service seconds for ``req`` (0 while α is cold)."""
        alpha = self.monitor.unitary_cost(req.type_name)
        return req.cost * alpha if alpha is not None else 0.0

    def _est_wait_s(self) -> float:
        """Predicted queue wait: outstanding queued work over the batch
        width (0 while the α estimates are cold)."""
        total = 0.0
        for r in self.queue:
            alpha = self.monitor.unitary_cost(r.type_name)
            if alpha is not None:
                total += r.cost * alpha
        return total / max(1, self.max_batch)

    def _pop_next(self) -> Request:
        """Highest-priority queued request; FIFO within a priority
        class (all-default priorities reduce to plain ``pop(0)``)."""
        best = 0
        best_pri = self.queue[0].priority
        for i in range(1, len(self.queue)):
            pri = self.queue[i].priority
            if pri > best_pri:
                best, best_pri = i, pri
        return self.queue.pop(best)

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self._pop_next()
            self._publish(EventKind.TASK_EXECUTE, req.request_id,
                          req.type_name, req.cost)
            t0 = self._clock()
            toks = req.prompt
            if self._bucketing:
                bucket = max(16, 1 << (len(toks) - 1).bit_length())
                toks = toks + [0] * (bucket - len(toks))
            prompt = jnp.asarray([toks], jnp.int32)
            logits, cache1 = self._prefill(self.params, prompt)
            if self._bucketing:
                logits = logits[:, len(req.prompt) - 1]
            first = int(jnp.argmax(logits[0, :self.cfg.vocab]))
            self.cache = _scatter_cache(self.cache, cache1, slot)
            self.active[slot] = req
            req.output.append(first)
            self.tokens = self.tokens.at[slot].set(first)
            self.pos = self.pos.at[slot].set(len(req.prompt))
            self.remaining[slot] = req.max_new_tokens - 1
            elapsed = self._clock() - t0
            self._publish(EventKind.TASK_COMPLETED, req.request_id * 2 + 1,
                          "prefill", float(len(req.prompt)), elapsed)

    # -- decode tick ------------------------------------------------------------

    def tick(self) -> int:
        """Admit + one batched decode step.  Returns #active slots."""
        self._admit()
        live = [s for s, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        t0 = self._clock()
        logits, self.cache = self._decode(self.params, self.tokens,
                                          self.pos, self.cache)
        nxt = jnp.argmax(logits[:, :self.cfg.vocab], axis=-1) \
            .astype(jnp.int32)
        self.tokens = nxt
        self.pos = self.pos + 1
        elapsed = self._clock() - t0
        self._publish(EventKind.TASK_COMPLETED, next(self._ids) * 2,
                      "decode_tick", float(len(live)), elapsed)
        self.ticks += 1
        nxt_host = np.asarray(nxt)
        for s in live:
            req = self.active[s]
            assert req is not None
            tok = int(nxt_host[s])
            req.output.append(tok)
            self.tokens_out += 1
            self.remaining[s] -= 1
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if self.remaining[s] <= 0 or hit_eos \
                    or int(self.pos[s]) >= self.max_len - 1:
                req.done_at = self._clock()
                self._publish(EventKind.TASK_COMPLETED, req.request_id,
                              req.type_name, req.cost,
                              req.done_at - req.submitted_at)
                self.active[s] = None
        return len(live)

    def run_until_drained(self, max_ticks: int = 100_000) -> None:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.active):
                return
            self.tick()
        now = self._clock()
        live = [r for r in self.active if r is not None]
        oldest = min((r.submitted_at for r in self.queue + live),
                     default=now)
        raise RuntimeError(
            f"engine did not drain after {max_ticks} ticks: "
            f"{len(self.queue)} queued, {len(live)} active slots, "
            f"oldest request age {now - oldest:.3f}s")

    # -- autoscaler inputs ---------------------------------------------------------

    @property
    def load(self) -> int:
        return len(self.queue) + sum(r is not None for r in self.active)
