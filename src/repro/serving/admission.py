"""Overload protection primitives: admission control, circuit breaking,
and the power-cap brownout allowance.

These are pure, clock-injected decision objects — no wall clock, no
hidden state — shared by the live :class:`~repro.serving.engine.
ServingEngine` and the discrete-event
:class:`~repro.serving.simserving.SimServing` frontend, and unit-
testable without either.

* :class:`AdmissionController` answers "should this request enter the
  queue?" — shedding on queue depth and on deadline infeasibility
  (the prediction stack's estimated wait says the deadline is already
  lost, so the cheapest place to fail is *now*, before the request
  burns a slot).
* :class:`CircuitBreaker` is the classic three-state machine guarding
  one replica: CLOSED counts consecutive failures, OPEN quarantines
  until ``reset_after_s`` elapses, HALF_OPEN admits probe traffic and
  closes again after ``probe_successes`` clean completions (shape per
  the distributed-manager runtime's recovery/re-admission loop).
* :func:`cap_allowance` turns a facility power cap into the number of
  replicas that may run hot, assuming the worst case (every hot
  replica drawing active power) so compliance never depends on load.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from .slo import SLOClass

__all__ = ["AdmissionController", "CircuitBreaker", "cap_allowance"]


@dataclass(frozen=True)
class AdmissionController:
    """Shed-or-admit decisions for one serving frontend.

    ``max_queue_depth`` bounds the queue (None = unbounded; the caller
    decides between rejecting the newcomer and evicting a lower-
    priority victim).  The deadline check sheds a request whose
    estimated completion — now + estimated queue wait + its own
    estimated service — already overshoots its deadline by more than
    the ``slack`` factor allows.
    """

    max_queue_depth: int | None = None
    #: deadline-infeasibility safety factor: shed when the estimated
    #: completion exceeds ``deadline · slack`` past release (1.0 =
    #: shed exactly at infeasibility; > 1 tolerates estimate noise)
    slack: float = 1.0

    def __post_init__(self) -> None:
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError("max_queue_depth must be >= 1")
        if self.slack <= 0.0:
            raise ValueError("slack must be > 0")

    def shed_reason(self, *, now: float, queue_depth: int,
                    slo: SLOClass | None, submitted_at: float,
                    est_wait_s: float = 0.0,
                    est_service_s: float = 0.0) -> str | None:
        """None = admit; otherwise the shed reason ("queue"/"deadline").

        A "queue" verdict means the queue is full — the caller may
        still admit by evicting a lower-priority queued request.
        """
        if (self.max_queue_depth is not None
                and queue_depth >= self.max_queue_depth):
            return "queue"
        if slo is not None and slo.deadline_s is not None:
            eta = now + est_wait_s + est_service_s
            if eta > submitted_at + slo.deadline_s * self.slack:
                return "deadline"
        return None


class CircuitBreaker:
    """Three-state failure gate for one replica (clock-injected).

    CLOSED → (``failure_threshold`` consecutive failures, or
    :meth:`force_open` on a hard fault) → OPEN → (``reset_after_s``
    elapses) → HALF_OPEN → (``probe_successes`` consecutive successes)
    → CLOSED, or (any failure) → back to OPEN.

    The breaker never reads a clock: every transition is driven by the
    ``now`` its caller passes, so it is deterministic under virtual
    time and trivially unit-testable.
    """

    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"

    def __init__(self, failure_threshold: int = 3,
                 reset_after_s: float = 1.0,
                 probe_successes: int = 2) -> None:
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if reset_after_s < 0.0:
            raise ValueError("reset_after_s must be >= 0")
        if probe_successes < 1:
            raise ValueError("probe_successes must be >= 1")
        self.failure_threshold = failure_threshold
        self.reset_after_s = reset_after_s
        self.probe_successes = probe_successes
        self._state = self.CLOSED
        self._failures = 0
        self._probes = 0
        self._opened_at = 0.0

    def state(self, now: float) -> str:
        """Current state — an elapsed OPEN cooldown transitions to
        HALF_OPEN here, so simply *asking* advances the machine."""
        if (self._state == self.OPEN
                and now - self._opened_at >= self.reset_after_s):
            self._state = self.HALF_OPEN
            self._probes = 0
        return self._state

    def allow(self, now: float) -> bool:
        """May the replica take traffic?  HALF_OPEN allows probes — the
        caller limits their concurrency (typically to one in flight)."""
        return self.state(now) != self.OPEN

    def record_success(self, now: float) -> None:
        if self.state(now) == self.HALF_OPEN:
            self._probes += 1
            if self._probes >= self.probe_successes:
                self._state = self.CLOSED
                self._failures = 0
        else:
            self._failures = 0

    def record_failure(self, now: float) -> None:
        st = self.state(now)
        if st == self.HALF_OPEN:
            self._open(now)
        else:
            self._failures += 1
            if self._failures >= self.failure_threshold:
                self._open(now)

    def force_open(self, now: float) -> None:
        """Quarantine unconditionally (hard fault, e.g. CORE_FAIL)."""
        self._open(now)

    def _open(self, now: float) -> None:
        self._state = self.OPEN
        self._opened_at = now
        self._failures = 0
        self._probes = 0


def cap_allowance(cap_w: float,
                  draws: Sequence[tuple[float, float]]) -> int:
    """How many replicas may run hot under a ``cap_w`` power budget.

    ``draws`` lists, in wake-priority order (fastest first), each live
    replica's ``(active_watts, idle_watts)``.  The budget is charged
    worst-case: every hot replica at full active draw, every parked one
    at its idle floor — so the allowance is load-independent and a
    compliant schedule can never be pushed over the cap by a burst.
    """
    budget = cap_w - sum(idle for _, idle in draws)
    n = 0
    for active, idle in draws:
        step = active - idle
        if step > budget + 1e-12:
            break
        budget -= step
        n += 1
    return n
