"""Per-request SLO classes: deadlines, priorities, retry/hedge budgets.

An :class:`SLOClass` is the contract a request arrives with — how long
the client will wait (``deadline_s``), how it ranks against other
classes under overload (``priority``), and what the serving stack may
do on its behalf when an attempt stalls: time it out after
``timeout_s``, re-release it up to ``retry_budget`` times with
exponential backoff + jitter, and (for the latency-critical tail)
issue a hedged duplicate after ``hedge_after_s``.  ``best_effort``
classes additionally consent to brownout: under an active power cap
the engine may truncate their ``max_new_tokens`` instead of shedding
them.

The class is immutable and JSON-serializable, so a recorded serving
trace carries each request's full SLO contract and replays byte-
exactly.  Backoff jitter follows the repo's seeded wall-clock-free
discipline: a fresh ``random.Random`` keyed on
``(seed, request_id, attempt)`` per call, so the jitter of one request
never depends on how many other requests drew before it.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Mapping

__all__ = ["SLOClass", "INTERACTIVE", "STANDARD", "BATCH"]


@dataclass(frozen=True, slots=True)
class SLOClass:
    """One service-level contract shared by every request of a class."""

    name: str
    #: end-to-end latency bound in seconds (None = no deadline; the
    #: request is attained iff it completes at all)
    deadline_s: float | None = None
    #: admission rank under overload — higher wins a full queue
    priority: int = 0
    #: per-attempt timeout (None falls back to ``deadline_s``; both
    #: None = attempts never time out)
    timeout_s: float | None = None
    #: how many timed-out attempts may be re-released
    retry_budget: int = 0
    #: first-retry backoff; doubles per attempt
    backoff_base_s: float = 0.05
    #: ± fraction of the backoff drawn as seeded jitter
    backoff_jitter: float = 0.25
    #: issue a hedged duplicate if an attempt is still running after
    #: this many seconds (None = never hedge)
    hedge_after_s: float | None = None
    #: consents to brownout (``max_new_tokens`` truncation) under an
    #: active power cap instead of being shed
    best_effort: bool = False

    def __post_init__(self) -> None:
        if self.deadline_s is not None and self.deadline_s <= 0.0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")
        if self.timeout_s is not None and self.timeout_s <= 0.0:
            raise ValueError(f"timeout_s must be > 0, got {self.timeout_s}")
        if self.retry_budget < 0:
            raise ValueError("retry_budget must be >= 0")
        if self.backoff_base_s < 0.0:
            raise ValueError("backoff_base_s must be >= 0")
        if not 0.0 <= self.backoff_jitter < 1.0:
            raise ValueError("backoff_jitter must be in [0, 1)")
        if self.hedge_after_s is not None and self.hedge_after_s <= 0.0:
            raise ValueError("hedge_after_s must be > 0")

    @property
    def attempt_timeout_s(self) -> float | None:
        """Effective per-attempt timeout (falls back to the deadline)."""
        return self.timeout_s if self.timeout_s is not None \
            else self.deadline_s

    def backoff(self, attempt: int, *, seed: int = 0,
                request_id: int = 0) -> float:
        """Seconds to wait before re-releasing the ``attempt``-th try
        (attempt 1 = first retry): exponential base with seeded jitter.

        Deterministic and order-independent — keyed on
        ``(seed, request_id, attempt)``, not on a shared PRNG stream —
        so concurrent requests retry at reproducible instants
        regardless of interleaving (the property the byte-exact
        serving replay relies on).
        """
        if attempt < 1:
            raise ValueError(f"attempt must be >= 1, got {attempt}")
        base = self.backoff_base_s * (2.0 ** (attempt - 1))
        if self.backoff_jitter == 0.0 or base == 0.0:
            return base
        # str seeding hashes via sha512 — stable across processes and
        # Python versions, unlike (deprecated) tuple seeding
        rng = random.Random(f"{seed}:{request_id}:{attempt}")
        return base * rng.uniform(1.0 - self.backoff_jitter,
                                  1.0 + self.backoff_jitter)

    # -- serialization (trace round trip) -----------------------------------

    def to_dict(self) -> dict[str, Any]:
        d: dict[str, Any] = {"name": self.name}
        if self.deadline_s is not None:
            d["deadline_s"] = self.deadline_s
        if self.priority:
            d["priority"] = self.priority
        if self.timeout_s is not None:
            d["timeout_s"] = self.timeout_s
        if self.retry_budget:
            d["retry_budget"] = self.retry_budget
        if self.backoff_base_s != 0.05:
            d["backoff_base_s"] = self.backoff_base_s
        if self.backoff_jitter != 0.25:
            d["backoff_jitter"] = self.backoff_jitter
        if self.hedge_after_s is not None:
            d["hedge_after_s"] = self.hedge_after_s
        if self.best_effort:
            d["best_effort"] = True
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SLOClass":
        return cls(**dict(d))


#: latency-critical traffic: tight deadline, top priority, one retry,
#: hedged tail
INTERACTIVE = SLOClass("interactive", deadline_s=3.0, priority=2,
                       timeout_s=1.5, retry_budget=1, hedge_after_s=1.0)

#: default traffic: looser deadline, one retry, no hedging
STANDARD = SLOClass("standard", deadline_s=10.0, priority=1,
                    timeout_s=5.0, retry_budget=1)

#: throughput traffic: no deadline, lowest priority, browns out under
#: a power cap instead of being shed
BATCH = SLOClass("batch", best_effort=True)
