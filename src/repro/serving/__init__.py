"""Serving tier: continuous-batching engine + prediction-based
autoscaling (the paper's Algorithm 1/2 applied to serving replicas),
with SLO-aware overload protection (admission control, retries/hedging,
circuit breakers, brownout) and a discrete-event frontend that runs the
whole robustness story at 10⁵-request scale in virtual time."""

from .engine import Request, ServingEngine
from .autoscale import AutoScaler
from .slo import BATCH, INTERACTIVE, STANDARD, SLOClass
from .admission import AdmissionController, CircuitBreaker, cap_allowance
from .simserving import (ServingModel, SimRequest, SimServing,
                         build_requests, replay_serving)

__all__ = [
    "Request", "ServingEngine", "AutoScaler",
    "SLOClass", "INTERACTIVE", "STANDARD", "BATCH",
    "AdmissionController", "CircuitBreaker", "cap_allowance",
    "ServingModel", "SimRequest", "SimServing", "build_requests",
    "replay_serving",
]
