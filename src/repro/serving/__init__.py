"""Serving tier: continuous-batching engine + prediction-based
autoscaling (the paper's Algorithm 1/2 applied to serving replicas)."""

from .engine import Request, ServingEngine
from .autoscale import AutoScaler

__all__ = ["Request", "ServingEngine", "AutoScaler"]
