"""Prediction-based replica autoscaling — Algorithm 1 applied to serving.

A serving deployment holds up to ``max_replicas`` engine replicas.  The
monitoring infrastructure aggregates request workloads (cost = prompt +
expected new tokens, normalized by measured service times into unitary
costs α), and the :class:`~repro.core.prediction.CPUPredictor` computes
the optimal replica count Δ at the prediction rate — the serving twin of
the paper's CPU manager:

* **busy**   — all replicas always hot (max throughput, max energy)
* **idle**   — replicas park the moment they have no work
* **prediction** — replicas track Δ

Replica lifecycle costs (model load / cache warmup) play the role of the
paper's thread resume latency; the EDP trade-off reproduces Fig. 4's
story at serving granularity (``benchmarks/bench_serving.py``).
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.monitoring import TaskMonitor
from ..core.prediction import CPUPredictor, PredictionConfig

__all__ = ["AutoScaler"]


@dataclass
class AutoScaler:
    monitor: TaskMonitor
    max_replicas: int
    policy: str = "prediction"          # busy | idle | prediction
    min_replicas: int = 1
    rate_s: float = 0.05

    def __post_init__(self) -> None:
        self.predictor = CPUPredictor(
            self.monitor, n_cpus=self.max_replicas,
            config=PredictionConfig(rate_s=self.rate_s, min_samples=3))

    def target(self, queued: int, active: int) -> int:
        """Replicas to keep hot, given current queue/active request counts."""
        if self.policy == "busy":
            return self.max_replicas
        if self.policy == "idle":
            return max(self.min_replicas if queued + active else 0,
                       min(queued + active, self.max_replicas))
        delta = self.predictor.tick()
        if queued + active == 0:
            return 0
        return max(self.min_replicas, min(delta, self.max_replicas))
