"""Prediction-based replica autoscaling — Algorithm 1 applied to serving.

A serving deployment holds up to ``max_replicas`` engine replicas.  The
monitoring infrastructure aggregates request workloads (cost = prompt +
expected new tokens, normalized by measured service times into unitary
costs α) and a :class:`~repro.core.governor.ResourceGovernor` — built from
the same :class:`~repro.core.governor.GovernorSpec` that drives the
executors — computes the optimal replica count Δ at the prediction rate,
the serving twin of the paper's CPU manager:

* **busy**   — all replicas always hot (max throughput, max energy)
* **idle**   — replicas park the moment they have no work
* **prediction** — replicas track Δ

The target decision is made by the registered :class:`Policy` object
(``Policy.target``), not by branching on policy names, so any registered
policy works here unchanged.

Replica lifecycle costs (model load / cache warmup) play the role of the
paper's thread resume latency; the EDP trade-off reproduces Fig. 4's
story at serving granularity (``benchmarks/bench_serving.py``).
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.events import EventBus
from ..core.governor import GovernorSpec, ResourceGovernor
from ..core.monitoring import TaskMonitor
from ..core.prediction import PredictionConfig

__all__ = ["AutoScaler"]


@dataclass
class AutoScaler:
    monitor: TaskMonitor
    max_replicas: int
    policy: str = "prediction"          # any registered policy name
    min_replicas: int = 1
    rate_s: float = 0.05
    spec: GovernorSpec | None = None    # overrides the kwargs above
    #: runtime event bus (e.g. ``ServingEngine.bus``) — Δ decisions are
    #: published as PREDICTION events so serving traces record them
    bus: EventBus | None = None

    def __post_init__(self) -> None:
        if self.spec is None:
            self.spec = GovernorSpec(
                resources=self.max_replicas, policy=self.policy,
                min_resources=self.min_replicas,
                prediction=PredictionConfig(rate_s=self.rate_s),
                monitoring=True)
        else:
            # an explicit spec wins: keep the public fields in sync
            self.max_replicas = self.spec.resources
            self.min_replicas = self.spec.min_resources
            self.policy = self.spec.policy
            self.rate_s = self.spec.prediction.rate_s
        self.governor = ResourceGovernor(self.spec, monitor=self.monitor,
                                         bus=self.bus)
        self.predictor = self.governor.predictor

    def target(self, queued: int, active: int) -> int:
        """Replicas to keep hot, given current queue/active request counts."""
        return self.governor.target(queued, active)
