"""LM substrate: one pattern-based decoder covering dense GQA, MoE,
RG-LRU hybrid and RWKV-6 architectures, with KV-cache serving paths."""

from .config import LayerKind, ModelConfig
from .sharding import Rules
from .transformer import (cache_specs, decode_step, forward, init_cache,
                          init_params, lm_loss, param_specs, prefill)

__all__ = [
    "LayerKind", "ModelConfig", "Rules",
    "cache_specs", "decode_step", "forward", "init_cache", "init_params",
    "lm_loss", "param_specs", "prefill",
]
