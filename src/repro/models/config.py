"""Model configuration — one dataclass describes every assigned arch.

A model is a *pattern* of layer kinds repeated over depth.  Homogeneous
repeats are stacked and scanned (bounded HLO size / compile time at 1000+
layers); a non-divisible remainder is unrolled.

Layer kinds:
  ``attn``    dense GQA attention block (optional window / softcap / bias)
  ``moe``     GQA attention + mixture-of-experts FFN
  ``rglru``   RG-LRU recurrent block (RecurrentGemma)
  ``rwkv``    RWKV-6 time-mix + channel-mix block (attention-free)
"""

from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass

__all__ = ["LayerKind", "ModelConfig"]


class LayerKind(str, enum.Enum):
    ATTN = "attn"
    MOE = "moe"
    RGLRU = "rglru"
    RWKV = "rwkv"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int            # query heads (0 for attention-free archs)
    kv_heads: int           # KV heads (GQA); == n_heads ⇒ MHA
    d_ff: int
    vocab: int
    head_dim: int = 128
    # -- layer pattern -------------------------------------------------------
    #: repeating unit of layer kinds; cycled over n_layers.
    pattern: tuple[LayerKind, ...] = (LayerKind.ATTN,)
    # -- attention flavor ------------------------------------------------------
    #: sliding-window size for *local* attention layers (None ⇒ global).
    window: int | None = None
    #: which pattern positions use the window (True ⇒ local); len == pattern.
    local_mask: tuple[bool, ...] | None = None
    attn_softcap: float | None = None     # gemma2: 50.0
    logit_softcap: float | None = None    # gemma2: 30.0
    qkv_bias: bool = False                # qwen1.5
    rope_theta: float = 10_000.0
    # -- MoE --------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0             # llama4: 1 shared expert
    #: sequence-chunk size for dispatch einsums (bounds the (B,S,E,C) temp)
    moe_seq_chunk: int = 512
    # -- recurrent (RG-LRU / RWKV) -------------------------------------------------
    rnn_width: int | None = None          # RG-LRU recurrent width (d_rnn)
    conv_width: int = 4                   # temporal conv in RG-LRU block
    #: WKV chunk length (pairwise-decay tile);  traffic ≈ S·L·N + S/L·N²
    #: is minimized near L = √N = 8 (see EXPERIMENTS.md §Perf)
    rwkv_chunk: int = 16
    #: KV-cache storage dtype; "int8" halves decode cache traffic using
    #: fixed-scale symmetric quantization (post-RoPE keys are O(1))
    cache_dtype: str = "bfloat16"
    # -- activation / norm flavor ---------------------------------------------------
    mlp: str = "swiglu"                   # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    post_norms: bool = False              # gemma2: post-attn/post-ffn norms
    embed_scale: bool = False             # gemma-family: scale embed by sqrt(d)
    tie_embeddings: bool = True
    # -- frontend stub (vlm / audio) -------------------------------------------------
    #: if > 0, input_specs provide (B, frontend_len, d_model) embeddings that
    #: are prepended to the token embeddings (modality frontends are stubs).
    frontend_len: int = 0
    # -- training-time knobs -----------------------------------------------------------
    remat: str = "full"                   # none | full | dots
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    #: cross-entropy computed over sequence chunks of this size (0 ⇒ whole
    #: sequence at once); bounds the (B,S,V) logits temporary.
    ce_seq_chunk: int = 0

    # -- derived -------------------------------------------------------------------------

    def layer_kinds(self) -> list[LayerKind]:
        p = list(self.pattern)
        return [p[i % len(p)] for i in range(self.n_layers)]

    def layer_is_local(self, pattern_pos: int) -> bool:
        if self.window is None:
            return False
        if self.local_mask is None:
            return True
        return self.local_mask[pattern_pos % len(self.pattern)]

    @property
    def n_units(self) -> int:
        """Number of full pattern repeats (scanned)."""
        return self.n_layers // len(self.pattern)

    @property
    def n_remainder(self) -> int:
        """Trailing layers not forming a full pattern (unrolled)."""
        return self.n_layers % len(self.pattern)

    @property
    def attention_free(self) -> bool:
        return all(k in (LayerKind.RWKV,) for k in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is O(1)/bounded ⇒ long-context capable."""
        kinds = set(self.layer_kinds())
        if kinds <= {LayerKind.RWKV, LayerKind.RGLRU}:
            return True
        # attention layers are fine iff every one is windowed
        if self.window is None:
            return False
        for i, k in enumerate(self.layer_kinds()):
            if k in (LayerKind.ATTN, LayerKind.MOE) \
                    and not self.layer_is_local(i):
                return False
        return True

    def padded_vocab(self, multiple: int = 128) -> int:
        return ((self.vocab + multiple - 1) // multiple) * multiple

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for 6·N·D roofline checks) ------------------------------

    def param_count(self) -> tuple[int, int]:
        """Returns (total_params, active_params_per_token)."""
        d, ff, hd = self.d_model, self.d_ff, self.head_dim
        total = active = self.padded_vocab() * d  # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab() * d
            active += self.padded_vocab() * d
        for i, kind in enumerate(self.layer_kinds()):
            if kind in (LayerKind.ATTN, LayerKind.MOE):
                attn = d * self.n_heads * hd + 2 * d * self.kv_heads * hd \
                    + self.n_heads * hd * d
                total += attn
                active += attn
            if kind is LayerKind.ATTN:
                m = d * ff * (3 if self.mlp in ("swiglu", "geglu") else 2)
                total += m
                active += m
            elif kind is LayerKind.MOE:
                m1 = d * ff * (3 if self.mlp in ("swiglu", "geglu") else 2)
                total += self.n_experts * m1 + d * self.n_experts
                active += (self.top_k + self.n_shared_experts) * m1 \
                    + d * self.n_experts
                total += self.n_shared_experts * m1
            elif kind is LayerKind.RGLRU:
                rnn = self.rnn_width or d
                blk = d * rnn * 2 + rnn * d + rnn * self.conv_width \
                    + 2 * rnn * rnn // 8 + rnn  # gates are block-diagonal
                m = d * ff * (3 if self.mlp in ("swiglu", "geglu") else 2)
                total += blk + m
                active += blk + m
            elif kind is LayerKind.RWKV:
                tm = d * d * 4 + d * 64 * 2 + d * 32 * 2  # r,k,v,o + w/g lora
                cm = d * ff * 2
                total += tm + cm
                active += tm + cm
        return total, active
