"""RWKV-6 ("Finch") — attention-free time-mix with data-dependent decay.

Per head (head dim N = 64), the WKV state is an N×N matrix:

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
    y_t = r_t · (S_{t-1} + diag(u) · k_tᵀ v_t)

with w_t = exp(-exp(w0 + LoRA_w(x̄_t))) — the data-dependent decay that
distinguishes RWKV-6 from RWKV-5.  Token-shift mixing (ddlerp) computes
per-channel interpolations between x_t and x_{t-1} with LoRA-modulated
coefficients for each of r/k/v/w/g.

Training/prefill uses a *chunked* formulation (chunk L): within a chunk
the decays are factored into cumulative products so the intra-chunk part
is two masked matmuls, and the state is carried across chunks by a scan —
O(S·N²/L) state math + O(S·L·N) matmuls, numerically guarded by clamping
log-decay spans (contributions below e^-40 are flushed).  Decode carries
the state matrix: O(1) per token.  The Pallas kernel in
:mod:`repro.kernels.rwkv6` implements the same chunked algorithm.

Channel-mix is the RWKV squared-ReLU MLP.  Head-wise GroupNorm follows
the WKV output (per the reference implementation).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .layers import rmsnorm

__all__ = ["wkv6_chunked", "wkv6_scan_ref", "rwkv_block", "init_rwkv"]

_CLAMP = 40.0


def wkv6_scan_ref(r, k, v, w, u, s0=None):
    """Step-by-step reference.  r,k,v,w: (B,H,S,N); u: (H,N).

    Returns (y (B,H,S,N), s_final (B,H,N,N)).  fp32 math.
    """
    B, H, S, N = r.shape
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    if s0 is None:
        s0 = jnp.zeros((B, H, N, N), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                     # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, y

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (r, k, v, w))
    s_fin, ys = lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 2), s_fin


def wkv6_chunked(r, k, v, w, u, s0=None, chunk: int = 16):
    """Chunked parallel WKV-6.  Same signature/results as the scan ref.

    Intra-chunk decays use the exact pairwise log-difference
    ``lc_{t-1} − lc_s`` (≤ 0 for s < t, so a single one-sided clip is
    lossless down to e^-40); the (L, L, N) pairwise tensor is why the
    chunk is kept small — the Pallas kernel holds it in VMEM.
    """
    B, H, S, N = r.shape
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    if s0 is None:
        s0 = jnp.zeros((B, H, N, N), jnp.float32)
    if S % chunk != 0:
        pad = chunk - S % chunk
        zeros = jnp.zeros((B, H, pad, N), jnp.float32)
        r = jnp.concatenate([r, zeros], axis=2)
        k = jnp.concatenate([k, zeros], axis=2)
        v = jnp.concatenate([v, zeros], axis=2)
        w = jnp.concatenate([w, jnp.ones((B, H, pad, N), jnp.float32)],
                            axis=2)
    L = chunk
    n = r.shape[2] // L

    def reshape(t):
        return t.reshape(B, H, n, L, N).transpose(2, 0, 1, 3, 4)

    rc, kc, vc, wc = (reshape(t) for t in (r, k, v, w))   # (n,B,H,L,N)

    def body(s, inp):
      with jax.named_scope("pallas:wkv6"):
        rt, kt, vt, wt = inp                      # (B,H,L,N)
        lw = jnp.log(jnp.clip(wt, 1e-38))         # ≤ 0
        cum = jnp.cumsum(lw, axis=2)              # inclusive  lc_t
        cum_ex = cum - lw                         # exclusive  lc_{t-1}
        # Intra-chunk: exact pairwise decay D[t,s] = exp(lc_{t-1} − lc_s)
        # for s < t (exponent ≤ 0 ⇒ one-sided clip is lossless).
        diff = cum_ex[:, :, :, None, :] - cum[:, :, None, :, :]
        decay = jnp.exp(jnp.clip(diff, -_CLAMP, 0.0))     # (B,H,L,L,N)
        scores = jnp.einsum("bhln,bhmn,bhlmn->bhlm", rt, kt, decay)
        mask = jnp.tril(jnp.ones((L, L), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        bonus = jnp.einsum("bhln,bhln->bhl", rt, u[None, :, None, :] * kt)
        y = jnp.einsum("bhlm,bhmn->bhln", scores, vt) \
            + bonus[..., None] * vt
        # Inter-chunk: initial-state contribution (exponent ≤ 0).
        r_dec = rt * jnp.exp(jnp.clip(cum_ex, -_CLAMP, 0.0))
        y = y + jnp.einsum("bhln,bhnm->bhlm", r_dec, s)
        # State update: S' = diag(exp(lc_L))·S + Σ_s k_s·exp(lc_L−lc_s)·v_sᵀ
        tail = cum[:, :, -1:, :]                  # lc_L  (B,H,1,N)
        k_tail = kt * jnp.exp(jnp.clip(tail - cum, -_CLAMP, 0.0))
        s_new = jnp.exp(jnp.clip(tail[:, :, 0, :, None], -_CLAMP, 0.0)) * s \
            + jnp.einsum("bhln,bhlm->bhnm", k_tail, vt)
        return s_new, y

    s_fin, ys = lax.scan(body, s0, (rc, kc, vc, wc))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, -1, N)[:, :, :S]
    return y, s_fin


def _ddlerp(x, xx, mu, lora_a, lora_b):
    """Data-dependent lerp: x + (x_prev − x) · (μ + tanh((x+Δ·μx)A)B)."""
    m = mu + jnp.tanh((x + xx * mu) @ lora_a) @ lora_b
    return x + xx * m


def rwkv_block(x: jax.Array, p: dict, cfg,
               state: dict | None = None) -> tuple[jax.Array, dict | None]:
    """Full RWKV-6 block (time-mix + channel-mix).  x: (B, S, d).

    ``state`` (decode): {"shift_t", "shift_c": (B,d), "wkv": (B,H,N,N)}.
    """
    B, S, d = x.shape
    N = 64
    H = d // N
    new_state: dict | None = None

    # ---- time mix -----------------------------------------------------
    xt = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if state is not None:
        prev = jnp.concatenate(
            [state["shift_t"].astype(xt.dtype)[:, None, :], xt[:, :-1]],
            axis=1)
    else:
        prev = jnp.pad(xt, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xx = prev - xt
    tm = p["tm"]
    xr = _ddlerp(xt, xx, tm["mu_r"], tm["a_r"], tm["b_r"])
    xk = _ddlerp(xt, xx, tm["mu_k"], tm["a_k"], tm["b_k"])
    xv = _ddlerp(xt, xx, tm["mu_v"], tm["a_v"], tm["b_v"])
    xw = _ddlerp(xt, xx, tm["mu_w"], tm["a_w"], tm["b_w"])
    xg = _ddlerp(xt, xx, tm["mu_g"], tm["a_g"], tm["b_g"])

    r = (xr @ tm["wr"]).reshape(B, S, H, N).transpose(0, 2, 1, 3)
    kk = (xk @ tm["wk"]).reshape(B, S, H, N).transpose(0, 2, 1, 3)
    vv = (xv @ tm["wv"]).reshape(B, S, H, N).transpose(0, 2, 1, 3)
    g = jax.nn.silu(xg @ tm["wg"])
    logw = tm["w0"] + jnp.tanh(xw @ tm["a_w2"]) @ tm["b_w2"]
    wdec = jnp.exp(-jnp.exp(logw.astype(jnp.float32)))
    wdec = wdec.reshape(B, S, H, N).transpose(0, 2, 1, 3)

    s0 = state["wkv"] if state is not None else None
    if S == 1 and state is not None:
        y, s_fin = wkv6_scan_ref(r, kk, vv, wdec, tm["u"], s0)
    else:
        y, s_fin = wkv6_chunked(r, kk, vv, wdec, tm["u"], s0,
                                chunk=getattr(cfg, "rwkv_chunk", 16))
    y = y.transpose(0, 2, 1, 3).reshape(B, S, d)
    # Head-wise group norm.
    yh = y.reshape(B, S, H, N).astype(jnp.float32)
    yh = (yh - yh.mean(-1, keepdims=True)) \
        * lax.rsqrt(yh.var(-1, keepdims=True) + 64e-5)
    y = (yh.reshape(B, S, d) * tm["gn_w"] + tm["gn_b"]).astype(x.dtype)
    out = x + (y * g) @ tm["wo"]

    # ---- channel mix ----------------------------------------------------
    xc = rmsnorm(out, p["ln2"], cfg.norm_eps)
    if state is not None:
        prevc = jnp.concatenate(
            [state["shift_c"].astype(xc.dtype)[:, None, :], xc[:, :-1]],
            axis=1)
    else:
        prevc = jnp.pad(xc, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xxc = prevc - xc
    cm = p["cm"]
    xk2 = xc + xxc * cm["mu_k"]
    xr2 = xc + xxc * cm["mu_r"]
    kk2 = jnp.square(jax.nn.relu(xk2 @ cm["wk"]))
    out = out + jax.nn.sigmoid(xr2 @ cm["wr"]) * (kk2 @ cm["wv"])

    if state is not None:
        new_state = {"shift_t": xt[:, -1], "shift_c": xc[:, -1],
                     "wkv": s_fin}
    return out, new_state


def init_rwkv(key: jax.Array, cfg, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    N = 64
    H = d // N
    lora, lora_w = 32, 64
    ks = iter(jax.random.split(key, 24))
    std = 1.0 / math.sqrt(d)

    def nrm(shape, scale=std):
        return jax.random.normal(next(ks), shape, dtype) * scale

    tm = {"u": jax.random.normal(next(ks), (H, N), jnp.float32) * 0.1,
          "w0": jnp.linspace(-6.0, -0.5, d).astype(jnp.float32),
          "a_w2": nrm((d, lora_w)), "b_w2": nrm((lora_w, d), 0.01),
          "gn_w": jnp.ones((d,), jnp.float32),
          "gn_b": jnp.zeros((d,), jnp.float32)}
    for nm in ("r", "k", "v", "w", "g"):
        tm[f"mu_{nm}"] = jnp.full((d,), 0.5, dtype)
        tm[f"a_{nm}"] = nrm((d, lora))
        tm[f"b_{nm}"] = nrm((lora, d), 0.01)
    for nm in ("wr", "wk", "wv", "wg", "wo"):
        tm[nm] = nrm((d, d))
    cm = {"mu_k": jnp.full((d,), 0.5, dtype),
          "mu_r": jnp.full((d,), 0.5, dtype),
          "wk": nrm((d, ff)), "wv": nrm((ff, d), 1.0 / math.sqrt(ff)),
          "wr": nrm((d, d))}
    return {"ln1": jnp.zeros((d,), dtype), "ln2": jnp.zeros((d,), dtype),
            "tm": tm, "cm": cm}
