"""Core transformer layers: RMSNorm, RoPE, GQA attention (global /
sliding-window / softcap / bias), gated MLPs, and KV-cache decode paths.

Everything is pure JAX (jit/pjit-compatible); attention over long
sequences is *q-chunked* (scan over query blocks with bounded score
temporaries) so prefill_32k fits HBM without a kernel.  The Pallas flash
kernel in :mod:`repro.kernels.flash_attention` is a drop-in replacement
for the inner block math on real TPUs.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "rmsnorm", "rope", "gqa_attention", "decode_gqa_attention",
    "mlp_apply", "init_attn_layer", "init_mlp",
]


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return ((1.0 + w.astype(jnp.float32)) * x).astype(dt)


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, D); pos: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = pos.astype(jnp.float32)[..., None] * freqs       # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                       # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _softcap(scores: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def _score_block(q_blk, k, softcap, scale):
    # q_blk: (B, Sq, K, G, D), k: (B, Skv, K, D) -> (B, K, G, Sq, Skv)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k,
                   preferred_element_type=jnp.float32) * scale
    return _softcap(s, softcap)


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int | None = None,
                  softcap: float | None = None,
                  q_chunk: int = 512,
                  pos_offset: int = 0) -> jax.Array:
    """Causal grouped-query attention over a full sequence.

    q: (B, S, H, D); k, v: (B, S, K, D) with H = K·G.  Scanned over query
    chunks: peak score temp is (B, K, G, q_chunk, kv_span) where kv_span
    is S for global layers and window + q_chunk for local ones.
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, S, K, G, D)

    if S <= q_chunk:
        with jax.named_scope("pallas:flash_attention"):
            pos = pos_offset + jnp.arange(S)
            s = _score_block(qg, k, softcap, scale)
            mask = pos[:, None] >= pos[None, :]
            if window is not None:
                mask &= pos[:, None] - pos[None, :] < window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
            o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
        return o.reshape(B, S, H, D)

    assert S % q_chunk == 0, (S, q_chunk)
    n_blocks = S // q_chunk
    qg = qg.reshape(B, n_blocks, q_chunk, K, G, D)

    # NOTE: each chunk body is checkpointed — without this, the backward
    # pass of the scan stacks every chunk's (B,K,G,c,kv_span) probability
    # tensor as residuals, exactly the O(S²) memory the chunking avoids.
    if window is not None:
        # Local: each q block attends to a fixed-size kv span ending at the
        # block end.  Span is padded on the left so slicing is static-size.
        span = window + q_chunk
        k_pad = jnp.pad(k, ((0, 0), (span - q_chunk, 0), (0, 0), (0, 0)))
        v_pad = jnp.pad(v, ((0, 0), (span - q_chunk, 0), (0, 0), (0, 0)))

        @jax.checkpoint
        def blk(_, i):
            with jax.named_scope("pallas:flash_attention"):
                qb = qg[:, i]                               # (B,c,K,G,D)
                kb = lax.dynamic_slice_in_dim(k_pad, i * q_chunk, span,
                                              axis=1)
                vb = lax.dynamic_slice_in_dim(v_pad, i * q_chunk, span,
                                              axis=1)
                qpos = i * q_chunk + jnp.arange(q_chunk)
                kpos = i * q_chunk + jnp.arange(span) - (span - q_chunk)
                s = _score_block(qb, kb, softcap, scale)
                m = (qpos[:, None] >= kpos[None, :]) \
                    & (qpos[:, None] - kpos[None, :] < window) \
                    & (kpos[None, :] >= 0)
                s = jnp.where(m[None, None, None], s, -jnp.inf)
                p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
                return None, jnp.einsum("bkgqs,bskd->bqkgd", p, vb)

        _, o = lax.scan(blk, None, jnp.arange(n_blocks))
        o = jnp.moveaxis(o, 0, 1)                 # (B, n, c, K, G, D)
        return o.reshape(B, S, H, D)

    @jax.checkpoint
    def blk(_, i):
        with jax.named_scope("pallas:flash_attention"):
            qb = qg[:, i]
            qpos = pos_offset + i * q_chunk + jnp.arange(q_chunk)
            kpos = pos_offset + jnp.arange(S)
            s = _score_block(qb, k, softcap, scale)   # (B,K,G,c,S)
            m = qpos[:, None] >= kpos[None, :]
            s = jnp.where(m[None, None, None], s, -jnp.inf)
            p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
            return None, jnp.einsum("bkgqs,bskd->bqkgd", p, v)

    _, o = lax.scan(blk, None, jnp.arange(n_blocks))
    o = jnp.moveaxis(o, 0, 1)
    return o.reshape(B, S, H, D)


def decode_gqa_attention(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, pos: jax.Array, *,
                         ring: bool,
                         softcap: float | None = None) -> jax.Array:
    """One-token attention against a cache.

    q: (B, 1, H, D); caches: (B, Sc, K, D); ``pos`` — the position of the
    current token, scalar (homogeneous batch) or (B,) vector (continuous
    batching: every slot at its own depth).  ``ring=True`` means the
    cache is a ring buffer (slot = position mod Sc).  Keys are stored
    post-RoPE.
    """
    B, _, H, D = q.shape
    Sc, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    with jax.named_scope("pallas:flash_decode"):
        qg = q.reshape(B, 1, K, G, D)
        s = _score_block(qg, k_cache, softcap, scale)  # (B,K,G,1,Sc)
        slots = jnp.arange(Sc)
        posb = pos if getattr(pos, "ndim", 0) else jnp.full((B,), pos)
        posb = posb[:, None]                           # (B, 1)
        if ring:
            slot_pos = posb - ((posb - slots[None, :]) % Sc)
            valid = (slot_pos >= 0) & (slot_pos <= posb)
        else:
            valid = slots[None, :] <= posb
        s = jnp.where(valid[:, None, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache)
    return o.reshape(B, 1, H, D)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_apply(x: jax.Array, p: dict, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["w1"], approximate=True) * (x @ p["w3"])
    elif kind == "gelu":
        h = jax.nn.gelu(x @ p["w1"], approximate=True)
    else:
        raise ValueError(kind)
    return h @ p["w2"]


def init_mlp(key: jax.Array, d: int, ff: int, kind: str,
             dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(ff)
    p = {
        "w1": jax.random.normal(k1, (d, ff), dtype) * std_in,
        "w2": jax.random.normal(k2, (ff, d), dtype) * std_out,
    }
    if kind in ("swiglu", "geglu"):
        p["w3"] = jax.random.normal(k3, (d, ff), dtype) * std_in
    return p


def init_attn_layer(key: jax.Array, cfg, dtype) -> dict:
    """Weights for one attention block (projections + norms + MLP)."""
    d, H, K, D = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    std = 1.0 / math.sqrt(d)
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "wq": jax.random.normal(ks[0], (d, H * D), dtype) * std,
        "wk": jax.random.normal(ks[1], (d, K * D), dtype) * std,
        "wv": jax.random.normal(ks[2], (d, K * D), dtype) * std,
        "wo": jax.random.normal(ks[3], (H * D, d), dtype)
        / math.sqrt(H * D),
        "ln2": jnp.zeros((d,), dtype),
        "mlp": init_mlp(ks[4], d, cfg.d_ff, cfg.mlp, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * D,), dtype)
        p["bk"] = jnp.zeros((K * D,), dtype)
        p["bv"] = jnp.zeros((K * D,), dtype)
    if cfg.post_norms:
        p["ln1_post"] = jnp.zeros((d,), dtype)
        p["ln2_post"] = jnp.zeros((d,), dtype)
    return p
