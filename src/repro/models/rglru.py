"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block structure (Griffin, arXiv:2402.19427):

    x ─ RMSNorm ─┬─ linear gate ── GeLU ──────────────┐
                 └─ linear y ── causal conv1d ── RG-LRU ⊙ ── linear out ─ +residual

RG-LRU recurrence (all elementwise over the recurrent width):

    r_t = σ(W_a x_t + b_a)          (recurrence gate, block-diagonal W_a)
    i_t = σ(W_x x_t + b_x)          (input gate,      block-diagonal W_x)
    a_t = exp(-c · softplus(Λ) · r_t)            c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill uses ``jax.lax.associative_scan`` over (a, b) pairs —
O(log S) depth, fp32 carries.  Decode keeps an O(1) state: the hidden
``h`` plus the last ``conv_width−1`` conv inputs.  The Pallas kernel in
:mod:`repro.kernels.rglru` implements the same scan with chunked VMEM
tiles.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .layers import rmsnorm

__all__ = ["rglru_scan", "rglru_block", "init_rglru", "conv1d_causal",
           "RGLRU_C"]

RGLRU_C = 8.0


def _gates(x: jax.Array, p: dict) -> tuple[jax.Array, jax.Array]:
    """Block-diagonal gate projections.  x: (B, S, R) → (a_t, gated input)."""
    B, S, R = x.shape
    H = p["wa"].shape[0]                       # gate heads
    xh = x.reshape(B, S, H, R // H)
    r = jax.nn.sigmoid(
        jnp.einsum("bshr,hrk->bshk", xh, p["wa"]) + p["ba"])
    i = jax.nn.sigmoid(
        jnp.einsum("bshr,hrk->bshk", xh, p["wx"]) + p["bx"])
    r = r.reshape(B, S, R)
    i = i.reshape(B, S, R)
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = i * x
    return a, gated


def rglru_scan(a: jax.Array, bx: jax.Array,
               h0: jax.Array | None = None) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t via associative scan over the time axis.

    a, bx: (B, S, R) fp32; h0: (B, R) initial state or None.
    Returns h: (B, S, R).
    """
    a = a.astype(jnp.float32)
    bx = bx.astype(jnp.float32)
    if h0 is not None:
        # Fold the initial state into the first step: b_1 += a_1 h_0.
        bx = bx.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    with jax.named_scope("pallas:rglru"):
        _, h = lax.associative_scan(combine, (a, bx), axis=1)
    return h


def conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: jax.Array | None = None) -> jax.Array:
    """Per-channel causal conv.  x: (B,S,R); w: (W,R); state: (B,W-1,R)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out + b


def rglru_block(x: jax.Array, p: dict, cfg, state: dict | None = None,
                ) -> tuple[jax.Array, dict | None]:
    """The full recurrent block.  x: (B, S, d); returns (y, new_state).

    ``state`` (decode): {"h": (B,R) fp32, "conv": (B,W-1,R)}.
    """
    B, S, _ = x.shape
    h_in = rmsnorm(x, p["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(h_in @ p["w_gate"], approximate=True)
    y = h_in @ p["w_y"]
    new_state: dict | None = None
    if state is not None:
        conv_in = jnp.concatenate([state["conv"].astype(y.dtype), y],
                                  axis=1)
        y = conv1d_causal(y, p["conv_w"], p["conv_b"], state["conv"])
        a, bx = _gates(y, p)
        h_prev = state["h"]
        h = a[:, 0] * h_prev + jnp.sqrt(
            jnp.clip(1.0 - a[:, 0] ** 2, 0.0)) * bx[:, 0].astype(jnp.float32)
        hs = h[:, None, :]
        new_state = {"h": h, "conv": conv_in[:, 1:].astype(jnp.bfloat16)}
    else:
        y = conv1d_causal(y, p["conv_w"], p["conv_b"])
        a, bx = _gates(y, p)
        bx = jnp.sqrt(jnp.clip(1.0 - a ** 2, 0.0)) * bx.astype(jnp.float32)
        hs = rglru_scan(a, bx)
    out = (gate * hs.astype(gate.dtype)) @ p["w_out"]
    return out, new_state


def init_rglru(key: jax.Array, cfg, dtype) -> dict:
    d = cfg.d_model
    R = cfg.rnn_width or d
    H = max(1, cfg.n_heads)
    k = R // H
    ks = jax.random.split(key, 6)
    std = 1.0 / math.sqrt(d)
    return {
        "ln": jnp.zeros((d,), dtype),
        "w_gate": jax.random.normal(ks[0], (d, R), dtype) * std,
        "w_y": jax.random.normal(ks[1], (d, R), dtype) * std,
        "conv_w": jax.random.normal(ks[2], (cfg.conv_width, R),
                                    dtype) / math.sqrt(cfg.conv_width),
        "conv_b": jnp.zeros((R,), dtype),
        "wa": jax.random.normal(ks[3], (H, k, k), jnp.float32) / math.sqrt(k),
        "ba": jnp.zeros((H, k), jnp.float32),
        "wx": jax.random.normal(ks[4], (H, k, k), jnp.float32) / math.sqrt(k),
        "bx": jnp.zeros((H, k), jnp.float32),
        # Λ init so a^c·softplus ∈ (0.9, 0.999)-ish at σ(r)≈0.5
        "lam": jnp.linspace(-2.0, 1.0, R).astype(jnp.float32),
        "w_out": jax.random.normal(ks[5], (R, d), dtype) / math.sqrt(R),
    }
