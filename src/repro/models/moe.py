"""Mixture-of-Experts FFN — GShard-style capacity-bounded one-hot dispatch.

Design notes (TPU adaptation):

* Dispatch/combine are einsums against a one-hot ``(B, c, E, C)`` tensor —
  all-to-alls emerge from GSPMD when the expert dim is sharded.
* The sequence is processed in chunks of ``cfg.moe_seq_chunk`` (scan), so
  the dispatch temporary is bounded at ``B·c·E·C_chunk`` regardless of
  sequence length — this is what lets mixtral (E=8, big capacity) lower
  for 32k prefill without an O(S²/E)-sized temp.
* Experts are sharded over the ``model`` axis when ``E % tp == 0``
  (llama4: 128/16 = 8 experts per device); otherwise the expert weights
  are TP-sharded over ``d_ff`` (mixtral: 8 < 16) and every device holds a
  slice of all experts.
* Router math in fp32; top-k renormalized (mixtral convention).

Returns the layer output and the load-balancing auxiliary loss
(Switch-style: ``E · Σ_e f_e · P_e``).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .layers import mlp_apply

__all__ = ["moe_apply", "init_moe"]


def _dispatch_chunk(xc: jax.Array, p: dict, cfg, constrain) -> tuple:
    """One sequence chunk through the routed experts.

    xc: (B, c, d) -> (out (B, c, d), aux scalar)
    """
    B, c, d = xc.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(k, int(math.ceil(c * k / E * cfg.capacity_factor)))

    logits = (xc.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))            # (B,c,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, k)                # (B,c,k)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Aux load-balance loss: fraction of tokens per expert × mean prob.
    top1_hot = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32)
    f_e = top1_hot.mean(axis=(0, 1))
    aux = E * jnp.sum(f_e * probs.mean(axis=(0, 1)))

    # Position of each (token, slot) within its expert's capacity buffer.
    dispatch = jnp.zeros((B, c, E, C), dtype=xc.dtype)
    combine = jnp.zeros((B, c, E, C), dtype=jnp.float32)
    fill = jnp.zeros((B, E), dtype=jnp.int32)
    for slot in range(k):
        e_hot = jax.nn.one_hot(gate_idx[..., slot], E,
                               dtype=jnp.int32)              # (B,c,E)
        pos = fill[:, None, :] + jnp.cumsum(e_hot, axis=1) - e_hot
        keep = (e_hot > 0) & (pos < C)
        pos_hot = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                                 dtype=xc.dtype)[..., :C]    # (B,c,E,C)
        sel = pos_hot * e_hot[..., None].astype(xc.dtype)
        dispatch = dispatch + sel
        combine = combine + sel.astype(jnp.float32) \
            * gate_vals[..., slot][..., None, None]
        fill = fill + e_hot.sum(axis=1)

    xd = jnp.einsum("bcek,bcd->ebkd", dispatch, xc)          # (E,B,C,d)
    xd = constrain(xd, "expert_tokens")
    h = jax.nn.silu(jnp.einsum("ebkd,edf->ebkf", xd, p["w1"]))
    if "w3" in p:
        h = h * jnp.einsum("ebkd,edf->ebkf", xd, p["w3"])
    ye = jnp.einsum("ebkf,efd->ebkd", h, p["w2"])            # (E,B,C,d)
    ye = constrain(ye, "expert_tokens")
    out = jnp.einsum("bcek,ebkd->bcd", combine.astype(ye.dtype), ye)
    return out, aux


def moe_apply(x: jax.Array, p: dict, cfg,
              constrain=lambda t, _n: t) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (out (B, S, d), aux loss scalar)."""
    B, S, d = x.shape
    chunk = cfg.moe_seq_chunk
    if chunk <= 0 or S <= chunk:
        out, aux = _dispatch_chunk(x, p, cfg, constrain)
    else:
        assert S % chunk == 0, (S, chunk)
        n = S // chunk
        xs = x.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)

        # checkpointed: otherwise the backward stacks every chunk's
        # (B,c,E,C) dispatch tensor + expert activations as residuals
        @jax.checkpoint
        def body(_, xc):
            o, a = _dispatch_chunk(xc, p, cfg, constrain)
            return None, (o, a)

        _, (outs, auxs) = lax.scan(body, None, xs)
        out = outs.transpose(1, 0, 2, 3).reshape(B, S, d)
        aux = auxs.mean()
    if cfg.n_shared_experts:
        out = out + mlp_apply(x, p["shared"], cfg.mlp)
    return out, aux


def init_moe(key: jax.Array, cfg, dtype) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(ff)
    p = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * std_in,
        "w1": jax.random.normal(ks[1], (E, d, ff), dtype) * std_in,
        "w2": jax.random.normal(ks[2], (E, ff, d), dtype) * std_out,
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["w3"] = jax.random.normal(ks[3], (E, d, ff), dtype) * std_in
    if cfg.n_shared_experts:
        from .layers import init_mlp
        p["shared"] = init_mlp(ks[4], d, ff * cfg.n_shared_experts,
                               cfg.mlp, dtype)
    return p
