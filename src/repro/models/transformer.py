"""The unified decoder: pattern-of-layer-kinds, scanned over depth.

One code path serves all ten assigned architectures: dense GQA
transformers (with window / softcap / bias variants), MoE, RG-LRU hybrids
and RWKV-6.  Layers repeat a *pattern unit*; parameters of each pattern
position are stacked over the repeat count and the unit is scanned
(``jax.lax.scan``) with optional remat — HLO size stays O(pattern), not
O(depth).  A non-divisible remainder is unrolled.

Public entry points:

* :func:`init_params` / :func:`param_specs` — weights + PartitionSpecs
* :func:`forward` — full-sequence logits (training / prefill math)
* :func:`lm_loss` — CE (+ MoE aux), optionally sequence-chunked
* :func:`init_cache` / :func:`cache_specs` — decode state
* :func:`prefill` — forward that also fills the decode cache
* :func:`decode_step` — one-token serving step
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .config import LayerKind, ModelConfig
from .layers import (decode_gqa_attention, gqa_attention, init_attn_layer,
                     init_mlp, mlp_apply, rmsnorm, rope)
from .moe import init_moe, moe_apply
from .rglru import conv1d_causal, init_rglru, rglru_block, rglru_scan, _gates
from .rwkv import init_rwkv, rwkv_block
from .sharding import Rules, constrain

__all__ = [
    "init_params", "param_specs", "forward", "lm_loss",
    "init_cache", "cache_specs", "prefill", "decode_step",
]


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, kind: LayerKind) -> dict:
    dtype = _dt(cfg)
    if kind is LayerKind.ATTN:
        return init_attn_layer(key, cfg, dtype)
    if kind is LayerKind.MOE:
        k1, k2 = jax.random.split(key)
        p = init_attn_layer(k1, cfg, dtype)
        del p["mlp"]
        p["moe"] = init_moe(k2, cfg, dtype)
        return p
    if kind is LayerKind.RGLRU:
        k1, k2 = jax.random.split(key)
        p = init_rglru(k1, cfg, dtype)
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
        return p
    if kind is LayerKind.RWKV:
        return init_rwkv(key, cfg, dtype)
    raise ValueError(kind)


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    dtype = _dt(cfg)
    Vp = cfg.padded_vocab()
    k_embed, k_head, k_blocks, k_rest = jax.random.split(key, 4)
    params: dict = {
        "embed": jax.random.normal(k_embed, (Vp, cfg.d_model), dtype)
        * (1.0 / math.sqrt(cfg.d_model)),
        "final_ln": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            k_head, (cfg.d_model, Vp), dtype) / math.sqrt(cfg.d_model)
    R = cfg.n_units
    blocks = []
    for pos, kind in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(k_blocks, pos), R)
        blocks.append(jax.vmap(
            lambda k, kind=kind: _init_layer(k, cfg, kind))(keys))
    params["blocks"] = tuple(blocks)
    rest = []
    kinds = cfg.layer_kinds()
    for i in range(cfg.n_remainder):
        rest.append(_init_layer(jax.random.fold_in(k_rest, i), cfg,
                                kinds[R * len(cfg.pattern) + i]))
    params["rest"] = tuple(rest)
    return params


# ---------------------------------------------------------------------------
# Partition specs (path-based rules over the eval_shape tree)
# ---------------------------------------------------------------------------

_TP_IN = {"wq", "w1", "w3", "w_gate", "w_y", "wr", "wg",
          "a_w2"}          # (d, X): shard X over tp, d over fsdp
_TP_OUT = {"wo", "w2", "w_out", "b_w2"}  # (X, d): shard X over tp


def _leaf_spec(path: tuple, leaf, cfg: ModelConfig, rules: Rules,
               tp_size: int, stacked: bool) -> P:
    name = None
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            name = p.key
            break
    ndim = len(leaf.shape)
    lead = (None,) if stacked else ()
    f, t = rules.fsdp, rules.tp

    def mk(*spec):
        return P(*(lead + spec))

    eff = ndim - len(lead)
    if name == "embed":
        return P(t, f)
    if name == "lm_head":
        return P(f, t)
    if name == "final_ln":
        return P(None)
    if name == "router":
        return mk(f, None)
    if eff == 3 and name in ("w1", "w2", "w3"):
        # stacked MoE expert weights (E, d, ff) / (E, ff, d)
        if cfg.n_experts % tp_size == 0 and cfg.n_experts >= tp_size:
            return mk(t, f, None) if name != "w2" else mk(t, None, f)
        return mk(None, f, t) if name != "w2" else mk(None, t, f)
    parents = {p.key for p in path if isinstance(p, jax.tree_util.DictKey)}
    if eff == 2 and name in _TP_IN:
        return mk(f, t)
    if eff == 2 and name in _TP_OUT:
        return mk(t, f)
    if eff == 2 and name in ("conv_w",):
        return mk(None, t)
    if eff == 2 and name in ("wk", "wv"):
        if parents & {"tm", "cm"}:
            return mk(f, t)     # RWKV projections: heads shard over tp
        # Attention K/V projections: KV heads are REPLICATED across the
        # model axis (kv_heads rarely divides tp); the projection compute
        # is tiny and this avoids per-layer KV all-gathers.
        return mk(f, None)
    # gate blocks (H, k, k), biases, norms, mus, loras: replicate
    return mk(*([None] * eff))


def param_specs(cfg: ModelConfig, rules: Rules, tp_size: int) -> dict:
    shapes = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))

    def walk(tree, stacked: bool):
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: _leaf_spec(path, leaf, cfg, rules, tp_size,
                                          stacked), tree)

    top = {k: v for k, v in shapes.items() if k not in ("blocks", "rest")}
    out = walk(top, False)   # keep dict keys in paths (embed/lm_head/…)
    out["blocks"] = tuple(walk(b, True) for b in shapes["blocks"])
    out["rest"] = tuple(walk(r, False) for r in shapes["rest"])
    return out


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _attn_block(h, p, cfg: ModelConfig, rules, *, local: bool,
                positions, cache=None, pos=None):
    """Attention (+MLP/MoE) residual block.  Returns (h, aux, new_cache)."""
    B, S, d = h.shape
    H, K, D = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    x = rmsnorm(h, p["ln1"], cfg.norm_eps)
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(rules, q.reshape(B, S, H, D), "heads")
    k = constrain(rules, k.reshape(B, S, K, D), "kv")
    v = constrain(rules, v.reshape(B, S, K, D), "kv")
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    window = cfg.window if local else None
    new_cache = None
    if cache is not None:
        # Ring semantics are universal: a full-length cache (Sc ≥ max_len)
        # behaves identically to linear indexing because slot = pos % Sc
        # = pos and future slots mask out as invalid.
        Sc = cache["k"].shape[1]
        slot = pos % Sc
        k_st = _cache_store(k, cache["k"].dtype)
        v_st = _cache_store(v, cache["v"].dtype)
        if getattr(pos, "ndim", 0):
            # per-slot positions (continuous batching): vmapped updates
            upd = jax.vmap(lambda c, u, s_:
                           lax.dynamic_update_slice(c, u, (s_, 0, 0)))
            ck = upd(cache["k"], k_st, slot)
            cv = upd(cache["v"], v_st, slot)
        else:
            zero = jnp.zeros((), slot.dtype) if hasattr(slot, "dtype") else 0
            ck = lax.dynamic_update_slice(cache["k"], k_st,
                                          (zero, slot, zero, zero))
            cv = lax.dynamic_update_slice(cache["v"], v_st,
                                          (zero, slot, zero, zero))
        o = decode_gqa_attention(q, _cache_load(ck), _cache_load(cv),
                                 pos, ring=True,
                                 softcap=cfg.attn_softcap)
        new_cache = {"k": ck, "v": cv}
    else:
        o = gqa_attention(q, k, v, window=window,
                          softcap=cfg.attn_softcap)
    o = constrain(rules, o.reshape(B, S, H * D), "hidden_tp")
    o = o @ p["wo"]
    if cfg.post_norms:
        o = rmsnorm(o, p["ln1_post"], cfg.norm_eps)
    h = constrain(rules, h + o, "hidden")

    x2 = rmsnorm(h, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        m, aux = moe_apply(x2, p["moe"], cfg,
                           constrain=partial(constrain, rules))
    else:
        m = mlp_apply(x2, p["mlp"], cfg.mlp)
    if cfg.post_norms:
        m = rmsnorm(m, p["ln2_post"], cfg.norm_eps)
    h = constrain(rules, h + m, "hidden")
    return h, aux, new_cache


def _rglru_layer(h, p, cfg: ModelConfig, rules, state=None,
                 return_state=False):
    B, S, d = h.shape
    if return_state and state is None:
        # prefill: run full-seq then extract final state
        x = rmsnorm(h, p["ln"], cfg.norm_eps)
        gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
        y = x @ p["w_y"]
        W = cfg.conv_width
        conv_tail = y[:, -(W - 1):].astype(jnp.bfloat16)
        yc = conv1d_causal(y, p["conv_w"], p["conv_b"])
        a, bx = _gates(yc, p)
        bx = jnp.sqrt(jnp.clip(1.0 - a ** 2, 0.0)) * bx.astype(jnp.float32)
        hs = rglru_scan(a, bx)
        out = (gate * hs.astype(gate.dtype)) @ p["w_out"]
        new_state = {"h": hs[:, -1], "conv": conv_tail}
        o = out
    else:
        o, new_state = rglru_block(h, p, cfg, state)
    h = constrain(rules, h + o, "hidden")
    x2 = rmsnorm(h, p["ln2"], cfg.norm_eps)
    h = constrain(rules, h + mlp_apply(x2, p["mlp"], cfg.mlp), "hidden")
    return h, new_state


def _apply_layer(h, p, cfg, rules, kind: LayerKind, pattern_pos: int,
                 positions, cache=None, pos=None, return_state=False):
    if kind in (LayerKind.ATTN, LayerKind.MOE):
        local = cfg.layer_is_local(pattern_pos)
        h, aux, nc = _attn_block(h, p, cfg, rules, local=local,
                                 positions=positions, cache=cache, pos=pos)
        return h, aux, nc
    if kind is LayerKind.RGLRU:
        h, ns = _rglru_layer(h, p, cfg, rules, state=cache,
                             return_state=return_state)
        return h, jnp.zeros((), jnp.float32), ns
    if kind is LayerKind.RWKV:
        if return_state and cache is None:
            # rwkv_block computes states only when given one; synthesize.
            B, d = h.shape[0], cfg.d_model
            H = d // 64
            cache = {"shift_t": jnp.zeros((B, d), h.dtype),
                     "shift_c": jnp.zeros((B, d), h.dtype),
                     "wkv": jnp.zeros((B, H, 64, 64), jnp.float32)}
        h, ns = rwkv_block(h, p, cfg, state=cache)
        return h, jnp.zeros((), jnp.float32), ns
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Forward (training) — scan over pattern units
# ---------------------------------------------------------------------------


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _embed(params, tokens, cfg: ModelConfig, rules, prefix=None):
    h = params["embed"][tokens]
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    if prefix is not None:
        h = jnp.concatenate([prefix.astype(h.dtype), h], axis=1)
    return constrain(rules, h, "hidden")


def _unembed(params, h, cfg: ModelConfig, rules):
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = h @ (table.T if cfg.tie_embeddings else table)
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return constrain(rules, logits, "logits")


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            rules: Rules | None = None,
            prefix: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence logits.  Returns (logits (B,S,V), moe_aux scalar)."""
    h = _embed(params, tokens, cfg, rules, prefix)
    S = h.shape[1]
    positions = jnp.arange(S)

    def unit(h, unit_params):
        aux = jnp.zeros((), jnp.float32)
        for ppos, kind in enumerate(cfg.pattern):
            h, a, _ = _apply_layer(h, unit_params[ppos], cfg, rules, kind,
                                   ppos, positions)
            aux = aux + a
        return h, aux

    unit_r = _remat(unit, cfg)
    h, auxs = lax.scan(lambda c, xs: unit_r(c, xs), h, params["blocks"])
    aux = auxs.sum()
    kinds = cfg.layer_kinds()
    base = cfg.n_units * len(cfg.pattern)
    for i, p in enumerate(params["rest"]):
        h, a, _ = _apply_layer(h, p, cfg, rules, kinds[base + i],
                               i % len(cfg.pattern), positions)
        aux = aux + a
    return _unembed(params, h, cfg, rules), aux


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def _ce(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Token cross-entropy; labels < 0 are masked.  Returns (sum, count).

    Written to stay sharded over a TP vocab dim: the gold logit is an
    iota-mask reduction (``take_along_axis`` over a sharded axis would
    all-gather the logits), and logsumexp reduces shard-local with GSPMD
    inserting the cross-shard psum.
    """
    l32 = logits.astype(jnp.float32)
    m = lax.stop_gradient(jnp.max(l32, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(l32 - m), axis=-1)) + m[..., 0]
    iota = lax.broadcasted_iota(jnp.int32, l32.shape, l32.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], l32, 0.0), axis=-1)
    mask = labels >= 0
    return jnp.sum((lse - gold) * mask), mask.sum()


def lm_loss(params: dict, tokens: jax.Array, labels: jax.Array,
            cfg: ModelConfig, rules: Rules | None = None,
            prefix: jax.Array | None = None,
            aux_coef: float = 0.01) -> jax.Array:
    h = _embed(params, tokens, cfg, rules, prefix)
    S = h.shape[1]
    positions = jnp.arange(S)

    def unit(h, unit_params):
        aux = jnp.zeros((), jnp.float32)
        for ppos, kind in enumerate(cfg.pattern):
            h, a, _ = _apply_layer(h, unit_params[ppos], cfg, rules, kind,
                                   ppos, positions)
            aux = aux + a
        return h, aux

    unit_r = _remat(unit, cfg)
    h, auxs = lax.scan(lambda c, xs: unit_r(c, xs), h, params["blocks"])
    aux = auxs.sum()
    kinds = cfg.layer_kinds()
    base = cfg.n_units * len(cfg.pattern)
    for i, p in enumerate(params["rest"]):
        h, a, _ = _apply_layer(h, p, cfg, rules, kinds[base + i],
                               i % len(cfg.pattern), positions)
        aux = aux + a

    chunk = cfg.ce_seq_chunk
    if chunk and S > chunk and S % chunk == 0:
        # Never materialize (B, S, V): scan the unembedding over S chunks.
        # The body is checkpointed so the backward recomputes each chunk's
        # logits instead of stacking them all as residuals.
        n = S // chunk
        hs = h.reshape(h.shape[0], n, chunk, -1).transpose(1, 0, 2, 3)
        ls = labels.reshape(labels.shape[0], n, chunk).transpose(1, 0, 2)

        @jax.checkpoint
        def body(acc, xs):
            hc, lc = xs
            logits = _unembed(params, hc, cfg, rules)
            s, c = _ce(logits, lc)
            return (acc[0] + s, acc[1] + c), None

        (tot, cnt), _ = lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
            (hs, ls))
    else:
        logits = _unembed(params, h, cfg, rules)
        tot, cnt = _ce(logits, labels)
    return tot / jnp.maximum(cnt, 1) + aux_coef * aux


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------


_CACHE_SCALE = 42.0     # int8 fixed scale: ±3σ of O(1) activations


def _cache_store(x: jax.Array, dtype) -> jax.Array:
    if dtype == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) * _CACHE_SCALE),
                        -127, 127).astype(jnp.int8)
    return x.astype(dtype)


def _cache_load(x: jax.Array) -> jax.Array:
    if x.dtype == jnp.int8:
        return (x.astype(jnp.float32) / _CACHE_SCALE).astype(jnp.bfloat16)
    return x


def _layer_cache(cfg: ModelConfig, kind: LayerKind, pattern_pos: int,
                 B: int, max_len: int) -> dict:
    dtype = jnp.bfloat16
    if kind in (LayerKind.ATTN, LayerKind.MOE):
        cdtype = jnp.dtype(cfg.cache_dtype)
        local = cfg.layer_is_local(pattern_pos)
        Sc = min(cfg.window, max_len) if (local and cfg.window) else max_len
        return {"k": jnp.zeros((B, Sc, cfg.kv_heads, cfg.head_dim),
                               cdtype),
                "v": jnp.zeros((B, Sc, cfg.kv_heads, cfg.head_dim),
                               cdtype)}
    if kind is LayerKind.RGLRU:
        R = cfg.rnn_width or cfg.d_model
        return {"h": jnp.zeros((B, R), jnp.float32),
                "conv": jnp.zeros((B, cfg.conv_width - 1, R), dtype)}
    if kind is LayerKind.RWKV:
        d = cfg.d_model
        return {"shift_t": jnp.zeros((B, d), dtype),
                "shift_c": jnp.zeros((B, d), dtype),
                "wkv": jnp.zeros((B, d // 64, 64, 64), jnp.float32)}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, B: int, max_len: int) -> dict:
    blocks = []
    for ppos, kind in enumerate(cfg.pattern):
        one = _layer_cache(cfg, kind, ppos, B, max_len)
        stacked = jax.tree.map(
            lambda x: (jnp.broadcast_to(x, (cfg.n_units,) + x.shape)
                       if isinstance(x, jax.Array) else x), one,
            is_leaf=lambda x: not isinstance(x, dict))
        blocks.append(stacked)
    kinds = cfg.layer_kinds()
    base = cfg.n_units * len(cfg.pattern)
    rest = tuple(_layer_cache(cfg, kinds[base + i], i, B, max_len)
                 for i in range(cfg.n_remainder))
    return {"blocks": tuple(blocks), "rest": rest}


def cache_specs(cache_shapes, rules: Rules) -> dict:
    """Batch-shard every cache leaf (model axis unused by caches)."""
    def spec(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return P()
        return P(rules.batch, *([None] * (leaf.ndim - 1)))
    return jax.tree.map(spec, cache_shapes,
                        is_leaf=lambda x: not isinstance(x, (dict, tuple)))


# ---------------------------------------------------------------------------
# Decode / prefill
# ---------------------------------------------------------------------------


def decode_step(params: dict, token: jax.Array, pos: jax.Array,
                cache: dict, cfg: ModelConfig,
                rules: Rules | None = None
                ) -> tuple[jax.Array, dict]:
    """One serving step.  token: (B,) int32; pos: int32 scalar or (B,)
    vector (continuous batching).  Returns (logits (B, V), new cache)."""
    h = _embed(params, token[:, None], cfg, rules)
    positions = pos[None] if pos.ndim == 0 else pos[:, None]

    def unit(h, xs):
        unit_params, unit_cache = xs
        new_caches = []
        for ppos, kind in enumerate(cfg.pattern):
            h, _, nc = _apply_layer(h, unit_params[ppos], cfg, rules, kind,
                                    ppos, positions,
                                    cache=unit_cache[ppos], pos=pos)
            new_caches.append(nc)
        return h, tuple(new_caches)

    h, new_blocks = lax.scan(lambda c, xs: unit(c, xs), h,
                             (params["blocks"], cache["blocks"]))
    kinds = cfg.layer_kinds()
    base = cfg.n_units * len(cfg.pattern)
    new_rest = []
    for i, p in enumerate(params["rest"]):
        h, _, nc = _apply_layer(h, p, cfg, rules, kinds[base + i],
                                i % len(cfg.pattern), positions,
                                cache=cache["rest"][i], pos=pos)
        new_rest.append(nc)
    logits = _unembed(params, h, cfg, rules)
    return logits[:, 0], {"blocks": new_blocks, "rest": tuple(new_rest)}


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig,
            rules: Rules | None = None, max_len: int | None = None,
            prefix: jax.Array | None = None,
            return_all_logits: bool = False) -> tuple[jax.Array, dict]:
    """Forward over a prompt, returning (last-token logits, filled cache).

    The full-sequence math runs exactly as in training; attention caches
    are filled from the computed k/v (window-aligned for ring buffers).
    """
    B, S_tok = tokens.shape
    S = S_tok + (prefix.shape[1] if prefix is not None else 0)
    max_len = max_len or S
    h = _embed(params, tokens, cfg, rules, prefix)
    positions = jnp.arange(S)
    cache = init_cache(cfg, B, max_len)

    def fill_attn(c, k, v):
        Sc = c["k"].shape[1]
        if Sc < S:
            # Ring buffer smaller than the prompt: keep the last Sc keys.
            # Slot alignment requires Sc | S (e.g. window 4096, prompt 32k).
            assert S % Sc == 0, (S, Sc)
            k, v = k[:, -Sc:], v[:, -Sc:]
        ck = lax.dynamic_update_slice(
            c["k"], _cache_store(k, c["k"].dtype), (0, 0, 0, 0))
        cv = lax.dynamic_update_slice(
            c["v"], _cache_store(v, c["v"].dtype), (0, 0, 0, 0))
        return {"k": ck, "v": cv}

    def apply_fill(h, p, c, kind, ppos):
        if kind in (LayerKind.ATTN, LayerKind.MOE):
            # recompute k/v to fill the cache (cheap vs. attention itself)
            x = rmsnorm(h, p["ln1"], cfg.norm_eps)
            k = x @ p["wk"]
            v = x @ p["wv"]
            if cfg.qkv_bias:
                k, v = k + p["bk"], v + p["bv"]
            k = k.reshape(B, S, cfg.kv_heads, cfg.head_dim)
            v = v.reshape(B, S, cfg.kv_heads, cfg.head_dim)
            k = rope(k, positions, cfg.rope_theta)
            h2, _, _ = _apply_layer(h, p, cfg, rules, kind, ppos, positions)
            return h2, fill_attn(c, k, v)
        h2, _, ns = _apply_layer(h, p, cfg, rules, kind, ppos, positions,
                                 return_state=True)
        return h2, ns

    def unit(h, xs):
        unit_params, unit_cache = xs
        ncs = []
        for ppos, kind in enumerate(cfg.pattern):
            h, nc = apply_fill(h, unit_params[ppos], unit_cache[ppos],
                               kind, ppos)
            ncs.append(nc)
        return h, tuple(ncs)

    h, new_blocks = lax.scan(lambda c, xs: unit(c, xs), h,
                             (params["blocks"], cache["blocks"]))
    kinds = cfg.layer_kinds()
    base = cfg.n_units * len(cfg.pattern)
    new_rest = []
    for i, p in enumerate(params["rest"]):
        h, nc = apply_fill(h, p, cache["rest"][i], kinds[base + i],
                           i % len(cfg.pattern))
        new_rest.append(nc)
    if return_all_logits:
        logits = _unembed(params, h, cfg, rules)
        return logits, {"blocks": new_blocks, "rest": tuple(new_rest)}
    logits = _unembed(params, h[:, -1:], cfg, rules)
    return logits[:, 0], {"blocks": new_blocks, "rest": tuple(new_rest)}
