"""Sharding rules: logical tensor roles → PartitionSpec on the production
mesh (``(data, model)`` single-pod, ``(pod, data, model)`` multi-pod).

* ``batch``  — batch dims shard over (pod, data)
* ``fsdp``   — parameter/optimizer dims shard over (pod, data) (ZeRO-3)
* ``tp``     — head / ff / vocab / expert dims shard over model

Constraints are applied through :meth:`Rules.constrain`; with
``rules=None`` every call is a no-op so the same model code runs on a
single CPU device in tests.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["Rules", "P"]


@dataclass(frozen=True)
class Rules:
    batch: tuple[str, ...] = ("data",)       # ("pod","data") multi-pod
    fsdp: tuple[str, ...] = ("data",)
    tp: str = "model"
    #: named activation constraint points (hillclimb levers)
    overrides: dict = field(default_factory=dict)

    # -- activation constraint points ------------------------------------

    def spec(self, name: str) -> P:
        """PartitionSpec for a named activation role."""
        if name in self.overrides:
            return self.overrides[name]
        b = self.batch
        table = {
            "hidden": P(b, None, None),          # (B, S, d)
            "hidden_tp": P(b, None, self.tp),    # (B, S, d) TP-sharded d
            "heads": P(b, None, self.tp, None),  # (B, S, H, D)
            "kv": P(b, None, None, None),        # (B, S, K, D) replicated K
            "logits": P(b, None, self.tp),       # (B, S, V)
            "expert_tokens": P(self.tp, b, None, None),  # (E, B, C, d)
            "rnn": P(b, None, self.tp),          # (B, S, R)
            "wkv_heads": P(b, self.tp, None, None),      # (B, H, S, N)
            "wkv_state": P(b, self.tp, None, None),      # (B, H, N, N)
            "cache": P(b, None, None, None),     # (B, Sc, K, D)
        }
        return table[name]

    def constrain(self, x: jax.Array, name: str) -> jax.Array:
        return jax.lax.with_sharding_constraint(x, self.spec(name))


def constrain(rules: "Rules | None", x: jax.Array, name: str) -> jax.Array:
    if rules is None:
        return x
    return rules.constrain(x, name)
