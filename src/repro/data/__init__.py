"""Data pipeline."""

from .synthetic import SyntheticLM, Batch

__all__ = ["SyntheticLM", "Batch"]
