"""Deterministic sharded synthetic token pipeline with prefetch.

Generates a reproducible Zipf-ish token stream (a fixed xorshift PRNG per
(seed, shard, step), so any host can regenerate any shard independently —
the property a 1000-node data pipeline needs for elastic membership and
restart-from-step-k without coordination).  A background thread prefetches
``prefetch`` batches ahead.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

__all__ = ["Batch", "SyntheticLM"]


@dataclass
class Batch:
    tokens: np.ndarray        # (A, B, S_tok) int32
    labels: np.ndarray        # (A, B, S) int32
    prefix: np.ndarray | None  # (A, B, F, d) bf16-compatible f32
    step: int


class SyntheticLM:
    """Iterable over training batches.

    ``shard`` / ``n_shards`` slice the global batch for multi-host use:
    every host generates only its rows, deterministically.
    """

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 accum: int = 1, frontend_len: int = 0, d_model: int = 0,
                 seed: int = 0, shard: int = 0, n_shards: int = 1,
                 start_step: int = 0, prefetch: int = 2) -> None:
        assert global_batch % (accum * n_shards) == 0
        self.vocab = vocab
        self.seq = seq_len
        self.accum = accum
        self.rows = global_batch // accum // n_shards
        self.frontend_len = frontend_len
        self.d_model = d_model
        self.seed = seed
        self.shard = shard
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    # -- deterministic generation ----------------------------------------

    def _rng(self, step: int) -> np.random.Generator:
        key = (self.seed * 0x9E3779B9 + step * 0x85EBCA6B
               + self.shard * 0xC2B2AE35) & 0xFFFFFFFF
        return np.random.default_rng(key)

    def _make(self, step: int) -> Batch:
        rng = self._rng(step)
        A, B = self.accum, self.rows
        S = self.seq
        F = self.frontend_len
        S_tok = S - F
        # Zipf-ish marginal: squared-uniform maps toward low token ids.
        u = rng.random((A, B, S_tok), dtype=np.float32)
        tokens = (u * u * (self.vocab - 1)).astype(np.int32)
        labels = np.concatenate(
            [np.full((A, B, F), -1, np.int32),
             np.roll(tokens, -1, axis=-1)], axis=-1) if F else \
            np.roll(tokens, -1, axis=-1)
        labels[..., -1] = -1          # no next-token for the last position
        prefix = None
        if F:
            prefix = rng.standard_normal(
                (A, B, F, self.d_model), dtype=np.float32) * 0.02
        return Batch(tokens=tokens, labels=labels, prefix=prefix,
                     step=step)

    # -- prefetch thread ----------------------------------------------------

    def _producer(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self):
        return self

    def __next__(self) -> Batch:
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
