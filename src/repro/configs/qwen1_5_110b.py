"""Qwen1.5 110B [hf:Qwen/Qwen1.5-110B; family verified at 0.5B scale].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.  QKV bias
(the Qwen1.5 signature).
"""

from ..models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    n_layers=80, d_model=8192, n_heads=64, kv_heads=8, d_ff=49152,
    vocab=152_064, head_dim=128,
    pattern=(LayerKind.ATTN,),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=8, kv_heads=2,
                          head_dim=8, d_ff=256, vocab=256, remat="none")
