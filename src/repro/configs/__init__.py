"""Assigned architectures (``--arch <id>``) + input shapes.

Each module exposes ``CONFIG`` (the exact published configuration) and
``smoke_config()`` (a reduced same-family config for CPU tests).  The
``SHAPES`` table defines the four assigned input-shape cells; helpers
report which (arch × shape) cells are runnable (``long_500k`` needs a
sub-quadratic decode path — skips are recorded in DESIGN.md §4).
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass

from ..models.config import ModelConfig

ARCHS = [
    "internvl2_1b", "gemma2_9b", "deepseek_coder_33b", "llama3_2_1b",
    "qwen1_5_110b", "mixtral_8x22b", "llama4_maverick_400b_a17b",
    "musicgen_medium", "recurrentgemma_2b", "rwkv6_7b",
]

#: canonical ids (CLI, exactly as assigned) → module names
ARCH_IDS = {
    "internvl2-1b": "internvl2_1b",
    "gemma2-9b": "gemma2_9b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen1.5-110b": "qwen1_5_110b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "musicgen-medium": "musicgen_medium",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "rwkv6-7b": "rwkv6_7b",
}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = ARCH_IDS.get(arch, arch)
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = ARCH_IDS.get(arch, arch)
    return importlib.import_module(f"repro.configs.{mod}").smoke_config()


def cell_runnable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable?, reason-if-not) for an (arch × shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention layers ⇒ 500k KV cache is "
                       "O(S) per layer; skipped per assignment note")
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            out.append((arch, shape))
    return out


__all__ = ["ARCHS", "ARCH_IDS", "SHAPES", "ShapeSpec", "get_config",
           "get_smoke_config", "cell_runnable", "all_cells"]
