"""DeepSeek-Coder 33B [arXiv:2401.14196; hf] — llama architecture.

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""

from ..models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    n_layers=62, d_model=7168, n_heads=56, kv_heads=8, d_ff=19200,
    vocab=32_256, head_dim=128,
    pattern=(LayerKind.ATTN,),
    rope_theta=100_000.0,
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=8, kv_heads=2,
                          head_dim=16, d_ff=160, vocab=256, remat="none")
