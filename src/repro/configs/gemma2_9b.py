"""Gemma 2 9B [arXiv:2408.00118; hf].

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.  Alternating
local (window 4096) / global attention, attention-logit softcap 50, final
logit softcap 30, GeGLU MLP, post-norms, embed scaling (gemma family).
"""

from ..models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    n_layers=42, d_model=3584, n_heads=16, kv_heads=8, d_ff=14336,
    vocab=256_000, head_dim=256,
    pattern=(LayerKind.ATTN, LayerKind.ATTN),   # local, global
    window=4096, local_mask=(True, False),
    attn_softcap=50.0, logit_softcap=30.0,
    mlp="geglu", post_norms=True, embed_scale=True,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, kv_heads=2,
                          head_dim=16, d_ff=128, vocab=256, window=16,
                          remat="none")
