"""Mixtral 8x22B [arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768; MoE with 8
experts, top-2 routing; sliding-window attention (assignment spec).
"""

from ..models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, kv_heads=8, d_ff=16384,
    vocab=32_768, head_dim=128,
    pattern=(LayerKind.MOE,),
    window=4096, local_mask=(True,),       # SWA on every layer
    n_experts=8, top_k=2, capacity_factor=1.25,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=8, kv_heads=2,
                          head_dim=8, d_ff=128, vocab=256, window=16,
                          n_experts=4, top_k=2, moe_seq_chunk=0,
                          remat="none")
