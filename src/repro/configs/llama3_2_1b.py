"""Llama 3.2 1B [hf:meta-llama/Llama-3.2-1B; unverified].

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
"""

from ..models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    n_layers=16, d_model=2048, n_heads=32, kv_heads=8, d_ff=8192,
    vocab=128_256, head_dim=64,
    pattern=(LayerKind.ATTN,),
    rope_theta=500_000.0,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=8, kv_heads=2,
                          head_dim=8, d_ff=256, vocab=256, remat="none")
