"""RecurrentGemma 2B (Griffin) [arXiv:2402.19427; hf].

26L d_model=2560 10H (local attn kv=1, MQA) d_ff=7680 vocab=256000.
Pattern: (RG-LRU, RG-LRU, local-attention) — 8 full units + 2 remainder
recurrent layers.  Local attention window 2048.  Sub-quadratic decode
(recurrent state + bounded window) ⇒ long_500k runs.
"""

from ..models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    n_layers=26, d_model=2560, n_heads=10, kv_heads=1, d_ff=7680,
    vocab=256_000, head_dim=256,
    pattern=(LayerKind.RGLRU, LayerKind.RGLRU, LayerKind.ATTN),
    window=2048, local_mask=(False, False, True),
    rnn_width=2560, conv_width=4,
    mlp="geglu", embed_scale=True,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=8, d_model=64, n_heads=4, kv_heads=1,
                          head_dim=16, d_ff=128, vocab=256, window=16,
                          rnn_width=64, remat="none")
