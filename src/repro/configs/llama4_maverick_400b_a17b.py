"""Llama 4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout family;
unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 per expert; MoE 128 experts
top-1 + 1 shared expert, interleaved dense/MoE layers (1:1).  Early
fusion is N/A here — the text backbone is modeled and any modality
frontend would arrive via ``input_specs`` embeddings like the other
stub frontends (DESIGN.md §4).
"""

from ..models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, kv_heads=8, d_ff=8192,
    vocab=202_048, head_dim=128,
    pattern=(LayerKind.ATTN, LayerKind.MOE),   # interleaved 1:1
    n_experts=128, top_k=1, n_shared_experts=1,
    capacity_factor=1.25,
    rope_theta=500_000.0,
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=8, kv_heads=2,
                          head_dim=8, d_ff=128, vocab=256,
                          n_experts=8, top_k=1, n_shared_experts=1,
                          moe_seq_chunk=0, remat="none")
