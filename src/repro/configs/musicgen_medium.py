"""MusicGen-medium [arXiv:2306.05284; hf] — decoder-only over EnCodec
tokens.

48L d_model=1536 24H (MHA: kv=24) d_ff=6144 vocab=2048.  The EnCodec
frontend is a stub: ``input_specs`` provides precomputed frame embeddings
(the codebook-interleaving delay pattern collapses to a single token
stream at the backbone boundary).  GELU MLP (the MusicGen transformer).
"""

from ..models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48, d_model=1536, n_heads=24, kv_heads=24, d_ff=6144,
    vocab=2_048, head_dim=64,
    pattern=(LayerKind.ATTN,),
    mlp="gelu",
    tie_embeddings=True,
    frontend_len=128,          # conditioning frames (stub)
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, kv_heads=4,
                          head_dim=16, d_ff=128, vocab=128,
                          frontend_len=8, remat="none")
