"""InternVL2-1B — InternViT-300M frontend (STUB) + InternLM2-Chat-1.8B-ish
0.9B text backbone [arXiv:2404.16821; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.  The ViT frontend
is a stub per the assignment: ``input_specs`` provides precomputed patch
embeddings (B, 256, d_model) prepended to the token embeddings.
"""

from ..models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    n_layers=24, d_model=896, n_heads=14, kv_heads=2, d_ff=4864,
    vocab=151_655, head_dim=64,
    pattern=(LayerKind.ATTN,),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    frontend_len=256,          # ViT patch embeddings (stub)
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, kv_heads=2,
                          head_dim=16, d_ff=128, vocab=256,
                          frontend_len=8, remat="none")
