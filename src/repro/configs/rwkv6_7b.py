"""RWKV-6 "Finch" 7B [arXiv:2404.05892; hf] — attention-free.

32L d_model=4096 d_ff=14336 vocab=65536.  Data-dependent decay WKV with
64-dim heads (64 heads), token-shift ddlerp mixing.  O(1) decode state
⇒ long_500k runs.
"""

from ..models.config import LayerKind, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    n_layers=32, d_model=4096, n_heads=64, kv_heads=0, d_ff=14336,
    vocab=65_536, head_dim=64,
    pattern=(LayerKind.RWKV,),
    mlp="gelu",                # unused by rwkv blocks (squared-relu CM)
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=2, kv_heads=0,
                          head_dim=64, d_ff=256, vocab=256, remat="none")
