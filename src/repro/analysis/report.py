"""Findings, suppressions, and rendering for the static passes.

One :class:`Finding` per problem, pinned to ``path:line``.  Suppression
is inline and must be justified::

    something_flagged()  # analysis: ignore[wall-clock] -- live frontend epoch

A suppression without the ``-- <justification>`` tail does not silence
anything — it produces a ``bad-suppression`` finding of its own, so the
escape hatch cannot rot into a blanket mute.
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass

__all__ = ["Finding", "Suppressions", "render_text", "render_json"]

_IGNORE_RE = re.compile(
    r"#\s*analysis:\s*ignore\[(?P<rules>[\w,\- ]+)\]"
    r"(?:\s*--\s*(?P<reason>\S.*))?")

#: marker for methods entered with the instance lock already held
CALLER_LOCKS_RE = re.compile(r"#\s*analysis:\s*caller-locks\b")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class Suppressions:
    """Per-file inline suppression table (line → justified rule set)."""

    def __init__(self, path: str, source_lines: list[str]) -> None:
        self.path = path
        self._by_line: dict[int, set[str]] = {}
        self.bad: list[Finding] = []
        for i, text in enumerate(source_lines, start=1):
            m = _IGNORE_RE.search(text)
            if not m:
                continue
            rules = {r.strip() for r in m.group("rules").split(",")
                     if r.strip()}
            if not m.group("reason"):
                self.bad.append(Finding(
                    rule="bad-suppression", path=path, line=i,
                    message="suppression without justification: write "
                            "`# analysis: ignore[rule] -- <why>`"))
                continue
            self._by_line[i] = rules

    def allows(self, finding: Finding) -> bool:
        rules = self._by_line.get(finding.line)
        return rules is not None and finding.rule in rules

    def apply(self, findings: list[Finding]) -> list[Finding]:
        """Filter suppressed findings; unjustified suppressions are
        appended as findings themselves."""
        kept = [f for f in findings if not self.allows(f)]
        kept.extend(self.bad)
        return kept


def render_text(findings: list[Finding], checked_files: int) -> str:
    lines = [f.render() for f in sorted(
        findings, key=lambda f: (f.path, f.line, f.rule))]
    if findings:
        lines.append(f"\n{len(findings)} finding(s) in "
                     f"{checked_files} file(s) analyzed")
    else:
        lines.append(f"clean: 0 findings in {checked_files} file(s) "
                     "analyzed")
    return "\n".join(lines)


def render_json(findings: list[Finding], checked_files: int) -> str:
    return json.dumps({
        "files_analyzed": checked_files,
        "findings": [asdict(f) for f in sorted(
            findings, key=lambda f: (f.path, f.line, f.rule))],
    }, indent=1)
