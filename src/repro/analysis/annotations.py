"""Machine-checkable concurrency annotations.

Every class in the runtime that owns a ``threading.Lock`` declares its
discipline here, and ``python -m repro.analysis`` (plus the runtime
witness) enforces it:

* ``@guarded_by("_a", "_b", lock="_lock")`` — instances of this class
  mutate ``self._a`` / ``self._b`` only while holding ``self._lock``.
  The class name must appear in :data:`LOCK_ORDER`; its position is the
  lock's rank in the global acquisition hierarchy.
* ``@lock_free`` — this class must never acquire a lock of its own (the
  single-threaded fast-path contract, e.g.
  :class:`~repro.runtime.scheduler._SeqScheduler`).  Inherited guarded
  fields are exempt; the static pass instead verifies no threading
  primitive is reachable through its methods, and the class is expected
  to enforce single-thread use at runtime (owning-thread assertion).
* ``@single_writer("_x")`` — the named fields are mutated by exactly one
  thread (e.g. the prediction tick loop) and read lock-free elsewhere;
  the class owns no lock at all.

Static-pass conventions (see :mod:`repro.analysis.lockcheck`):

* a method whose name ends in ``_locked`` — or whose ``def`` line (or
  the line above it) carries ``# analysis: caller-locks`` — is entered
  with the instance lock already held by its caller;
* a finding is silenced only by an inline
  ``# analysis: ignore[<rule>] -- <justification>`` comment; the
  analyzer rejects suppressions without a justification text.

``LOCK_ORDER`` is the single declared hierarchy, outermost lock first:
holding a lock, a thread may only acquire locks of classes that appear
*later* in the tuple.  The runtime witness checks the orders actually
observed during the threaded test suite against this exact tuple.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

__all__ = [
    "LOCK_ORDER",
    "guarded_by",
    "lock_free",
    "single_writer",
    "registered_classes",
    "lock_rank",
]

#: The global lock hierarchy, outermost first.  A thread holding the
#: lock of class at index i may only acquire locks of classes at index
#: > i.  Rationale for the order (the nestings that actually occur):
#:
#: * ``ThreadExecutor._submit_lock`` guards only the submission counter
#:   and nests inside nothing — outermost by construction.
#: * ``ResourceBroker`` verbs are self-contained and are always called
#:   from the event loop / worker loops with no other lock held.
#: * ``Scheduler`` holds its lock while driving the ``TaskMonitor``
#:   (``completion_batch``) and while publishing READY events (which
#:   reach a ``TraceRecorder``), so it precedes both.
#: * ``ShardedScheduler`` (the real-thread fast lane) holds its
#:   dependency-bookkeeping lock only while publishing submit-side
#:   events (→ recorder); its monitor flushes run with no lock held,
#:   but ranking it exactly where ``Scheduler`` sits keeps the two
#:   interchangeable behind an executor.
#: * ``WorkerManager`` publishes WORKER_STATE transitions (→ recorder)
#:   with its lock held.
#: * ``TraceRecorder.attach`` subscribes to a bus, so the recorder lock
#:   precedes the ``EventBus`` registration lock (``EventBus.publish``
#:   itself is lock-free by design and appears nowhere in the order).
LOCK_ORDER: tuple[str, ...] = (
    "ThreadExecutor",
    "ResourceBroker",
    "Scheduler",
    "ShardedScheduler",
    "WorkerManager",
    "TaskMonitor",
    "TraceRecorder",
    "EventBus",
)

#: class name → decorated class, for the runtime witness and tests
_REGISTRY: dict[str, type] = {}

#: the active runtime witness (see :mod:`repro.analysis.witness`); the
#: decorated ``__init__`` wrappers consult it once per construction
_witness: Any = None


def registered_classes() -> dict[str, type]:
    """All annotation-decorated classes by name (a copy)."""
    return dict(_REGISTRY)


def lock_rank(class_name: str) -> int:
    """Rank of ``class_name`` in :data:`LOCK_ORDER` (lower = outer)."""
    return LOCK_ORDER.index(class_name)


def _set_witness(witness: Any) -> None:
    """Called by :mod:`repro.analysis.witness` on install/uninstall."""
    global _witness
    _witness = witness


def guarded_by(*fields: str, lock: str = "_lock",
               ) -> Callable[[type], type]:
    """Declare the fields of a lock-owning class and its lock attribute.

    The class must appear in :data:`LOCK_ORDER` — an unlisted lock owner
    is a hard error at import time, which is what keeps the declared
    hierarchy complete.  When a runtime witness is installed, each new
    instance's lock is replaced by an instrumented wrapper right after
    ``__init__`` returns (zero overhead otherwise: one module-global
    ``None`` check per construction).
    """
    def deco(cls: type) -> type:
        if cls.__name__ not in LOCK_ORDER:
            raise ValueError(
                f"{cls.__name__} owns a lock but is not declared in "
                f"analysis.annotations.LOCK_ORDER")
        cls.__guarded_fields__ = tuple(fields)
        cls.__lock_attr__ = lock
        cls.__lock_rank__ = LOCK_ORDER.index(cls.__name__)
        _REGISTRY[cls.__name__] = cls
        inner_init = cls.__init__

        @functools.wraps(inner_init)
        def __init__(self, *args: Any, **kwargs: Any) -> None:
            inner_init(self, *args, **kwargs)
            if _witness is not None:
                _witness.instrument(self, lock, cls.__lock_rank__,
                                    cls.__name__)

        cls.__init__ = __init__
        return cls
    return deco


def lock_free(cls: type) -> type:
    """Declare that ``cls`` acquires no lock of its own, ever.

    The static pass walks the class's methods (transitively through
    ``self._helper()`` calls) and flags any lock acquisition or
    threading-primitive construction it can reach; calls into
    ``@guarded_by``-declared collaborators (whose locks are ranked and
    witness-checked) are allowed.
    """
    cls.__lock_free__ = True
    _REGISTRY[cls.__name__] = cls
    return cls


def single_writer(*fields: str) -> Callable[[type], type]:
    """Declare fields mutated by exactly one thread and read lock-free.

    The class owns no lock; the static pass verifies it acquires none
    and that only the declared fields are mutated outside ``__init__``.
    """
    def deco(cls: type) -> type:
        cls.__single_writer_fields__ = tuple(fields)
        _REGISTRY[cls.__name__] = cls
        return cls
    return deco
