"""Lock/field discipline static pass (pure AST — imports nothing it
analyzes).

Enforced rules, against the conventions of
:mod:`repro.analysis.annotations`:

* ``undeclared-lock`` — a class constructs a ``threading`` lock but
  carries no ``@guarded_by`` declaration.
* ``unused-lock`` — a declared lock no method ever acquires (dead
  "thread safety" that protects nothing).
* ``unranked-lock`` — a ``@guarded_by`` class missing from the
  ``LOCK_ORDER`` hierarchy (its own module's or the global one).
* ``unguarded-field`` — a declared guarded field mutated outside a
  ``with self.<lock>`` scope (methods named ``*_locked`` or marked
  ``# analysis: caller-locks`` are entered with the lock held).
* ``lock-order`` — a lexical nesting, or a one-hop call into a locking
  method of a typed collaborator, that acquires locks against the
  declared hierarchy (the PR 4 broker-deadlock shape).
* ``lock-free`` — a threading primitive (acquisition or construction)
  reachable from a ``@lock_free`` class through ``self.*`` calls — the
  ``threadsafe=False`` fast-path contract.
* ``single-writer`` — a ``@single_writer`` class mutating undeclared
  fields outside ``__init__``, or acquiring any lock.

Type information is heuristic and deliberately shallow: parameter
annotations, ``self.x = ClassName(...)`` constructor assignments, and
``x: ClassName`` annotations.  Anything unresolved is skipped, never
guessed — the runtime witness covers what static typing cannot see.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

from .annotations import LOCK_ORDER as GLOBAL_LOCK_ORDER
from .report import CALLER_LOCKS_RE, Finding, Suppressions

__all__ = ["collect", "check", "run_lockcheck", "ClassInfo"]

_LOCK_FACTORIES = {"Lock", "RLock"}
_THREAD_PRIMITIVES = {"Lock", "RLock", "Condition", "Event", "Semaphore",
                      "BoundedSemaphore", "Barrier", "Thread"}
_MUTATORS = {"append", "appendleft", "add", "discard", "remove", "pop",
             "popleft", "popitem", "clear", "update", "extend", "insert",
             "setdefault", "sort", "reverse"}


@dataclass
class MethodInfo:
    node: ast.FunctionDef
    caller_locks: bool = False
    acquires_own_lock: bool = False


@dataclass
class ClassInfo:
    name: str
    path: str
    node: ast.ClassDef
    guarded: tuple[str, ...] = ()
    lock_attr: str | None = None
    decorated: bool = False          # carries @guarded_by
    lock_free: bool = False
    single_writer: tuple[str, ...] | None = None
    created_locks: list[tuple[str, int]] = field(default_factory=list)
    methods: dict[str, MethodInfo] = field(default_factory=dict)
    #: self attribute → candidate class names (first resolvable wins)
    attr_types: dict[str, list[str]] = field(default_factory=dict)
    bases: list[str] = field(default_factory=list)


@dataclass
class ModuleInfo:
    path: str
    tree: ast.Module
    source_lines: list[str]
    classes: list[ClassInfo] = field(default_factory=list)
    lock_order: tuple[str, ...] | None = None


# ---------------------------------------------------------------------------
# small AST helpers
# ---------------------------------------------------------------------------


def _decorator_name(dec: ast.expr) -> tuple[str, ast.Call | None]:
    """('guarded_by', call-node) for both bare and called decorators."""
    target = dec.func if isinstance(dec, ast.Call) else dec
    call = dec if isinstance(dec, ast.Call) else None
    if isinstance(target, ast.Attribute):
        return target.attr, call
    if isinstance(target, ast.Name):
        return target.id, call
    return "", call


def _annotation_names(node: ast.expr | None) -> list[str]:
    """Candidate class names mentioned in a type annotation."""
    if node is None:
        return []
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.append(n.id)
        elif isinstance(n, ast.Constant) and isinstance(n.value, str):
            # string annotations: "TaskMonitor | None"
            out.extend(p.strip() for p in n.value.split("|"))
    return [n for n in out if n and n not in ("None", "Optional")]


def _self_field(node: ast.expr) -> str | None:
    """Field name when ``node`` is (a subscript/attribute of)
    ``self.<field>`` — the base guarded object of a mutation target."""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        inner = node.value
        if (isinstance(node, ast.Attribute)
                and isinstance(inner, ast.Name) and inner.id == "self"):
            return node.attr
        node = inner
    return None


def _is_threading_primitive(call: ast.Call) -> str | None:
    f = call.func
    if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
            and f.value.id == "threading"
            and f.attr in _THREAD_PRIMITIVES):
        return f.attr
    if isinstance(f, ast.Name) and f.id in _THREAD_PRIMITIVES:
        return f.id  # from threading import Lock
    return None


def _mutated_fields(stmt: ast.stmt) -> list[tuple[str, int]]:
    """``self.<field>`` names mutated by one statement (no recursion
    into nested statements — the walker handles those)."""
    out: list[tuple[str, int]] = []
    if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
        targets = (stmt.targets if isinstance(stmt, ast.Assign)
                   else [stmt.target])
        for t in targets:
            elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) else [t]
            for e in elts:
                f = _self_field(e)
                if f is not None:
                    out.append((f, e.lineno))
    elif isinstance(stmt, ast.Delete):
        for t in stmt.targets:
            f = _self_field(t)
            if f is not None:
                out.append((f, t.lineno))
    for call in _calls_in_stmt_exprs(stmt):
        fn = call.func
        if isinstance(fn, ast.Attribute) and fn.attr in _MUTATORS:
            f = _self_field(fn.value)
            if f is not None:
                out.append((f, call.lineno))
    return out


_BLOCK_FIELDS = ("body", "orelse", "finalbody")


def _calls_in_stmt_exprs(stmt: ast.stmt) -> list[ast.Call]:
    """Call nodes in the *expressions* of one statement, not descending
    into nested statement blocks or nested function bodies."""
    out: list[ast.Call] = []
    stack: list[ast.AST] = []
    for name, value in ast.iter_fields(stmt):
        if name in _BLOCK_FIELDS or name == "handlers":
            continue
        if isinstance(value, ast.expr):
            stack.append(value)
        elif isinstance(value, list):
            stack.extend(v for v in value if isinstance(v, ast.expr))
    while stack:
        n = stack.pop()
        for child in ast.iter_child_nodes(n):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            stack.append(child)
        if isinstance(n, ast.Call):
            out.append(n)
    return out


# ---------------------------------------------------------------------------
# collection
# ---------------------------------------------------------------------------


def _collect_attr_types(cls: ClassInfo) -> None:
    for m in cls.methods.values():
        fn = m.node
        params = {a.arg: _annotation_names(a.annotation)
                  for a in (fn.args.posonlyargs + fn.args.args
                            + fn.args.kwonlyargs)}
        for node in ast.walk(fn):
            if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                continue
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if not (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    continue
                cands = _value_type_candidates(node.value, params)
                if isinstance(node, ast.AnnAssign):
                    cands = _annotation_names(node.annotation) + cands
                if cands:
                    cls.attr_types.setdefault(t.attr, []).extend(cands)
    # class-level annotations: ``monitor: TaskMonitor``
    for stmt in cls.node.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name):
            cands = _annotation_names(stmt.annotation)
            if cands:
                cls.attr_types.setdefault(stmt.target.id, []).extend(cands)


def _value_type_candidates(value: ast.expr | None,
                           params: dict[str, list[str]]) -> list[str]:
    if value is None:
        return []
    if isinstance(value, ast.Call) and isinstance(value.func, ast.Name):
        return [value.func.id]
    if isinstance(value, ast.Name):
        return params.get(value.id, [])
    if isinstance(value, ast.IfExp):
        return (_value_type_candidates(value.body, params)
                + _value_type_candidates(value.orelse, params))
    return []


def collect(path: str, source: str) -> ModuleInfo:
    tree = ast.parse(source, filename=path)
    mod = ModuleInfo(path=path, tree=tree,
                     source_lines=source.splitlines())
    for stmt in tree.body:
        if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
                and stmt.targets[0].id == "LOCK_ORDER"
                and isinstance(stmt.value, (ast.Tuple, ast.List))):
            names = [e.value for e in stmt.value.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str)]
            mod.lock_order = tuple(names)
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            mod.classes.append(_collect_class(node, mod))
    return mod


def _collect_class(node: ast.ClassDef, mod: ModuleInfo) -> ClassInfo:
    cls = ClassInfo(name=node.name, path=mod.path, node=node,
                    bases=[b.id for b in node.bases
                           if isinstance(b, ast.Name)])
    for dec in node.decorator_list:
        name, call = _decorator_name(dec)
        if name == "guarded_by":
            cls.decorated = True
            cls.lock_attr = "_lock"
            if call is not None:
                cls.guarded = tuple(a.value for a in call.args
                                    if isinstance(a, ast.Constant)
                                    and isinstance(a.value, str))
                for kw in call.keywords:
                    if kw.arg == "lock" and isinstance(kw.value,
                                                       ast.Constant):
                        cls.lock_attr = kw.value.value
        elif name == "lock_free":
            cls.lock_free = True
        elif name == "single_writer":
            cls.single_writer = tuple(
                a.value for a in (call.args if call else [])
                if isinstance(a, ast.Constant) and isinstance(a.value, str))
    for stmt in node.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            caller_locks = (
                stmt.name.endswith("_locked")
                or _has_marker(mod, stmt.lineno)
                or any(_has_marker(mod, d.lineno)
                       for d in stmt.decorator_list))
            cls.methods[stmt.name] = MethodInfo(node=stmt,
                                                caller_locks=caller_locks)
    # lock creation + own-lock acquisition, per method
    for m in cls.methods.values():
        lock_attr = cls.lock_attr
        for sub in ast.walk(m.node):
            if (isinstance(sub, ast.Assign)
                    and isinstance(sub.value, ast.Call)
                    and _is_threading_primitive(sub.value)
                    in _LOCK_FACTORIES):
                for t in sub.targets:
                    f = _self_field(t)
                    if f is not None:
                        cls.created_locks.append((f, sub.lineno))
            if lock_attr is not None:
                if (isinstance(sub, (ast.With, ast.AsyncWith))
                        and any(_self_field(i.context_expr) == lock_attr
                                for i in sub.items)):
                    m.acquires_own_lock = True
                if (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Attribute)
                        and sub.func.attr in ("acquire", "release")
                        and _self_field(sub.func.value) == lock_attr):
                    m.acquires_own_lock = True
    _collect_attr_types(cls)
    return cls


def _has_marker(mod: ModuleInfo, lineno: int) -> bool:
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(mod.source_lines) \
                and CALLER_LOCKS_RE.search(mod.source_lines[ln - 1]):
            return True
    return False


# ---------------------------------------------------------------------------
# checking
# ---------------------------------------------------------------------------


class _Checker:
    def __init__(self, modules: list[ModuleInfo]) -> None:
        self.modules = modules
        self.classes: dict[str, ClassInfo] = {}
        for mod in modules:
            for cls in mod.classes:
                self.classes.setdefault(cls.name, cls)
        self.ranks: dict[str, int] = {n: i for i, n
                                      in enumerate(GLOBAL_LOCK_ORDER)}
        for mod in modules:
            if mod.lock_order:
                for i, n in enumerate(mod.lock_order):
                    self.ranks[n] = i
        self.findings: list[Finding] = []

    # -- type resolution ---------------------------------------------------

    def _resolve(self, name_candidates: list[str]) -> ClassInfo | None:
        for n in name_candidates:
            cls = self.classes.get(n)
            if cls is not None:
                return cls
        return None

    def _mro(self, cls: ClassInfo) -> list[ClassInfo]:
        out, seen, queue = [], set(), [cls.name]
        while queue:
            n = queue.pop(0)
            if n in seen:
                continue
            seen.add(n)
            c = self.classes.get(n)
            if c is not None:
                out.append(c)
                queue.extend(c.bases)
        return out

    def _effective_lock_attr(self, cls: ClassInfo) -> str | None:
        for c in self._mro(cls):
            if c.lock_attr is not None:
                return c.lock_attr
        return None

    def _effective_rank(self, cls: ClassInfo) -> int | None:
        for c in self._mro(cls):
            if c.name in self.ranks:
                return self.ranks[c.name]
        return None

    def _find_method(self, cls: ClassInfo, name: str) -> MethodInfo | None:
        for c in self._mro(cls):
            if name in c.methods:
                return c.methods[name]
        return None

    def _is_locking_method(self, cls: ClassInfo, name: str) -> bool:
        m = self._find_method(cls, name)
        return m is not None and m.acquires_own_lock

    def _expr_type(self, expr: ast.expr, cls: ClassInfo,
                   local_types: dict[str, list[str]]) -> ClassInfo | None:
        """Resolve the class of an attribute chain rooted at ``self`` or
        a typed local/parameter (depth-limited, heuristic)."""
        if isinstance(expr, ast.Name):
            if expr.id == "self":
                return cls
            return self._resolve(local_types.get(expr.id, []))
        if isinstance(expr, ast.Attribute):
            base = self._expr_type(expr.value, cls, local_types)
            if base is None:
                return None
            for c in self._mro(base):
                cands = c.attr_types.get(expr.attr)
                if cands:
                    return self._resolve(cands)
            return None
        return None

    def _resolve_with_lock(self, expr: ast.expr, cls: ClassInfo,
                           local_types: dict[str, list[str]],
                           ) -> tuple[int, str] | None:
        """(rank, owner-name) when ``expr`` is a known lock object."""
        if not isinstance(expr, ast.Attribute):
            return None
        owner = self._expr_type(expr.value, cls, local_types)
        if owner is None:
            return None
        if expr.attr != self._effective_lock_attr(owner):
            return None
        rank = self._effective_rank(owner)
        if rank is None:
            return None
        return rank, owner.name

    def _resolve_call_lock(self, call: ast.Call, cls: ClassInfo,
                           local_types: dict[str, list[str]],
                           ) -> tuple[int, str] | None:
        """(rank, owner) when ``call`` transiently acquires a known
        collaborator's lock (one-hop interprocedural edge)."""
        fn = call.func
        if not isinstance(fn, ast.Attribute):
            return None
        owner = self._expr_type(fn.value, cls, local_types)
        if owner is None or not self._is_locking_method(owner, fn.attr):
            return None
        rank = self._effective_rank(owner)
        if rank is None:
            return None
        return rank, owner.name

    # -- rules -------------------------------------------------------------

    def check(self) -> list[Finding]:
        for mod in self.modules:
            for cls in mod.classes:
                self._check_class(mod, cls)
        return self.findings

    def _emit(self, mod: ModuleInfo, rule: str, line: int,
              message: str) -> None:
        self.findings.append(Finding(rule=rule, path=mod.path, line=line,
                                     message=message))

    def _check_class(self, mod: ModuleInfo, cls: ClassInfo) -> None:
        if cls.created_locks and not cls.decorated and not cls.lock_free:
            for attr, line in cls.created_locks:
                self._emit(mod, "undeclared-lock", line,
                           f"{cls.name} constructs a lock in self.{attr} "
                           "but declares no @guarded_by discipline")
        if cls.decorated:
            if cls.name not in self.ranks:
                self._emit(mod, "unranked-lock", cls.node.lineno,
                           f"{cls.name} is @guarded_by-declared but "
                           "missing from LOCK_ORDER")
            if cls.created_locks and not any(
                    m.acquires_own_lock for m in cls.methods.values()):
                self._emit(mod, "unused-lock", cls.created_locks[0][1],
                           f"{cls.name}.{cls.lock_attr} is constructed "
                           "but never acquired by any method (dead lock "
                           "— remove it or guard the fields with it)")
            if not cls.lock_free:
                self._check_guarded_fields(mod, cls)
        if cls.lock_free:
            self._check_lock_free(mod, cls)
        if cls.single_writer is not None:
            self._check_single_writer(mod, cls)
        self._check_lock_order(mod, cls)

    # unguarded-field ------------------------------------------------------

    def _check_guarded_fields(self, mod: ModuleInfo, cls: ClassInfo) -> None:
        guarded = set(cls.guarded)
        if not guarded:
            return
        lock_attr = cls.lock_attr

        def walk(stmts: list[ast.stmt], held: bool) -> None:
            for s in stmts:
                if isinstance(s, (ast.With, ast.AsyncWith)):
                    now_held = held or any(
                        _self_field(i.context_expr) == lock_attr
                        for i in s.items)
                    walk(s.body, now_held)
                    continue
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    walk(s.body, False)  # closures may run lock-less
                    continue
                if not held:
                    for fname, line in _mutated_fields(s):
                        if fname in guarded:
                            self._emit(
                                mod, "unguarded-field", line,
                                f"{cls.name}.{fname} is declared guarded "
                                f"by self.{lock_attr} but mutated "
                                "outside it")
                for block in _BLOCK_FIELDS:
                    walk(getattr(s, block, []) or [], held)
                for h in getattr(s, "handlers", []) or []:
                    walk(h.body, held)

        for name, m in cls.methods.items():
            if name in ("__init__", "__new__") or m.caller_locks:
                continue
            walk(m.node.body, False)

    # lock-order -----------------------------------------------------------

    def _local_types(self, fn: ast.FunctionDef) -> dict[str, list[str]]:
        out = {a.arg: _annotation_names(a.annotation)
               for a in (fn.args.posonlyargs + fn.args.args
                         + fn.args.kwonlyargs)}
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)
                    and isinstance(node.value.func, ast.Name)):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out.setdefault(t.id, []).append(node.value.func.id)
        return out

    def _check_lock_order(self, mod: ModuleInfo, cls: ClassInfo) -> None:
        own_rank = self._effective_rank(cls)

        def check_acquire(rank: int, owner: str, line: int,
                          held: list[tuple[int, str]]) -> None:
            for h_rank, h_owner in held:
                if h_rank >= rank:
                    what = ("re-acquisition of the non-reentrant "
                            f"{owner} lock"
                            if h_owner == owner else
                            f"acquiring {owner} (rank {rank}) while "
                            f"holding {h_owner} (rank {h_rank})")
                    self._emit(mod, "lock-order", line,
                               f"{what} inverts the declared LOCK_ORDER")
                    return

        def scan_exprs(stmt: ast.stmt, held: list[tuple[int, str]],
                       local_types: dict[str, list[str]]) -> None:
            for call in _calls_in_stmt_exprs(stmt):
                hit = self._resolve_call_lock(call, cls, local_types)
                if hit is not None:
                    check_acquire(hit[0], hit[1], call.lineno, held)

        def walk(stmts: list[ast.stmt], held: list[tuple[int, str]],
                 local_types: dict[str, list[str]]) -> None:
            for s in stmts:
                if isinstance(s, (ast.With, ast.AsyncWith)):
                    scan_exprs(s, held, local_types)
                    inner = list(held)
                    for item in s.items:
                        hit = self._resolve_with_lock(item.context_expr,
                                                      cls, local_types)
                        if hit is not None:
                            check_acquire(hit[0], hit[1],
                                          item.context_expr.lineno, inner)
                            inner = inner + [hit]
                    walk(s.body, inner, local_types)
                    continue
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    walk(s.body, [], self._local_types(s))
                    continue
                scan_exprs(s, held, local_types)
                for block in _BLOCK_FIELDS:
                    walk(getattr(s, block, []) or [], held, local_types)
                for h in getattr(s, "handlers", []) or []:
                    walk(h.body, held, local_types)

        for name, m in cls.methods.items():
            # caller-locks methods run with the instance lock held — the
            # worst case their call sites guarantee
            held0 = ([(own_rank, cls.name)]
                     if m.caller_locks and own_rank is not None else [])
            walk(m.node.body, held0, self._local_types(m.node))

    # lock-free ------------------------------------------------------------

    def _check_lock_free(self, mod: ModuleInfo, cls: ClassInfo) -> None:
        # transitive closure over self.<method>() calls, through bases
        reachable: dict[str, MethodInfo] = {}
        queue = [n for n in cls.methods]
        while queue:
            name = queue.pop()
            if name in reachable:
                continue
            m = self._find_method(cls, name)
            if m is None:
                continue
            reachable[name] = m
            for sub in ast.walk(m.node):
                if (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Attribute)
                        and isinstance(sub.func.value, ast.Name)
                        and sub.func.value.id == "self"
                        and sub.func.attr not in reachable):
                    queue.append(sub.func.attr)
        lock_attr = self._effective_lock_attr(cls)
        for name, m in reachable.items():
            if name == "__init__":
                continue  # base __init__ may build the lock it never uses
            for sub in ast.walk(m.node):
                if isinstance(sub, (ast.With, ast.AsyncWith)):
                    for item in sub.items:
                        f = _self_field(item.context_expr)
                        if f is not None and f == lock_attr:
                            self._emit(
                                mod, "lock-free", item.context_expr.lineno,
                                f"@lock_free {cls.name} reaches a lock "
                                f"acquisition in {name}()")
                elif isinstance(sub, ast.Call):
                    prim = _is_threading_primitive(sub)
                    if prim is not None:
                        self._emit(
                            mod, "lock-free", sub.lineno,
                            f"@lock_free {cls.name} reaches "
                            f"threading.{prim}() in {name}()")
                    elif (isinstance(sub.func, ast.Attribute)
                          and sub.func.attr == "acquire"
                          and _self_field(sub.func.value) == lock_attr):
                        self._emit(
                            mod, "lock-free", sub.lineno,
                            f"@lock_free {cls.name} reaches a lock "
                            f"acquire in {name}()")

    # single-writer --------------------------------------------------------

    def _check_single_writer(self, mod: ModuleInfo, cls: ClassInfo) -> None:
        declared = set(cls.single_writer or ())
        for name, m in cls.methods.items():
            if name in ("__init__", "__new__"):
                continue
            for sub in ast.walk(m.node):
                if isinstance(sub, (ast.With, ast.AsyncWith)):
                    for item in sub.items:
                        f = _self_field(item.context_expr)
                        if f is not None and f.endswith("_lock"):
                            self._emit(
                                mod, "single-writer", item.context_expr
                                .lineno,
                                f"@single_writer {cls.name} acquires a "
                                f"lock in {name}() — declare @guarded_by "
                                "instead")
            for stmt in ast.walk(m.node):
                if isinstance(stmt, ast.stmt):
                    for fname, line in _mutated_fields(stmt):
                        if fname not in declared:
                            self._emit(
                                mod, "single-writer", line,
                                f"{cls.name}.{fname} mutated in {name}() "
                                "but not declared in @single_writer(...)")


def check(modules: list[ModuleInfo]) -> list[Finding]:
    return _Checker(modules).check()


def run_lockcheck(files: list[tuple[str, str]]) -> tuple[list[Finding], int]:
    """Run the pass over ``(path, source)`` pairs; returns (findings,
    files analyzed).  Suppressions are applied per file."""
    modules = [collect(path, source) for path, source in files]
    raw = check(modules)
    out: list[Finding] = []
    for mod in modules:
        sup = Suppressions(mod.path, mod.source_lines)
        out.extend(sup.apply([f for f in raw if f.path == mod.path]))
    return out, len(modules)
