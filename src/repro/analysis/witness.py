"""Runtime lock-order witness.

A debug-mode shim (no ``threading.setprofile``, no tracing): when a
:class:`LockOrderWitness` is installed, every construction of a
``@guarded_by``-decorated class replaces its declared lock with a thin
wrapper that keeps a per-thread stack of held locks.  Each acquisition
is checked against the stack — acquiring a lock whose declared rank is
outer-or-equal to one already held is a lock-order violation (the PR 4
broker-deadlock shape) — and every nested pair actually observed is
recorded, so the test suite ends with an empirical map of the hierarchy
that :meth:`LockOrderWitness.check_declared` cross-checks against
:data:`~repro.analysis.annotations.LOCK_ORDER`.

The witness is installed for the whole threaded test suite by an
autouse fixture in ``tests/conftest.py`` (disable with
``REPRO_LOCK_WITNESS=0``); measurement-only tests opt out with
:func:`witness_paused`.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Iterator

from . import annotations

__all__ = [
    "LockOrderWitness",
    "install_witness",
    "uninstall_witness",
    "active_witness",
    "witness_paused",
]


class _WitnessLock:
    """Drop-in ``threading.Lock`` wrapper that reports to the witness."""

    __slots__ = ("_inner", "rank", "owner", "_witness")

    def __init__(self, witness: "LockOrderWitness", rank: int,
                 owner: str) -> None:
        self._inner = threading.Lock()
        self.rank = rank
        self.owner = owner
        self._witness = witness

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        # Check *before* blocking: if this acquisition inverts the
        # declared order the deadlock may happen right here.
        self._witness.note_before_acquire(self)
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._witness.note_acquired(self)
        return got

    def release(self) -> None:
        self._witness.note_released(self)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc: object) -> None:
        self.release()


class LockOrderWitness:
    """Records actual lock-acquisition orders and flags inversions.

    ``strict=True`` raises on the acquiring thread at the moment of the
    inversion (regression tests); the default records the violation and
    lets the suite-level fixture fail the session with the full list.
    """

    def __init__(self, strict: bool = False) -> None:
        self.strict = strict
        self._tls = threading.local()
        self._mutex = threading.Lock()
        #: (outer class, inner class) pairs actually observed nested
        self.observed: set[tuple[str, str]] = set()
        #: human-readable violation descriptions
        self.violations: list[str] = []
        self.acquisitions = 0

    # -- instrumentation hook (called from decorated __init__) -----------

    def instrument(self, obj: object, lock_attr: str, rank: int,
                   owner: str) -> None:
        current = getattr(obj, lock_attr, None)
        if isinstance(current, _WitnessLock):
            return  # subclass chained through an already-wrapped init
        setattr(obj, lock_attr, _WitnessLock(self, rank, owner))

    # -- per-thread held stack -------------------------------------------

    def _stack(self) -> list[_WitnessLock]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def note_before_acquire(self, lock: _WitnessLock) -> None:
        stack = self._stack()
        if not stack:
            return
        for held in stack:
            if held.rank >= lock.rank:
                msg = (
                    f"lock-order inversion on thread "
                    f"{threading.current_thread().name!r}: acquiring "
                    f"{lock.owner} (rank {lock.rank}) while holding "
                    f"{held.owner} (rank {held.rank}); declared order: "
                    f"{' -> '.join(annotations.LOCK_ORDER)}")
                with self._mutex:
                    self.violations.append(msg)
                if self.strict:
                    raise RuntimeError(msg)

    def note_acquired(self, lock: _WitnessLock) -> None:
        stack = self._stack()
        if stack:
            pairs = {(held.owner, lock.owner) for held in stack}
            with self._mutex:
                self.observed |= pairs
        stack.append(lock)
        self.acquisitions += 1  # approximate across threads; fine for stats

    def note_released(self, lock: _WitnessLock) -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is lock:
                del stack[i]
                return

    # -- session-end cross-check -----------------------------------------

    def check_declared(self) -> list[str]:
        """Cross-check every observed nested pair against the declared
        hierarchy; returns the problems (empty = validated)."""
        problems = []
        rank = {name: i for i, name in enumerate(annotations.LOCK_ORDER)}
        with self._mutex:
            observed = sorted(self.observed)
        for outer, inner in observed:
            ro, ri = rank.get(outer), rank.get(inner)
            if ro is None or ri is None:
                problems.append(
                    f"observed lock of undeclared class: {outer} -> {inner}")
            elif ro >= ri:
                problems.append(
                    f"observed acquisition order {outer} -> {inner} "
                    f"inverts declared LOCK_ORDER (ranks {ro} >= {ri})")
        return problems


def install_witness(strict: bool = False) -> LockOrderWitness:
    """Install (and return) a fresh witness; newly constructed decorated
    objects get instrumented locks from here on."""
    witness = LockOrderWitness(strict=strict)
    annotations._set_witness(witness)
    return witness


def uninstall_witness() -> None:
    annotations._set_witness(None)


def active_witness() -> LockOrderWitness | None:
    return annotations._witness


@contextlib.contextmanager
def witness_paused() -> Iterator[None]:
    """Temporarily disable instrumentation of *new* objects — for
    measurement-only tests (throughput floors) that must not pay the
    per-acquisition bookkeeping."""
    saved = annotations._witness
    annotations._set_witness(None)
    try:
        yield
    finally:
        annotations._set_witness(saved)
