"""Concurrency & determinism analyzer for the governor stack.

Three layers, one convention:

* :mod:`repro.analysis.annotations` — the ``@guarded_by`` /
  ``@lock_free`` / ``@single_writer`` decorators and the global
  :data:`~repro.analysis.annotations.LOCK_ORDER` hierarchy that every
  lock-owning class in the runtime declares itself against.
* the static passes (:mod:`repro.analysis.lockcheck`,
  :mod:`repro.analysis.determinism`) — AST-only, import nothing from the
  runtime, and run as ``python -m repro.analysis`` (a required CI job).
* :mod:`repro.analysis.witness` — a debug-mode runtime shim that wraps
  the declared locks, records the acquisition orders the threaded test
  suite *actually* produces, and cross-checks them against the declared
  hierarchy.

This ``__init__`` stays import-light (stdlib only, no AST machinery) so
annotating a core class costs one decorator call at import time.
"""

from .annotations import (LOCK_ORDER, guarded_by, lock_free,
                          registered_classes, single_writer)
from .witness import (LockOrderWitness, active_witness, install_witness,
                      uninstall_witness, witness_paused)

__all__ = [
    "LOCK_ORDER",
    "guarded_by",
    "lock_free",
    "single_writer",
    "registered_classes",
    "LockOrderWitness",
    "active_witness",
    "install_witness",
    "uninstall_witness",
    "witness_paused",
]
