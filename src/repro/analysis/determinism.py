"""Determinism lint for simulation/replay/trace modules (pure AST).

The simulator's contract is bit-identical replays: same seed, same
trace, same report.  Three classes of hazard break that silently:

* ``wall-clock`` — ``time.time()`` / ``time.monotonic()`` /
  ``datetime.now()`` etc. leaking host time into sim results.  Virtual
  time comes from the event loop; the only sanctioned real clock lives
  in ``thread_executor.py`` (real threads genuinely wait), which is
  excluded from this pass's scope by the CLI.
* ``unseeded-random`` — module-level ``random.*`` / ``numpy.random.*``
  draws from hidden global state.  Sanctioned form: an explicit
  ``random.Random(seed)`` instance (or ``numpy.random.default_rng``)
  threaded through the call graph.
* ``set-iteration`` — iterating a set (or materializing one into an
  ordered container) leaks hash-order into schedules and traces.
  Dicts are insertion-ordered and fine; ``sorted(...)`` over a set is
  fine.

Scope selection (which files get this pass) is the CLI's job; this
module just checks sources handed to it.
"""

from __future__ import annotations

import ast

from .report import Finding, Suppressions

__all__ = ["run_determinism", "check_source"]

_WALL_CLOCK = {
    ("time", "time"), ("time", "time_ns"), ("time", "monotonic"),
    ("time", "monotonic_ns"), ("time", "perf_counter"),
    ("time", "perf_counter_ns"),
    ("datetime", "now"), ("datetime", "utcnow"), ("datetime", "today"),
    ("date", "today"),
}

#: global-state draws on the ``random`` module (``random.Random`` and
#: ``random.seed``-free instance use are the sanctioned alternative)
_GLOBAL_RANDOM = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "expovariate",
    "betavariate", "paretovariate", "triangular", "vonmisesvariate",
    "weibullvariate", "lognormvariate", "getrandbits", "randbytes",
}

_NP_NAMES = {"np", "numpy"}
_NP_OK = {"default_rng", "Generator", "SeedSequence", "PCG64"}


def _dotted(node: ast.expr) -> tuple[str, ...] | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _is_setish(node: ast.expr) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        if node.func.id in ("set", "frozenset"):
            return True
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        # set algebra: a | b, a - b, ... — setish if either side is
        return _is_setish(node.left) or _is_setish(node.right)
    return False


def check_source(path: str, source: str) -> list[Finding]:
    tree = ast.parse(source, filename=path)
    findings: list[Finding] = []

    def emit(rule: str, line: int, message: str) -> None:
        findings.append(Finding(rule=rule, path=path, line=line,
                                message=message))

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func)
            if dotted is None:
                continue
            # wall clock: time.time(), datetime.datetime.now(), ...
            if len(dotted) >= 2 and dotted[-2:] in _WALL_CLOCK:
                emit("wall-clock", node.lineno,
                     f"{'.'.join(dotted)}() reads the host clock — sim "
                     "results must derive from virtual time")
            # unseeded global random
            elif (len(dotted) == 2 and dotted[0] == "random"
                    and dotted[1] in _GLOBAL_RANDOM):
                emit("unseeded-random", node.lineno,
                     f"{'.'.join(dotted)}() draws from the global PRNG — "
                     "thread an explicit random.Random(seed) instead")
            elif (len(dotted) == 3 and dotted[0] in _NP_NAMES
                    and dotted[1] == "random"
                    and dotted[2] not in _NP_OK):
                emit("unseeded-random", node.lineno,
                     f"{'.'.join(dotted)}() uses numpy's global PRNG — "
                     "use numpy.random.default_rng(seed)")
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            if _is_setish(node.iter):
                emit("set-iteration", node.lineno,
                     "iterating a set leaks hash-order into control "
                     "flow — sort it or use an ordered container")
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                if _is_setish(gen.iter):
                    emit("set-iteration", gen.iter.lineno,
                         "comprehension over a set leaks hash-order — "
                         "sort it or use an ordered container")
    # list(set(...)) / tuple(set(...)) — order-leaking materialization
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in ("list", "tuple")
                and len(node.args) == 1 and _is_setish(node.args[0])):
            findings.append(Finding(
                rule="set-iteration", path=path, line=node.lineno,
                message=f"{node.func.id}() over a set materializes "
                        "hash-order — use sorted(...)"))
    return findings


def run_determinism(files: list[tuple[str, str]],
                    ) -> tuple[list[Finding], int]:
    """Run the lint over ``(path, source)`` pairs with suppressions."""
    out: list[Finding] = []
    for path, source in files:
        raw = check_source(path, source)
        sup = Suppressions(path, source.splitlines())
        # bad-suppression findings are lockcheck's to report when both
        # passes see a file; here keep only filtering
        kept = [f for f in raw if not sup.allows(f)]
        out.extend(kept)
    return out, len(files)
