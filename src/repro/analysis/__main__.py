"""``python -m repro.analysis`` — run the static passes over the repo.

With no arguments, analyzes the whole ``repro`` package.  Explicit file
or directory arguments narrow the target (used by the test fixtures).
Exit status is nonzero iff any finding survives suppression — this is
the required CI ``analysis`` job.

The lock/field pass runs on every target file; the determinism lint
only on files in its scope: ``runtime/`` (except ``thread_executor.py``,
whose real threads legitimately use the real clock), ``trace/``,
``workloads/``, ``serving/`` (the SLO/overload layer must be replayable
— clocks are injected, backoff jitter is seeded), ``core/conditions.py``
(the machine-conditions timeline feeds the simulator and the trace
round trip), and any module whose name mentions ``sim`` or ``replay``.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .determinism import run_determinism
from .lockcheck import run_lockcheck
from .report import Finding, render_json, render_text

_DETERMINISM_DIRS = {"trace", "workloads", "serving"}


def determinism_scope(path: Path) -> bool:
    if path.name == "thread_executor.py":
        return False
    # the machine-conditions timeline feeds the simulator and the trace
    # round trip, so it must be as wall-clock-free as they are
    if path.name == "conditions.py":
        return True
    parts = set(path.parts)
    if parts & _DETERMINISM_DIRS or "runtime" in parts:
        return True
    stem = path.stem
    return "sim" in stem or "replay" in stem


def discover(targets: list[str]) -> list[Path]:
    files: list[Path] = []
    for t in targets:
        p = Path(t)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    # the analyzer does not analyze itself: its fixtures-by-design
    # (witness lock wrappers, decorator machinery) are not runtime code
    pkg = Path(__file__).resolve().parent
    return [f for f in files
            if pkg not in f.resolve().parents and f.resolve() != pkg]


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="concurrency & determinism static analysis")
    parser.add_argument("targets", nargs="*",
                        help="files or directories (default: the repro "
                             "package)")
    parser.add_argument("--json", action="store_true",
                        help="machine-readable findings on stdout")
    args = parser.parse_args(argv)

    targets = args.targets or [str(Path(__file__).resolve().parents[1])]
    files = discover(targets)
    sources: list[tuple[str, str]] = []
    findings: list[Finding] = []
    for f in files:
        try:
            sources.append((str(f), f.read_text(encoding="utf-8")))
        except (OSError, UnicodeDecodeError) as exc:
            findings.append(Finding(rule="unreadable", path=str(f), line=1,
                                    message=f"cannot analyze: {exc}"))

    lock_findings, n = run_lockcheck(sources)
    findings.extend(lock_findings)
    det_files = [(p, s) for p, s in sources if determinism_scope(Path(p))]
    det_findings, _ = run_determinism(det_files)
    findings.extend(det_findings)

    out = (render_json(findings, n) if args.json
           else render_text(findings, n))
    print(out)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
