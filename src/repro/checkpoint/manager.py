"""Sharded checkpointing: npz shards + JSON manifest, atomic commit,
async background save, elastic re-shard on restore.

Layout::

    <dir>/step_000123/            (atomic: written as .tmp then renamed)
        manifest.json             tree structure, shapes, dtypes, step
        shard_0.npz               flattened leaves (host-gathered)

Restore never requires the saving mesh: leaves are loaded on host and
``jax.device_put`` re-shards them to whatever shardings the caller
supplies (elastic re-shard — restore on a different mesh/shape is tested
in ``tests/test_checkpoint.py``).
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "CheckpointManager"]


def _flatten(tree) -> tuple[list, object]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


_WIDTH_VIEW = {2: np.uint16, 1: np.uint8, 4: np.uint32, 8: np.uint64}


def _to_numpy_storable(h: np.ndarray) -> tuple[np.ndarray, str]:
    """npz can't serialize ml_dtypes (bfloat16, float8…): store the raw
    bits as an unsigned view and record the true dtype."""
    dtype = str(h.dtype)
    try:
        np.dtype(dtype)
        native = h.dtype.kind in "biufc"
    except TypeError:
        native = False
    if native and h.dtype.kind in "biufc" and dtype not in ("bfloat16",):
        return h, dtype
    return h.view(_WIDTH_VIEW[h.dtype.itemsize]), dtype


def save_checkpoint(directory, step: int, tree) -> pathlib.Path:
    """Blocking sharded save with atomic rename commit."""
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:09d}"
    tmp = directory / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    host = [np.asarray(x) for x in leaves]
    stored = [_to_numpy_storable(h) for h in host]
    np.savez(tmp / "shard_0.npz",
             **{f"leaf_{i}": s for i, (s, _) in enumerate(stored)})
    manifest = {
        "step": step,
        "n_leaves": len(host),
        "treedef": str(treedef),
        "shapes": [list(h.shape) for h in host],
        "dtypes": [dt for _, dt in stored],
        # Repo-wide clock convention: metric timestamps are monotonic
        # (``time.perf_counter`` live, virtual time in the simulator) —
        # wall clock can jump under NTP and cannot be compared against
        # any other component's timeline.  ``time`` follows that
        # convention (save-to-save intervals *within* a process); it is
        # meaningless across restarts, so durable provenance keeps a
        # separate, clearly-labelled wall-clock stamp that no metric
        # ever consumes.
        "time": time.perf_counter(),
        "unix_time": time.time(),
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic commit
    return final


def restore_checkpoint(directory, step: int | None, like_tree,
                       shardings=None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching tree of Shardings — leaves are
    device_put with them (elastic re-shard); else host arrays are
    returned in the tree structure.
    """
    directory = pathlib.Path(directory)
    if step is None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in directory.glob("step_*"))
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {directory}")
        step = steps[-1]
    path = directory / f"step_{step:09d}"
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "shard_0.npz")
    import ml_dtypes
    leaves = []
    for i in range(manifest["n_leaves"]):
        raw = data[f"leaf_{i}"]
        want = manifest["dtypes"][i]
        try:
            dt = np.dtype(want)
        except TypeError:
            dt = np.dtype(getattr(ml_dtypes, want))
        if raw.dtype != dt:
            raw = raw.view(dt)
        leaves.append(raw)
    _, treedef = _flatten(like_tree)
    like_leaves = treedef.flatten_up_to(like_tree)
    if len(like_leaves) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, expected "
            f"{len(like_leaves)}")
    out = []
    for i, (h, like) in enumerate(zip(leaves, like_leaves)):
        arr = h.astype(like.dtype) if hasattr(like, "dtype") else h
        if shardings is not None:
            sh = treedef.flatten_up_to(shardings)[i]
            arr = jax.device_put(arr, sh)
        out.append(arr)
    return treedef.unflatten(out), step


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; optional async saves."""

    def __init__(self, directory, keep: int = 3) -> None:
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.saved_steps: list[int] = []

    def save(self, step: int, tree, blocking: bool = True) -> None:
        if self._thread is not None:
            self._thread.join()            # one outstanding save at a time
            self._thread = None
        # Gather to host synchronously (cheap vs. serialization), then
        # serialize in the background.
        leaves, treedef = _flatten(tree)
        host = treedef.unflatten([np.asarray(x) for x in leaves])

        def work():
            save_checkpoint(self.directory, step, host)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        self.saved_steps.append(step)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:09d}",
                          ignore_errors=True)

    def latest_step(self) -> int | None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*"))
        return steps[-1] if steps else None

    def restore(self, like_tree, shardings=None, step: int | None = None):
        return restore_checkpoint(self.directory, step, like_tree,
                                  shardings)
