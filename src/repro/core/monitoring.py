"""Monitoring infrastructure (paper §3.1, Fig. 2).

Tracks, per task type ``j``:

* the **unitary cost** ``α_j`` — an exponentially-weighted average of
  ``measured_time / cost`` over completed instances (rolling window that
  weights recent samples more, per the paper);
* the **workload accounting** ``W_{i,j}`` — the accumulated *cost* of live
  instances per runtime status ``i ∈ {ready, executing}``;
* the **instance counts** ``M_j`` of live instances;
* **prediction accuracy** statistics (paper Table 2).

Executing tasks must not account for their whole predicted time once they
are deep into their execution; the paper handles this through the
parent–child link: when a child finishes, its measured time is subtracted
from the parent's outstanding predicted time.  We implement the same
mechanism (``on_task_completed`` walks the parent link).

Thread-safety: events are aggregated per *worker* into local buffers and
flushed into the shared aggregates at task-completion boundaries, mirroring
the paper's "outside the critical path" design.  A single lock guards the
shared aggregates; buffers keep the lock hold time O(1) per task.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Callable

from ..analysis import guarded_by
from .events import EventBus, EventKind, RuntimeEvent

__all__ = [
    "EMA",
    "TypeMetrics",
    "HeteroTypeSnapshot",
    "TaskMonitor",
    "AccuracyReport",
    "DEFAULT_MIN_SAMPLES",
    "OP_EXECUTE",
    "OP_COMPLETE",
]

#: op tags for the buffered-op batches :meth:`TaskMonitor.flush_ops`
#: consumes (built by per-worker producers, e.g. the sharded scheduler)
OP_EXECUTE = 0
OP_COMPLETE = 1

#: The one repo-wide default for "how many completed samples before a
#: type's unitary cost α_j is trusted" (Alg. 1's reliability threshold).
#: Every stack assembled through :class:`~repro.core.governor.GovernorSpec`
#: inherits it via ``PredictionConfig.min_samples`` — it replaces the old
#: inconsistent defaults (4 in the executors, 3 in the elastic/serving
#: controllers).
DEFAULT_MIN_SAMPLES = 4


class EMA:
    """Exponential moving average with sample-count warmup.

    The paper: "normalized metrics are computed using a rolling window,
    which weights past metrics by their occurrence — the more recent these
    previous metrics are, the more weight they have".  During warmup
    (< ``warmup`` samples) we use the plain mean so the very first noisy
    samples do not dominate.
    """

    __slots__ = ("decay", "warmup", "_value", "_count", "_mean", "_m2")

    def __init__(self, decay: float = 0.25, warmup: int = 8) -> None:
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.decay = decay
        self.warmup = warmup
        self._value: float = 0.0
        self._count: int = 0
        self._mean: float = 0.0  # running mean (also feeds variance)
        self._m2: float = 0.0

    def update(self, sample: float) -> None:
        self._count += 1
        delta = sample - self._mean
        self._mean += delta / self._count
        self._m2 += delta * (sample - self._mean)
        if self._count <= self.warmup:
            self._value = self._mean
        else:
            self._value += self.decay * (sample - self._value)

    @property
    def value(self) -> float:
        return self._value

    @property
    def count(self) -> int:
        return self._count

    @property
    def stddev(self) -> float:
        if self._count < 2:
            return 0.0
        return math.sqrt(self._m2 / (self._count - 1))

    def reliable(self, min_samples: int) -> bool:
        return self._count >= min_samples


@dataclass
class TypeMetrics:
    """Aggregated metrics for one task type (the ``j`` index of Alg. 1)."""

    name: str
    unitary_cost: EMA = field(default_factory=EMA)
    #: per-core-type unitary costs α_{j,c} — an E-core's elapsed/cost is
    #: systematically larger than a P-core's, so mixing them in one EMA
    #: biases the prediction on asymmetric machines
    per_core: dict[str, EMA] = field(default_factory=dict)
    # Live workload accounting, in *cost units* (multiplied by α_j on read).
    ready_cost: float = 0.0
    executing_cost: float = 0.0
    ready_instances: int = 0
    executing_instances: int = 0
    # Accuracy accounting (paper Table 2).
    acc_sum: float = 0.0
    acc_count: int = 0
    completed: int = 0
    #: instances that left the system without executing to completion
    #: (admission shed, queue eviction, deadline/retry-budget exhaustion)
    shed: int = 0

    @property
    def live_instances(self) -> int:
        return self.ready_instances + self.executing_instances

    @property
    def live_cost(self) -> float:
        return self.ready_cost + self.executing_cost


@dataclass(frozen=True)
class HeteroTypeSnapshot:
    """Per-task-type Alg.-1 inputs with the per-core-type α split.

    ``alpha_by_core`` maps core-type name → (α_{j,c}, sample count,
    reliable) for every core type that has completed samples.
    """

    name: str
    live_cost: float
    alpha: float
    live_instances: int
    reliable: bool
    alpha_by_core: dict[str, tuple[float, int, bool]]


@dataclass(frozen=True)
class AccuracyReport:
    """Per-type and overall prediction accuracy (reproduces Table 2)."""

    per_type: dict[str, tuple[int, float]]  # type -> (#instances, avg %)
    instances: int
    average_pct: float | None  # None ⇔ "NA" (no timing predictions made)


@guarded_by("_types", "_outstanding", "_predicted_at_start",
            "_subscribed_buses", "_direct_buses", "version",
            "_core_type_of", "_freq_of", "_suspect_of")
class TaskMonitor:
    """The shared monitoring module (paper Fig. 2, left box)."""

    def __init__(self, decay: float = 0.25, warmup: int = 8,
                 min_samples: int = DEFAULT_MIN_SAMPLES) -> None:
        self._lock = threading.Lock()
        self._types: dict[str, TypeMetrics] = {}
        self._decay = decay
        self._warmup = warmup
        self.min_samples = min_samples
        # Outstanding *predicted seconds* per live task id — used for the
        # parent–child subtraction and for accuracy accounting.
        self._outstanding: dict[int, float] = {}
        self._predicted_at_start: dict[int, float] = {}
        self._subscribed_buses: list[EventBus] = []
        # Buses whose lifecycle events already reach this monitor through
        # a direct driver (a Scheduler) — subscribing to one of these
        # would double-count, so subscribe() no-ops on them.
        self._direct_buses: list[EventBus] = []
        #: mutation counter bumped by every lifecycle update — lets the
        #: predictor skip recomputing Alg. 1 on ticks that fire inside
        #: an unchanged window (pure function of the snapshot)
        self.version = 0
        # Worker id → core-type name; set by topology-aware frontends so
        # completion events feed the per-(type × core-type) α_{j,c}.
        self._core_type_of: Callable[[int], str] | None = None
        self._freq_of: Callable[[int], float] | None = None
        # Worker id → "is this core a suspected straggler?"; set by
        # condition-aware frontends.  Samples from suspect cores are
        # excluded from the α EMAs — one sick core must not poison the
        # global cost model (throttled cores need no exclusion: their
        # dilation is already corrected by the frequency term).
        self._suspect_of: Callable[[int], bool] | None = None

    def set_core_type_of(self, fn: Callable[[int], str] | None,
                         freq_of: Callable[[int], float] | None = None,
                         ) -> None:
        """Teach the monitor which core type each worker id runs on —
        and, on DVFS machines, which frequency step, so α_{j,c} samples
        are normalized to full speed (a sample measured at q=0.75 bakes
        in the 1/q dilation; feeding it back raw would double-count the
        slowdown against the planner's own /q and oscillate)."""
        with self._lock:
            self._core_type_of = fn
            self._freq_of = freq_of

    def set_suspect_of(self, fn: Callable[[int], bool] | None) -> None:
        """Teach the monitor which worker ids are suspected stragglers
        (see :class:`~repro.core.conditions.MachineConditions`); their
        completion samples skip the α EMAs."""
        with self._lock:
            self._suspect_of = fn

    # -- event-bus subscription -------------------------------------------
    # The monitor is ONE subscriber on the runtime event bus, not the
    # hard-wired callback target of the scheduler: anything that can see
    # the bus (trace recorders, live dashboards) observes exactly the
    # same lifecycle stream the monitor aggregates.

    _LIFECYCLE_KINDS = (EventKind.TASK_READY, EventKind.TASK_EXECUTE,
                        EventKind.TASK_COMPLETED)

    def mark_direct_driven(self, bus: EventBus) -> None:
        """Record that a producer on ``bus`` (a Scheduler) feeds this
        monitor directly: a later :meth:`subscribe` on the same bus
        no-ops instead of double-counting every lifecycle event — the
        same safety the old monitor-as-subscriber wiring got from
        subscribe()'s idempotence."""
        with self._lock:
            if not any(b is bus for b in self._direct_buses):
                self._direct_buses.append(bus)

    def subscribe(self, bus: EventBus) -> "TaskMonitor":
        """Attach this monitor to ``bus`` (idempotent per bus — e.g. a
        governor-owned monitor handed to a Scheduler that shares the
        same bus must not double-count events; a bus already direct-
        driven by a Scheduler is a no-op for the same reason)."""
        with self._lock:
            if any(b is bus for b in self._subscribed_buses):
                return self
            if any(b is bus for b in self._direct_buses):
                return self
            self._subscribed_buses.append(bus)
        bus.subscribe(self._on_event, kinds=self._LIFECYCLE_KINDS)
        return self

    def unsubscribe(self, bus: EventBus) -> None:
        """Detach from ``bus`` (no-op if not subscribed) — run teardown
        for per-run monitors sharing a longer-lived bus."""
        with self._lock:
            if not any(b is bus for b in self._subscribed_buses):
                return
            self._subscribed_buses = [b for b in self._subscribed_buses
                                      if b is not bus]
        bus.unsubscribe(self._on_event)

    def _on_event(self, ev: RuntimeEvent) -> None:
        if ev.task_id is None or ev.type_name is None or ev.cost is None:
            raise ValueError(
                f"malformed {ev.kind.value} event: task_id, type_name "
                f"and cost are required, got {ev!r}")
        if ev.kind is EventKind.TASK_READY:
            self.on_task_ready(ev.task_id, ev.type_name, ev.cost)
        elif ev.kind is EventKind.TASK_EXECUTE:
            self.on_task_execute(ev.task_id, ev.type_name, ev.cost)
        elif ev.kind is EventKind.TASK_COMPLETED:
            core_type = (self._core_type_of(ev.worker_id)
                         if (self._core_type_of is not None
                             and ev.worker_id is not None) else None)
            freq = (self._freq_of(ev.worker_id)
                    if (self._freq_of is not None
                        and ev.worker_id is not None) else 1.0)
            suspect = (self._suspect_of(ev.worker_id)
                       if (self._suspect_of is not None
                           and ev.worker_id is not None) else False)
            self.on_task_completed(ev.task_id, ev.type_name, ev.cost,
                                   ev.elapsed if ev.elapsed is not None
                                   else 0.0,
                                   parent_id=ev.data.get("parent"),
                                   core_type=core_type, freq=freq,
                                   suspect=suspect)

    # -- type helpers ------------------------------------------------------

    def _metrics(self, type_name: str) -> TypeMetrics:  # analysis: caller-locks
        m = self._types.get(type_name)
        if m is None:
            m = TypeMetrics(name=type_name,
                            unitary_cost=EMA(self._decay, self._warmup))
            self._types[type_name] = m
        return m

    def type_names(self) -> list[str]:
        with self._lock:
            return list(self._types)

    # -- lifecycle events --------------------------------------------------
    # Event methods take (task_id, type_name, cost) rather than a Task
    # object so the monitor has no dependency on the runtime layer.

    def on_task_ready(self, task_id: int, type_name: str, cost: float) -> None:
        """Task became ready (dependencies satisfied / created ready)."""
        with self._lock:
            self._ready_locked(task_id, type_name, cost)

    def _ready_locked(self, task_id: int, type_name: str,
                      cost: float) -> None:
        self.version += 1
        m = self._types.get(type_name)
        if m is None:
            m = self._metrics(type_name)
        m.ready_cost += cost
        m.ready_instances += 1
        # Record the prediction that Alg. 1 would make for this task
        # right now; accuracy is evaluated against it on completion.
        # (EMA reads inlined — once per task on the hot path.)
        ema = m.unitary_cost
        if ema._count >= self.min_samples:
            predicted = cost * ema._value
            self._outstanding[task_id] = predicted
            self._predicted_at_start[task_id] = predicted

    def on_task_execute(self, task_id: int, type_name: str, cost: float) -> None:
        """Task moved ready → executing."""
        with self._lock:
            self._execute_locked(task_id, type_name, cost)

    def _execute_locked(self, task_id: int, type_name: str,
                        cost: float) -> None:
        self.version += 1
        m = self._types.get(type_name)
        if m is None:
            m = self._metrics(type_name)
        m.ready_cost -= cost
        m.ready_instances -= 1
        m.executing_cost += cost
        m.executing_instances += 1

    def on_task_completed(self, task_id: int, type_name: str, cost: float,
                          elapsed: float,
                          parent_id: int | None = None,
                          core_type: str | None = None,
                          freq: float = 1.0,
                          suspect: bool = False) -> None:
        """Task finished; fold the measured time into the aggregates.

        ``freq`` is the DVFS step the task ran at: the per-core α_{j,c}
        stores the full-speed cost (``elapsed · freq``), keeping the
        planner's capacity math frequency-independent.  ``suspect``
        marks a sample from a suspected-straggler core: its timing is
        excluded from the α EMAs (accuracy accounting stays honest)."""
        with self._lock:
            self._completed_locked(task_id, type_name, cost, elapsed,
                                   parent_id, core_type, freq, suspect)

    def on_task_shed(self, task_id: int, type_name: str,
                     cost: float) -> None:
        """A *ready* task left the system without executing (shed by
        admission control, evicted from a full queue, or abandoned after
        its deadline/retry budget ran out): reverse the ready
        registration and drop its outstanding prediction — shed work
        must stop inflating Δ, and a prediction that was never given a
        chance to run must not poison the accuracy statistics.  A task
        shed *mid-execution* goes through :meth:`on_task_abort` first
        (executing → ready), then here (ready → gone)."""
        with self._lock:
            self.version += 1
            m = self._types.get(type_name)
            if m is None:
                m = self._metrics(type_name)
            m.ready_cost -= cost
            m.ready_instances -= 1
            m.shed += 1
            self._predicted_at_start.pop(task_id, None)
            self._outstanding.pop(task_id, None)

    def shed_instances(self) -> int:
        with self._lock:
            return sum(m.shed for m in self._types.values())

    def on_task_abort(self, task_id: int, type_name: str,
                      cost: float) -> None:
        """An *executing* task was torn off its core (core failure) and
        requeued: reverse the executing → ready transition so the live
        workload accounting matches the scheduler's ready queue.  The
        prediction recorded at the original ready stands — the eventual
        re-execution completes against it."""
        with self._lock:
            self.version += 1
            m = self._types.get(type_name)
            if m is None:
                m = self._metrics(type_name)
            m.executing_cost -= cost
            m.executing_instances -= 1
            m.ready_cost += cost
            m.ready_instances += 1

    def _completed_locked(self, task_id: int, type_name: str, cost: float,
                          elapsed: float, parent_id: int | None,
                          core_type: str | None, freq: float,
                          suspect: bool = False) -> None:
        self.version += 1
        m = self._types.get(type_name)
        if m is None:
            m = self._metrics(type_name)
        m.executing_cost -= cost
        m.executing_instances -= 1
        m.completed += 1
        if elapsed > 0.0 and cost > 0.0 and not suspect:
            m.unitary_cost.update(elapsed / cost)
            if core_type is not None:
                ema = m.per_core.get(core_type)
                if ema is None:
                    ema = m.per_core[core_type] = EMA(self._decay,
                                                      self._warmup)
                ema.update(elapsed * freq / cost)
        # Accuracy (Table 2): compare against prediction-at-ready.
        predicted = self._predicted_at_start.pop(task_id, None)
        self._outstanding.pop(task_id, None)
        if predicted is not None and predicted > 0.0 and elapsed > 0.0:
            acc = 100.0 * (1.0 - abs(predicted - elapsed)
                           / max(predicted, elapsed))
            m.acc_sum += acc
            m.acc_count += 1
        # Parent–child link: the child's measured time no longer
        # belongs to the parent's outstanding predicted time.
        if parent_id is not None and parent_id in self._outstanding:
            self._outstanding[parent_id] = max(
                0.0, self._outstanding[parent_id] - elapsed)

    def completion_batch(self, task, elapsed: float,
                         worker_id: int | None,
                         parent_id: int | None, newly_ready) -> None:
        """Fold one completion plus the tasks it made ready into the
        aggregates under a *single* lock acquisition — the hot-path entry
        the :class:`~repro.runtime.scheduler.Scheduler` drives directly
        (per-event bus dispatch paid one event object + one lock
        round-trip for each of the 1 + N transitions).

        ``task``/``newly_ready`` items are duck-typed (``task_id``,
        ``type_name``, ``cost`` attributes) so the monitor keeps no
        dependency on the runtime layer.  Readies are applied *before*
        the completion — the same order the per-event path produced
        (successors enter the ready queue before the finisher's α
        update), which parity tests pin bit-for-bit.
        """
        core_type = (self._core_type_of(worker_id)
                     if (self._core_type_of is not None
                         and worker_id is not None) else None)
        freq = (self._freq_of(worker_id)
                if (self._freq_of is not None
                    and worker_id is not None) else 1.0)
        suspect = (self._suspect_of(worker_id)
                   if (self._suspect_of is not None
                       and worker_id is not None) else False)
        with self._lock:
            for t in newly_ready:
                self._ready_locked(t.task_id, t.type_name, t.cost)
            self._completed_locked(task.task_id, task.type_name, task.cost,
                                   elapsed, parent_id, core_type, freq,
                                   suspect)

    def ready_batch(self, tasks) -> None:
        """Fold many just-became-ready tasks in under a *single* lock
        acquisition — the submit-side twin of :meth:`completion_batch`
        (a whole-graph ``submit_all`` used to pay one monitor lock
        round-trip per ready root).  Items are duck-typed like
        :meth:`completion_batch`'s."""
        with self._lock:
            for t in tasks:
                self._ready_locked(t.task_id, t.type_name, t.cost)

    def flush_ops(self, ops) -> None:
        """Apply one worker's *buffered* lifecycle ops under a single
        lock acquisition — the multi-threaded generalization of
        :meth:`completion_batch` that the sharded real-thread scheduler
        drives: each worker accumulates its execute/complete transitions
        locally and hands a batch over at flush points, so N spinning
        workers stop serializing on this lock once per transition.

        ``ops`` entries are tuples tagged by their first element:

        * ``(OP_EXECUTE, task_id, type_name, cost)`` — ready → executing;
        * ``(OP_COMPLETE, task, elapsed, worker_id, parent_id,
          newly_ready)`` — one completion plus the tasks it made ready
          (applied readies-first, exactly like :meth:`completion_batch`).

        Because each worker flushes independently, ops from *different*
        workers may be applied out of their global wall-clock order (a
        stolen successor's execute can land before the completion that
        readied it); the aggregates are sums and EMAs, so they converge
        to the identical totals, and the transient skew is bounded by
        the flush batch size.
        """
        with self._lock:
            core_type_of = self._core_type_of
            freq_of = self._freq_of
            suspect_of = self._suspect_of
            for op in ops:
                if op[0] == OP_EXECUTE:
                    self._execute_locked(op[1], op[2], op[3])
                else:
                    _, task, elapsed, worker_id, parent_id, newly = op
                    for t in newly:
                        self._ready_locked(t.task_id, t.type_name, t.cost)
                    core_type = (core_type_of(worker_id)
                                 if (core_type_of is not None
                                     and worker_id is not None) else None)
                    freq = (freq_of(worker_id)
                            if (freq_of is not None
                                and worker_id is not None) else 1.0)
                    suspect = (suspect_of(worker_id)
                               if (suspect_of is not None
                                   and worker_id is not None) else False)
                    self._completed_locked(task.task_id, task.type_name,
                                           task.cost, elapsed, parent_id,
                                           core_type, freq, suspect)

    # -- snapshot for the predictor (Alg. 1 inputs) --------------------------

    def workload_snapshot(self, min_samples: int | None = None) -> list[
            tuple[str, float, float, int, bool]]:
        """Return ``[(type, W_ready+W_exec (cost units), α_j, M_j, reliable)]``.

        ``reliable`` is False while a type has too few completed samples to
        trust its unitary cost — Alg. 1 then falls back to counting tasks
        (the paper's "go-to approach when task timing predictions are not
        available").
        """
        k = self.min_samples if min_samples is None else min_samples
        out = []
        with self._lock:
            for name, m in self._types.items():
                # inlined live_instances/live_cost/EMA reads — this runs
                # once per prediction tick with the lock held
                live = m.ready_instances + m.executing_instances
                if live <= 0:
                    continue
                ema = m.unitary_cost
                out.append((
                    name,
                    m.ready_cost + m.executing_cost,
                    ema._value,
                    live,
                    ema._count >= k,
                ))
        return out

    def workload_snapshot_hetero(self, min_samples: int | None = None,
                                 ) -> list[HeteroTypeSnapshot]:
        """Like :meth:`workload_snapshot`, with the per-core-type α split
        the heterogeneous predictor needs (Δ_c fills fastest cores first
        using α_{j,c} normalized by core speed)."""
        k = self.min_samples if min_samples is None else min_samples
        out = []
        with self._lock:
            for name, m in self._types.items():
                if m.live_instances <= 0:
                    continue
                out.append(HeteroTypeSnapshot(
                    name=name,
                    live_cost=m.live_cost,
                    alpha=m.unitary_cost.value,
                    live_instances=m.live_instances,
                    reliable=m.unitary_cost.reliable(k),
                    alpha_by_core={c: (e.value, e.count, e.reliable(k))
                                   for c, e in m.per_core.items()},
                ))
        return out

    def fold_gamma(self, k: int, rate_s: float, count_based_only: bool,
                   limit: float | None) -> tuple[float, int]:
        """Fused Algorithm-1 γ accumulation — one pass over the live
        types under one lock, no snapshot list.  This is the predictor's
        per-tick hot path; :meth:`workload_snapshot` remains the
        observable (list-building) form.

        Returns ``(γ, total_live_instances)``.  ``limit`` is the
        paper's early-exit bound (``while γ < N_CPUs``); None disables
        it (oversubscribing DLB mode).  Term order and arithmetic match
        :meth:`~repro.core.prediction.CPUPredictor.compute_delta`'s
        original snapshot loop exactly.
        """
        gamma = 0.0
        total = 0
        with self._lock:
            for m in self._types.values():
                live = m.ready_instances + m.executing_instances
                if live <= 0:
                    continue
                total += live
                if limit is not None and gamma >= limit:
                    continue
                ema = m.unitary_cost
                if count_based_only or ema._count < k:
                    gamma += live
                else:
                    gamma += ((m.ready_cost + m.executing_cost)
                              * ema._value) / rate_s
        return gamma, total

    def outstanding_seconds(self, min_samples: int | None = None) -> tuple[float, int, int]:
        """Aggregate (predicted_seconds, live_instances, unreliable_instances).

        ``predicted_seconds`` covers only types with reliable α_j.
        """
        pred = 0.0
        live = 0
        unreliable = 0
        for _, w, alpha, m_j, ok in self.workload_snapshot(min_samples):
            live += m_j
            if ok:
                pred += w * alpha
            else:
                unreliable += m_j
        return pred, live, unreliable

    # -- reporting -----------------------------------------------------------

    def unitary_cost(self, type_name: str,
                     core_type: str | None = None) -> float | None:
        with self._lock:
            m = self._types.get(type_name)
            if m is None:
                return None
            ema = (m.unitary_cost if core_type is None
                   else m.per_core.get(core_type))
            if ema is None or ema.count == 0:
                return None
            return ema.value

    def accuracy_report(self) -> AccuracyReport:
        with self._lock:
            per_type: dict[str, tuple[int, float]] = {}
            total_acc = 0.0
            total_n = 0
            for name, m in self._types.items():
                if m.acc_count:
                    per_type[name] = (m.acc_count, m.acc_sum / m.acc_count)
                    total_acc += m.acc_sum
                    total_n += m.acc_count
            return AccuracyReport(
                per_type=per_type,
                instances=total_n,
                average_pct=(total_acc / total_n) if total_n else None,
            )

    def completed_instances(self) -> int:
        with self._lock:
            return sum(m.completed for m in self._types.values())

    def live_instances(self) -> int:
        """Total live (ready + executing) instances across all types —
        the load signal pull-style frontends hand to ``target()``."""
        with self._lock:
            return sum(m.live_instances for m in self._types.values())
