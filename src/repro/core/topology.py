"""Heterogeneous-core topology — pure data shared by every layer.

Modern parts are asymmetric: P/E hybrids (Alder-Lake-style), big.LITTLE,
multi-socket machines with independent DVFS domains.  The paper's
predictor picks how *many* cores a phase needs; on such silicon the
energy-optimal answer is how many cores *of which type at which
frequency* (cf. Costero et al., arXiv:2402.06319, and the Myrmics
heterogeneous-manycore scheduler, arXiv:1606.04282).

:class:`CoreType` describes one class of cores (count, relative speed,
per-state power, available DVFS frequency steps); :class:`CoreTopology`
is an ordered tuple of core types with positional core→type mapping
(cores of the first type occupy indices ``[0, count)``, and so on).
Both are frozen plain data with dict round-trips, so a
:class:`~repro.core.governor.GovernorSpec` can carry one and the
:class:`~repro.runtime.machine.MachineModel` presets can embed them.

A topology with a single :class:`CoreType` at speed 1.0 and one
frequency step *is* today's homogeneous machine — every hetero-aware
code path reduces to the existing behaviour by construction.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Mapping

from .energy import PowerModel

__all__ = ["CoreType", "CoreTopology"]


@dataclass(frozen=True)
class CoreType:
    """One class of cores in an asymmetric machine."""

    name: str
    count: int
    #: task-speed multiplier relative to the machine's reference core
    #: (the MachineModel's ``core_speed`` scales all types uniformly)
    speed: float = 1.0
    #: per-state power for this type; None ⇒ the stack's default model
    power: PowerModel | None = None
    #: available DVFS steps as fractions of the base frequency, ascending;
    #: ``(1.0,)`` means the type cannot be re-clocked
    freq_steps: tuple[float, ...] = (1.0,)
    #: socket / NUMA domain this type's cores live on — the middle tier
    #: of the core → socket → node locality hierarchy.  Serialized only
    #: when nonzero so pre-hierarchy spec dicts round-trip unchanged.
    socket: int = 0

    def __post_init__(self) -> None:
        if self.count < 1:
            raise ValueError(f"count must be >= 1, got {self.count}")
        if self.speed <= 0:
            raise ValueError(f"speed must be > 0, got {self.speed}")
        if self.socket < 0:
            raise ValueError(f"socket must be >= 0, got {self.socket}")
        if not self.freq_steps:
            raise ValueError("freq_steps must not be empty")
        steps = tuple(float(q) for q in self.freq_steps)
        if any(q <= 0 or q > 1.0 for q in steps):
            raise ValueError(
                f"freq_steps must be in (0, 1], got {steps}")
        if list(steps) != sorted(steps):
            raise ValueError(f"freq_steps must be ascending, got {steps}")
        object.__setattr__(self, "freq_steps", steps)

    @property
    def max_freq(self) -> float:
        return self.freq_steps[-1]

    def to_dict(self) -> dict[str, Any]:
        d: dict[str, Any] = {"name": self.name, "count": self.count,
                             "speed": self.speed,
                             "freq_steps": list(self.freq_steps)}
        if self.socket != 0:
            d["socket"] = self.socket
        if self.power is not None:
            d["power"] = {"active": self.power.active,
                          "spin": self.power.spin,
                          "idle": self.power.idle,
                          "off": self.power.off,
                          "resume_energy": self.power.resume_energy}
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "CoreType":
        d = dict(d)
        if isinstance(d.get("power"), Mapping):
            d["power"] = PowerModel(**d["power"])
        if "freq_steps" in d:
            d["freq_steps"] = tuple(d["freq_steps"])
        return cls(**d)


@dataclass(frozen=True)
class CoreTopology:
    """Ordered core types + positional core-index → type mapping."""

    types: tuple[CoreType, ...]
    _offsets: tuple[int, ...] = field(init=False, repr=False, compare=False)

    def __post_init__(self) -> None:
        if not self.types:
            raise ValueError("topology needs at least one core type")
        types = tuple(self.types)
        names = [t.name for t in types]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate core-type names: {names}")
        offsets = []
        base = 0
        for t in types:
            offsets.append(base)
            base += t.count
        object.__setattr__(self, "types", types)
        object.__setattr__(self, "_offsets", tuple(offsets))

    @classmethod
    def homogeneous(cls, n_cores: int, name: str = "core",
                    speed: float = 1.0) -> "CoreTopology":
        """The single-type topology equivalent to today's flat machine."""
        return cls(types=(CoreType(name=name, count=n_cores, speed=speed),))

    # -- introspection -----------------------------------------------------

    @property
    def n_cores(self) -> int:
        return self._offsets[-1] + self.types[-1].count

    @property
    def is_homogeneous(self) -> bool:
        return len(self.types) == 1

    def type_names(self) -> list[str]:
        return [t.name for t in self.types]

    def by_name(self, name: str) -> CoreType:
        for t in self.types:
            if t.name == name:
                return t
        raise KeyError(name)

    def core_type_at(self, index: int) -> CoreType:
        """Core type of local core ``index`` (positional assignment)."""
        i = index % self.n_cores   # global simulator ids wrap per machine
        for off, t in zip(reversed(self._offsets), reversed(self.types)):
            if i >= off:
                return t
        raise IndexError(index)  # pragma: no cover - unreachable

    def type_of(self, index: int) -> str:
        return self.core_type_at(index).name

    def speed_of(self, index: int) -> float:
        return self.core_type_at(index).speed

    def socket_of(self, index: int) -> int:
        """Socket/NUMA domain of local core ``index`` (wraps like
        :meth:`core_type_at`); every core maps to exactly one socket."""
        return self.core_type_at(index).socket

    @property
    def n_sockets(self) -> int:
        return len({t.socket for t in self.types})

    def fastest_first(self) -> list[CoreType]:
        """Types ordered fastest→slowest (Δ_c fills fastest cores
        first); at equal speed, lower socket ids first — the planner
        fills an app's primary socket before spilling to a remote one.
        Single-socket topologies keep declaration order (the sort is
        stable and every key ties)."""
        return sorted(self.types, key=lambda t: (-t.speed, t.socket))

    def mean_speed(self) -> float:
        return (sum(t.count * t.speed for t in self.types)
                / self.n_cores)

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict[str, Any]:
        return {"types": [t.to_dict() for t in self.types]}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "CoreTopology":
        return cls(types=tuple(CoreType.from_dict(t) for t in d["types"]))
