"""Energy accounting (EDP) — the container has no RAPL, so energy is a
documented *proxy model* integrated over (virtual or wall) time:

    E = Σ_cores ∫ P(state(t)) dt

with normalized powers ``P_active = P_spin = 1.0`` (busy-waiting burns the
same cycles as computing — the very premise of the paper's energy argument),
``P_idle = 0.1`` (sleeping core), ``P_off = 0.0`` (core lent away; the
borrower accounts for it).  EDP = E · elapsed, matching the paper's
"energy-delay product correlates both performance and energy consumption
in only one value".

The proxy preserves the paper's *ordering* of policies by construction:
busy maximizes active core-seconds, idle minimizes them at the price of
transition overhead, prediction sits in between.  Absolute Joules are out
of scope on this host.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field

__all__ = ["CoreState", "PowerModel", "EnergyMeter"]


class CoreState(enum.Enum):
    ACTIVE = "active"   # executing a task
    SPIN = "spin"       # busy-waiting (polls, finds nothing)
    IDLE = "idle"       # yielded / sleeping
    OFF = "off"         # lent to another runtime (DLB) or fenced off


@dataclass(frozen=True)
class PowerModel:
    active: float = 1.0
    spin: float = 1.0
    idle: float = 0.1
    off: float = 0.0
    #: energy spike charged per idle→active resume (wakeup cost)
    resume_energy: float = 0.0

    def power(self, state: CoreState) -> float:
        return {
            CoreState.ACTIVE: self.active,
            CoreState.SPIN: self.spin,
            CoreState.IDLE: self.idle,
            CoreState.OFF: self.off,
        }[state]


@dataclass
class _CoreTimeline:
    state: CoreState
    since: float
    accum: dict[CoreState, float] = field(
        default_factory=lambda: {s: 0.0 for s in CoreState})
    resumes: int = 0


class EnergyMeter:
    """Integrates per-core state durations; time source is supplied by the
    executor (virtual time in simulation, ``time.perf_counter`` live)."""

    def __init__(self, n_cores: int, power: PowerModel | None = None,
                 t0: float = 0.0) -> None:
        self.power_model = power or PowerModel()
        self._cores = {i: _CoreTimeline(CoreState.SPIN, t0)
                       for i in range(n_cores)}
        self._t0 = t0
        self._t_end: float | None = None

    def add_core(self, core_id: int, state: CoreState, now: float) -> None:
        self._cores[core_id] = _CoreTimeline(state, now)

    def set_state(self, core_id: int, state: CoreState, now: float) -> None:
        tl = self._cores[core_id]
        if tl.state is state:
            return
        tl.accum[tl.state] += max(0.0, now - tl.since)
        if tl.state is CoreState.IDLE and state in (CoreState.ACTIVE,
                                                    CoreState.SPIN):
            tl.resumes += 1
        tl.state = state
        tl.since = now

    def finish(self, now: float) -> None:
        for tl in self._cores.values():
            tl.accum[tl.state] += max(0.0, now - tl.since)
            tl.since = now
        self._t_end = now

    # -- reports ---------------------------------------------------------

    def state_seconds(self) -> dict[CoreState, float]:
        out = {s: 0.0 for s in CoreState}
        for tl in self._cores.values():
            for s, v in tl.accum.items():
                out[s] += v
        return out

    def energy(self) -> float:
        pm = self.power_model
        acc = self.state_seconds()
        e = sum(acc[s] * pm.power(s) for s in CoreState)
        e += pm.resume_energy * sum(tl.resumes for tl in self._cores.values())
        return e

    def elapsed(self) -> float:
        if self._t_end is None:
            raise RuntimeError("EnergyMeter.finish() not called")
        return self._t_end - self._t0

    def edp(self) -> float:
        return self.energy() * self.elapsed()

    def resumes(self) -> int:
        return sum(tl.resumes for tl in self._cores.values())
