"""Energy accounting (EDP) — the container has no RAPL, so energy is a
documented *proxy model* integrated over (virtual or wall) time:

    E = Σ_cores ∫ P(state(t), freq(t)) dt

with normalized powers ``P_active = P_spin = 1.0`` (busy-waiting burns the
same cycles as computing — the very premise of the paper's energy argument),
``P_idle = 0.1`` (sleeping core), ``P_off = 0.0`` (core lent away; the
borrower accounts for it).  EDP = E · elapsed, matching the paper's
"energy-delay product correlates both performance and energy consumption
in only one value".

Heterogeneous extensions: each core may carry its *own* power model (an
E-core draws less than a P-core in every state) and a DVFS frequency
step.  Dynamic power scales cubically with the step (P ∝ V²f with
V ∝ f — the classic first-order DVFS model); the idle floor plays the
static/leakage component, so

    P(state, q) = P_idle + (P(state) − P_idle) · q³     for active/spin

and idle/off power does not scale.  At ``q = 1`` this is exactly the
flat model, so homogeneous stacks are bit-for-bit unchanged.

The proxy preserves the paper's *ordering* of policies by construction:
busy maximizes active core-seconds, idle minimizes them at the price of
transition overhead, prediction sits in between.  Absolute Joules are out
of scope on this host.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field

__all__ = ["CoreState", "PowerModel", "EnergyMeter"]


class CoreState(enum.Enum):
    ACTIVE = "active"   # executing a task
    SPIN = "spin"       # busy-waiting (polls, finds nothing)
    IDLE = "idle"       # yielded / sleeping
    OFF = "off"         # lent to another runtime (DLB) or fenced off


# Dense per-member index: hot-path accumulators are plain lists indexed
# by `state.idx` (an attribute load) instead of dicts keyed by the enum
# member (enum.__hash__ is a Python-level call, paid per segment close).
for _i, _s in enumerate(CoreState):
    _s.idx = _i


@dataclass(frozen=True)
class PowerModel:
    active: float = 1.0
    spin: float = 1.0
    idle: float = 0.1
    off: float = 0.0
    #: energy spike charged per idle→active resume (wakeup cost)
    resume_energy: float = 0.0

    def __post_init__(self) -> None:
        # power() runs once per state-segment close on the simulator hot
        # path; cache the idx→power list instead of rebuilding a dict
        # per call (frozen dataclass, hence object.__setattr__).
        by_state = [0.0] * len(CoreState)
        by_state[CoreState.ACTIVE.idx] = self.active
        by_state[CoreState.SPIN.idx] = self.spin
        by_state[CoreState.IDLE.idx] = self.idle
        by_state[CoreState.OFF.idx] = self.off
        object.__setattr__(self, "_by_state", by_state)

    def power(self, state: CoreState, freq: float = 1.0) -> float:
        """Draw at ``state`` and DVFS step ``freq``.

        Contract: ``freq`` is clamped to the physical band [0, 1].  A
        ``PowerModel`` has no knowledge of a core type's DVFS steps —
        typed validation lives where the type is known
        (:meth:`MachineModel.service_time`,
        :meth:`ResourceGovernor.apply_frequencies`) — but the cubic
        must never extrapolate: ``freq > 1`` used to silently yield
        super-unit power and ``freq < 0`` a *negative* dynamic term.
        In-band frequencies are returned bit-identically.
        """
        base = self._by_state[state.idx]
        if freq != 1.0 and (state is CoreState.ACTIVE
                            or state is CoreState.SPIN):
            if freq > 1.0:
                return base
            if freq < 0.0:
                freq = 0.0
            # cubic dynamic component over the static (idle) floor
            return self.idle + (base - self.idle) * freq ** 3
        return base


@dataclass(slots=True)
class _CoreTimeline:
    state: CoreState
    since: float
    power: PowerModel
    core_type: str = ""
    freq: float = 1.0
    joules: float = 0.0
    # state-seconds accumulator indexed by CoreState.idx
    accum: list[float] = field(
        default_factory=lambda: [0.0] * len(CoreState))
    resumes: int = 0

    def close_segment(self, now: float) -> None:
        dt = now - self.since
        if dt > 0.0:
            self.accum[self.state.idx] += dt
            self.joules += dt * self.power.power(self.state, self.freq)
        self.since = now


class EnergyMeter:
    """Integrates per-core state durations; time source is supplied by the
    executor (virtual time in simulation, ``time.perf_counter`` live).

    Cores may carry individual power models and a DVFS frequency step
    (see :meth:`add_core` / :meth:`set_frequency`); cores added through
    the constructor use the meter-wide default model at full frequency.
    """

    def __init__(self, n_cores: int, power: PowerModel | None = None,
                 t0: float = 0.0) -> None:
        self.power_model = power or PowerModel()
        self._cores = {i: _CoreTimeline(CoreState.SPIN, t0,
                                        power=self.power_model)
                       for i in range(n_cores)}
        self._t0 = t0
        self._t_end: float | None = None
        # Power-cap accounting is lazy: nothing below is touched (and
        # the hot set_state path pays one falsy attribute check) until
        # the first set_power_cap() call.
        self._cap: float | None = None
        self._cap_track = False
        self._watts = 0.0
        self._cap_since = 0.0
        self._cap_violation_s = 0.0

    def add_core(self, core_id: int, state: CoreState, now: float,
                 power: PowerModel | None = None,
                 core_type: str = "") -> None:
        tl = self._cores.get(core_id)
        if tl is not None:
            # Re-registration (e.g. the same CPU borrowed again): keep
            # the accumulated history — overwriting the timeline used to
            # erase the earlier borrow window's energy.  The DVFS step
            # resets to full; the owner re-applies its current plan.
            if self._cap_track:
                self._cap_tick(now)
                self._watts -= tl.power.power(tl.state, tl.freq)
            tl.close_segment(now)
            tl.state = state
            tl.freq = 1.0
            if power is not None:
                tl.power = power
            if core_type:
                tl.core_type = core_type
            if self._cap_track:
                self._watts += tl.power.power(state, 1.0)
            return
        self._cores[core_id] = _CoreTimeline(
            state, now, power=power or self.power_model,
            core_type=core_type)
        if self._cap_track:
            self._cap_tick(now)
            self._watts += self._cores[core_id].power.power(state, 1.0)

    def set_state(self, core_id: int, state: CoreState, now: float) -> None:
        """Transition a core; identical-state calls coalesce (the open
        segment keeps integrating as one (core, state) run — state
        churn that lands back on the same state costs nothing).

        ``close_segment`` is inlined: this runs twice per simulated
        task."""
        tl = self._cores[core_id]
        prev = tl.state
        if prev is state:
            return
        dt = now - tl.since
        if dt > 0.0:
            tl.accum[prev.idx] += dt
            tl.joules += dt * tl.power.power(prev, tl.freq)
        tl.since = now
        if prev is CoreState.IDLE and (state is CoreState.ACTIVE
                                       or state is CoreState.SPIN):
            tl.resumes += 1
        tl.state = state
        if self._cap_track:
            self._cap_tick(now)
            self._watts += (tl.power.power(state, tl.freq)
                            - tl.power.power(prev, tl.freq))

    def set_frequency(self, core_id: int, freq: float, now: float) -> None:
        """Re-clock a core: the open segment is accounted at the old step."""
        tl = self._cores[core_id]
        if tl.freq == freq:
            return
        if self._cap_track:
            self._cap_tick(now)
            self._watts -= tl.power.power(tl.state, tl.freq)
        tl.close_segment(now)
        tl.freq = freq
        if self._cap_track:
            self._watts += tl.power.power(tl.state, freq)

    def frequency_of(self, core_id: int) -> float:
        return self._cores[core_id].freq

    def core_ids(self) -> list[int]:
        return list(self._cores)

    # -- power-cap accounting --------------------------------------------

    def _cap_tick(self, now: float) -> None:
        """Close the open constant-draw interval; accumulate violation
        seconds if the draw exceeded the active cap."""
        dt = now - self._cap_since
        if dt > 0.0:
            if self._cap is not None and self._watts > self._cap + 1e-12:
                self._cap_violation_s += dt
            self._cap_since = now

    def set_power_cap(self, now: float, watts: float | None) -> None:
        """Install (or lift, with ``None``) a machine-wide power cap.

        The meter does not *enforce* the cap — policies do, by parking
        cores or lowering frequencies — it *measures* compliance: every
        second the aggregate draw sits above the cap is charged to
        :attr:`cap_violation_s`.  Tracking starts lazily at the first
        call so cap-free runs pay nothing.
        """
        if not self._cap_track:
            self._cap_track = True
            self._watts = sum(tl.power.power(tl.state, tl.freq)
                              for tl in self._cores.values())
            self._cap_since = now
        else:
            self._cap_tick(now)
        self._cap = watts

    @property
    def power_cap_w(self) -> float | None:
        return self._cap

    @property
    def watts(self) -> float:
        """Current aggregate draw (only maintained once a cap was set)."""
        return self._watts

    @property
    def cap_violation_s(self) -> float:
        return self._cap_violation_s

    def finish(self, now: float) -> None:
        if self._cap_track:
            self._cap_tick(now)
        for tl in self._cores.values():
            tl.close_segment(now)
        self._t_end = now

    # -- reports ---------------------------------------------------------

    def state_seconds(self) -> dict[CoreState, float]:
        out = {s: 0.0 for s in CoreState}
        for tl in self._cores.values():
            for s in CoreState:
                out[s] += tl.accum[s.idx]
        return out

    def state_seconds_by_type(self) -> dict[str, dict[CoreState, float]]:
        """Per-core-type state seconds (empty for untyped/homogeneous
        meters — cores added without a ``core_type`` label)."""
        out: dict[str, dict[CoreState, float]] = {}
        for tl in self._cores.values():
            if not tl.core_type:
                continue
            acc = out.setdefault(tl.core_type,
                                 {s: 0.0 for s in CoreState})
            for s in CoreState:
                acc[s] += tl.accum[s.idx]
        return out

    def energy_by_type(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for tl in self._cores.values():
            if not tl.core_type:
                continue
            out[tl.core_type] = (out.get(tl.core_type, 0.0) + tl.joules
                                 + tl.power.resume_energy * tl.resumes)
        return out

    def energy(self) -> float:
        return sum(tl.joules + tl.power.resume_energy * tl.resumes
                   for tl in self._cores.values())

    def elapsed(self) -> float:
        if self._t_end is None:
            raise RuntimeError("EnergyMeter.finish() not called")
        return self._t_end - self._t0

    def edp(self) -> float:
        return self.energy() * self.elapsed()

    def resumes(self) -> int:
        return sum(tl.resumes for tl in self._cores.values())
