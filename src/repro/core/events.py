"""Unified runtime event bus.

Every frontend used to hard-wire its observability: the
:class:`~repro.runtime.scheduler.Scheduler` called the
:class:`~repro.core.monitoring.TaskMonitor` directly, worker-state
transitions were visible only through counters, and predictions left no
record at all.  This module decouples producers from consumers with a
small structured pub/sub:

* producers (``Scheduler``, ``WorkerManager``, ``ResourceGovernor``,
  ``ServingEngine``, ``SimCluster``) publish :class:`RuntimeEvent`\\ s into
  an :class:`EventBus`;
* consumers subscribe — the :class:`TaskMonitor` is now *one subscriber*
  (see :meth:`TaskMonitor.subscribe`), and the
  :class:`~repro.trace.TraceRecorder` is another, which is what makes
  trace record/replay work identically on every frontend.

Events are plain data (:meth:`RuntimeEvent.to_dict` /
:meth:`RuntimeEvent.from_dict` round-trip through JSON), timestamps come
from whatever clock the producer runs on (virtual time in the simulator,
``perf_counter`` live), and publishing with no subscribers is a cheap
no-op so closed-loop hot paths pay nothing.
"""

from __future__ import annotations

import enum
import threading
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Iterable, Mapping

from ..analysis import guarded_by

__all__ = ["EventKind", "RuntimeEvent", "EventBus", "QUIET_INTEREST"]

#: the :attr:`EventBus.interest` value of a bus nobody subscribed to —
#: producers compare against it to skip publish calls entirely on quiet
#: hot paths (one shared definition; an empty frozenset compares equal)
QUIET_INTEREST: frozenset = frozenset()


class EventKind(enum.Enum):
    #: task registered with a scheduler (data: deps, parent)
    TASK_SUBMITTED = "task_submitted"
    #: dependencies satisfied, task entered the ready queue
    TASK_READY = "task_ready"
    #: task popped by a worker (worker_id when the frontend knows it)
    TASK_EXECUTE = "task_execute"
    #: task finished (elapsed = measured seconds; data: parent)
    TASK_COMPLETED = "task_completed"
    #: open-workload arrival released a task into the runtime
    TASK_ARRIVED = "task_arrived"
    #: worker state transition (data: state, prev) — resumes, idles, lends
    WORKER_STATE = "worker_state"
    #: one Algorithm-1 tick (data: delta)
    PREDICTION = "prediction"
    #: inter-node network transfer on a cross-node dependency edge
    #: (multi-node clusters; data: src, dst, seconds)
    TRANSFER = "transfer"
    #: machine-condition change applied by the runtime (power cap,
    #: core fail/recover, thermal throttle, straggler onset); ``data``
    #: is the :meth:`~repro.core.conditions.Perturbation.to_dict`
    #: payload, so a recorded perturbed run carries its own timeline
    #: and replays byte-exactly
    PERTURBATION = "perturbation"
    #: request left the system without completing: refused by admission
    #: control, evicted from a full queue by a higher-priority arrival,
    #: or abandoned after its deadline/retry budget ran out
    #: (``data["reason"]`` ∈ {"queue", "deadline", "timeout"})
    SHED = "shed"
    #: a timed-out attempt was re-released after exponential backoff
    #: (``data``: try number, backoff seconds) or requeued uncharged
    #: after a capacity change tore it off its replica
    RETRY = "retry"
    #: a hedged duplicate attempt was issued for a tail request
    #: (``worker_id`` = the hedge replica; first completion wins)
    HEDGE = "hedge"
    #: graceful-degradation mode change: brownout engage/release under
    #: a power cap, or a circuit breaker quarantining / re-probing a
    #: replica (``data["mode"]``)
    DEGRADE = "degrade"


@dataclass(frozen=True, slots=True)
class RuntimeEvent:
    """One structured runtime event; immutable and JSON-serializable."""

    kind: EventKind
    time: float
    task_id: int | None = None
    type_name: str | None = None
    cost: float | None = None
    worker_id: int | None = None
    elapsed: float | None = None
    #: application namespace for multi-app traces (co-scheduled jobs
    #: share one machine but publish on per-app buses; the bus stamps
    #: this so a combined recording can be split back per app).  None on
    #: single-app frontends — the field round-trips through JSON only
    #: when set, so existing traces stay byte-identical.
    app: str | None = None
    #: per-stream monotonic sequence stamp for multi-threaded producers
    #: (one stream per publishing worker, plus one for the submit side).
    #: Appends from N worker threads interleave in recorder-lock order,
    #: not program order; the stamp lets
    #: :meth:`~repro.trace.TraceRecorder.merged_events` reconstruct the
    #: canonical per-stream order at flush time.  None on
    #: single-threaded frontends (the simulator) — like ``app``, the
    #: field round-trips through JSON only when set, so existing traces
    #: stay byte-identical.
    seq: int | None = None
    #: locality stamps for multi-node runs: the node the producing job
    #: lives on and the socket of ``worker_id`` (when the bus knows the
    #: topology).  Like ``app``/``seq`` they serialize only when set, so
    #: single-node traces stay byte-identical.
    node: int | None = None
    socket: int | None = None
    data: Mapping[str, Any] = field(default_factory=dict)

    def to_dict(self) -> dict[str, Any]:
        d: dict[str, Any] = {"kind": self.kind.value, "time": self.time}
        for k in ("task_id", "type_name", "cost", "worker_id", "elapsed",
                  "app", "seq", "node", "socket"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        if self.data:
            d["data"] = dict(self.data)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RuntimeEvent":
        d = dict(d)
        d["kind"] = EventKind(d["kind"])
        return cls(**d)


@guarded_by("_subs", "interest")
class EventBus:
    """Thread-safe pub/sub for :class:`RuntimeEvent`.

    Subscribers are called synchronously, in subscription order, on the
    publisher's thread — handlers must be fast and must not call back
    into the publisher.  ``kinds`` filters at the bus so uninterested
    subscribers cost nothing per event.

    ``app`` names the application this bus belongs to: published events
    with no ``app`` of their own are stamped with it, which is what lets
    a recorder attached to several per-app buses produce one splittable
    multi-app trace.  ``node`` (the app's home node) and ``socket_of``
    (worker id → socket) stamp locality the same way on multi-node
    runs; both default to off so single-node traces are unchanged.
    """

    def __init__(self, app: str | None = None, node: int | None = None,
                 socket_of: Callable[[int], int] | None = None) -> None:
        self._lock = threading.Lock()
        self.app = app
        self.node = node
        self.socket_of = socket_of
        # Copy-on-write subscriber list: publish() iterates a snapshot
        # without holding the lock.
        self._subs: tuple[tuple[Callable[[RuntimeEvent], None],
                                frozenset[EventKind] | None], ...] = ()
        #: public read-only view of subscriber interest — the union of
        #: every subscriber's kind filter.  None ⇒ some subscriber wants
        #: all kinds; empty (== :data:`QUIET_INTEREST`) ⇒ nobody wants
        #: anything.  Recomputed on (un)subscribe so per-event pre-checks
        #: are one attribute load + set probe; producers read it directly
        #: on hot paths (scheduler, manager, governor).
        self.interest: frozenset[EventKind] | None = QUIET_INTEREST

    def _recompute_interest_locked(self) -> None:
        kinds: set[EventKind] = set()
        for _, ks in self._subs:
            if ks is None:
                self.interest = None
                return
            kinds |= ks
        self.interest = frozenset(kinds)

    def subscribe(self, handler: Callable[[RuntimeEvent], None],
                  kinds: Iterable[EventKind] | None = None,
                  ) -> Callable[[RuntimeEvent], None]:
        """Register ``handler`` (for ``kinds``, or all); returns it so the
        caller can later :meth:`unsubscribe` the same object.

        Subscribing a handler that is already registered (equality, not
        identity — bound methods compare equal by (function, instance))
        does NOT add a second entry: it updates the existing entry's kind
        filter.  Double delivery silently doubled every subscriber-side
        aggregate (e.g. TaskMonitor costs), and was asymmetric with
        :meth:`unsubscribe`.
        """
        ks = frozenset(kinds) if kinds is not None else None
        with self._lock:
            for i, (h, _) in enumerate(self._subs):
                if h == handler:
                    self._subs = (self._subs[:i] + ((handler, ks),)
                                  + self._subs[i + 1:])
                    self._recompute_interest_locked()
                    return handler
            self._subs = self._subs + ((handler, ks),)
            self._recompute_interest_locked()
        return handler

    def unsubscribe(self, handler: Callable[[RuntimeEvent], None]) -> None:
        # Equality, not identity: each access to a bound method (e.g.
        # ``monitor._on_event``) builds a fresh object, and bound methods
        # compare equal by (function, instance).  Removes exactly the one
        # matching entry — subscribe() guarantees there is at most one —
        # keeping the pair symmetric (one subscribe ⟺ one unsubscribe).
        with self._lock:
            for i, (h, _) in enumerate(self._subs):
                if h == handler:
                    self._subs = self._subs[:i] + self._subs[i + 1:]
                    self._recompute_interest_locked()
                    return

    @property
    def n_subscribers(self) -> int:
        return len(self._subs)

    def interested(self, kind: EventKind) -> bool:
        """True iff some subscriber would receive ``kind`` — the cheap
        pre-check that lets producers skip building event payloads on
        hot paths (a kind-filtered subscriber, e.g. the TaskMonitor,
        does not make the bus interested in other kinds).  One set
        lookup against the cached interest union — O(1) regardless of
        subscriber count."""
        interest = self.interest
        if interest is None:
            return True
        # `not interest` before the containment check: an empty frozenset
        # (subscriber-free bus — THE hot case) answers without hashing
        # the enum member (enum.__hash__ is a Python-level call).
        return bool(interest) and kind in interest

    def publish(self, event: RuntimeEvent) -> None:
        # Same pre-check publish-side: on a subscriber-free bus (or one
        # whose subscribers filter this kind out) this returns before the
        # app-stamping replace(), so publishing is a no-alloc no-op.
        interest = self.interest
        if interest is not None and (not interest
                                     or event.kind not in interest):
            return
        if self.app is not None and event.app is None:
            event = replace(event, app=self.app)
        if self.node is not None and event.node is None:
            event = replace(event, node=self.node)
        if (self.socket_of is not None and event.socket is None
                and event.worker_id is not None):
            event = replace(event, socket=self.socket_of(event.worker_id))
        for handler, kinds in self._subs:
            if kinds is None or event.kind in kinds:
                handler(event)
