"""Unified runtime event bus.

Every frontend used to hard-wire its observability: the
:class:`~repro.runtime.scheduler.Scheduler` called the
:class:`~repro.core.monitoring.TaskMonitor` directly, worker-state
transitions were visible only through counters, and predictions left no
record at all.  This module decouples producers from consumers with a
small structured pub/sub:

* producers (``Scheduler``, ``WorkerManager``, ``ResourceGovernor``,
  ``ServingEngine``, ``SimCluster``) publish :class:`RuntimeEvent`\\ s into
  an :class:`EventBus`;
* consumers subscribe — the :class:`TaskMonitor` is now *one subscriber*
  (see :meth:`TaskMonitor.subscribe`), and the
  :class:`~repro.trace.TraceRecorder` is another, which is what makes
  trace record/replay work identically on every frontend.

Events are plain data (:meth:`RuntimeEvent.to_dict` /
:meth:`RuntimeEvent.from_dict` round-trip through JSON), timestamps come
from whatever clock the producer runs on (virtual time in the simulator,
``perf_counter`` live), and publishing with no subscribers is a cheap
no-op so closed-loop hot paths pay nothing.
"""

from __future__ import annotations

import enum
import threading
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Iterable, Mapping

__all__ = ["EventKind", "RuntimeEvent", "EventBus"]


class EventKind(enum.Enum):
    #: task registered with a scheduler (data: deps, parent)
    TASK_SUBMITTED = "task_submitted"
    #: dependencies satisfied, task entered the ready queue
    TASK_READY = "task_ready"
    #: task popped by a worker (worker_id when the frontend knows it)
    TASK_EXECUTE = "task_execute"
    #: task finished (elapsed = measured seconds; data: parent)
    TASK_COMPLETED = "task_completed"
    #: open-workload arrival released a task into the runtime
    TASK_ARRIVED = "task_arrived"
    #: worker state transition (data: state, prev) — resumes, idles, lends
    WORKER_STATE = "worker_state"
    #: one Algorithm-1 tick (data: delta)
    PREDICTION = "prediction"


@dataclass(frozen=True)
class RuntimeEvent:
    """One structured runtime event; immutable and JSON-serializable."""

    kind: EventKind
    time: float
    task_id: int | None = None
    type_name: str | None = None
    cost: float | None = None
    worker_id: int | None = None
    elapsed: float | None = None
    #: application namespace for multi-app traces (co-scheduled jobs
    #: share one machine but publish on per-app buses; the bus stamps
    #: this so a combined recording can be split back per app).  None on
    #: single-app frontends — the field round-trips through JSON only
    #: when set, so existing traces stay byte-identical.
    app: str | None = None
    data: Mapping[str, Any] = field(default_factory=dict)

    def to_dict(self) -> dict[str, Any]:
        d: dict[str, Any] = {"kind": self.kind.value, "time": self.time}
        for k in ("task_id", "type_name", "cost", "worker_id", "elapsed",
                  "app"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        if self.data:
            d["data"] = dict(self.data)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RuntimeEvent":
        d = dict(d)
        d["kind"] = EventKind(d["kind"])
        return cls(**d)


class EventBus:
    """Thread-safe pub/sub for :class:`RuntimeEvent`.

    Subscribers are called synchronously, in subscription order, on the
    publisher's thread — handlers must be fast and must not call back
    into the publisher.  ``kinds`` filters at the bus so uninterested
    subscribers cost nothing per event.

    ``app`` names the application this bus belongs to: published events
    with no ``app`` of their own are stamped with it, which is what lets
    a recorder attached to several per-app buses produce one splittable
    multi-app trace.
    """

    def __init__(self, app: str | None = None) -> None:
        self._lock = threading.Lock()
        self.app = app
        # Copy-on-write subscriber list: publish() iterates a snapshot
        # without holding the lock.
        self._subs: tuple[tuple[Callable[[RuntimeEvent], None],
                                frozenset[EventKind] | None], ...] = ()

    def subscribe(self, handler: Callable[[RuntimeEvent], None],
                  kinds: Iterable[EventKind] | None = None,
                  ) -> Callable[[RuntimeEvent], None]:
        """Register ``handler`` (for ``kinds``, or all); returns it so the
        caller can later :meth:`unsubscribe` the same object.

        Subscribing a handler that is already registered (equality, not
        identity — bound methods compare equal by (function, instance))
        does NOT add a second entry: it updates the existing entry's kind
        filter.  Double delivery silently doubled every subscriber-side
        aggregate (e.g. TaskMonitor costs), and was asymmetric with
        :meth:`unsubscribe`.
        """
        ks = frozenset(kinds) if kinds is not None else None
        with self._lock:
            for i, (h, _) in enumerate(self._subs):
                if h == handler:
                    self._subs = (self._subs[:i] + ((handler, ks),)
                                  + self._subs[i + 1:])
                    return handler
            self._subs = self._subs + ((handler, ks),)
        return handler

    def unsubscribe(self, handler: Callable[[RuntimeEvent], None]) -> None:
        # Equality, not identity: each access to a bound method (e.g.
        # ``monitor._on_event``) builds a fresh object, and bound methods
        # compare equal by (function, instance).  Removes exactly the one
        # matching entry — subscribe() guarantees there is at most one —
        # keeping the pair symmetric (one subscribe ⟺ one unsubscribe).
        with self._lock:
            for i, (h, _) in enumerate(self._subs):
                if h == handler:
                    self._subs = self._subs[:i] + self._subs[i + 1:]
                    return

    @property
    def n_subscribers(self) -> int:
        return len(self._subs)

    def interested(self, kind: EventKind) -> bool:
        """True iff some subscriber would receive ``kind`` — the cheap
        pre-check that lets producers skip building event payloads on
        hot paths (a kind-filtered subscriber, e.g. the TaskMonitor,
        does not make the bus interested in other kinds)."""
        return any(ks is None or kind in ks for _, ks in self._subs)

    def publish(self, event: RuntimeEvent) -> None:
        if self.app is not None and event.app is None:
            event = replace(event, app=self.app)
        for handler, kinds in self._subs:
            if kinds is None or event.kind in kinds:
                handler(event)
