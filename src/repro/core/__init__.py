"""The paper's primary contribution: the monitoring + prediction
infrastructure (§3.1), Algorithm 1 (CPU-utilization prediction),
Algorithm 2 (the prediction policy in the CPU manager), the baseline
policies (busy/idle/hybrid) and the DLB-style prediction-based resource
sharing (§3.3).  Everything here is host-side decision logic; the same
objects drive the threaded executor, the discrete-event simulator, and the
distributed elastic controller / serving autoscaler.
"""

from .arbiter import (AppPlan, AppShareStats, ClusterArbiter,
                      MultiAppReport, jain_fairness)
from .cost import CostClause, TaskTypeInfo, TaskTypeRegistry
from .energy import CoreState, EnergyMeter, PowerModel
from .events import EventBus, EventKind, RuntimeEvent
from .governor import (DEFAULT_MIN_SAMPLES, GovernorReport, GovernorSpec,
                       PolicyEntry, ResourceGovernor, policy_entry,
                       register_policy, registered_policies)
from .manager import WorkerManager, WorkerState
from .monitoring import (EMA, AccuracyReport, HeteroTypeSnapshot,
                         TaskMonitor, TypeMetrics)
from .policies import (BusyPolicy, HeteroPredictionPolicy, HybridPolicy,
                       IdlePolicy, Policy, PollDecision, PredictionPolicy)
from .prediction import (DEFAULT_PREDICTION_RATE_S, CPUPredictor,
                         HeteroPlan, PredictionConfig)
from .sharing import (DLBHybridPolicy, DLBPredictionPolicy, LeWIPolicy,
                      ResourceBroker, SharingPolicy)
from .topology import CoreTopology, CoreType

__all__ = [
    "AppPlan", "AppShareStats", "ClusterArbiter", "MultiAppReport",
    "jain_fairness",
    "CostClause", "TaskTypeInfo", "TaskTypeRegistry",
    "CoreState", "EnergyMeter", "PowerModel",
    "EventBus", "EventKind", "RuntimeEvent",
    "DEFAULT_MIN_SAMPLES", "GovernorReport", "GovernorSpec", "PolicyEntry",
    "ResourceGovernor", "policy_entry", "register_policy",
    "registered_policies",
    "WorkerManager", "WorkerState",
    "EMA", "AccuracyReport", "HeteroTypeSnapshot", "TaskMonitor",
    "TypeMetrics",
    "BusyPolicy", "HeteroPredictionPolicy", "HybridPolicy", "IdlePolicy",
    "Policy", "PollDecision", "PredictionPolicy",
    "DEFAULT_PREDICTION_RATE_S", "CPUPredictor", "HeteroPlan",
    "PredictionConfig",
    "DLBHybridPolicy", "DLBPredictionPolicy", "LeWIPolicy",
    "ResourceBroker", "SharingPolicy",
    "CoreTopology", "CoreType",
]
