"""Resource-management policies (paper §2, §3.2 — Algorithm 2).

A policy decides what a worker does when it polls for work and finds none
(``on_poll_empty``) and whether sleeping workers should be woken when new
work arrives (``workers_to_resume``).  The mechanics of idling/resuming are
owned by the executor's :class:`~repro.core.manager.WorkerManager`; policies
are pure decision logic so the same implementations drive the real threaded
executor, the discrete-event simulator, and the distributed elastic
controller.

Implemented policies:

* ``busy``        — OpenMP *active* / OmpSs-2 *busy*: spin forever.
* ``idle``        — OpenMP *passive* / OmpSs-2 *idle*: sleep immediately;
                    woken whenever tasks are added.
* ``hybrid``      — spin for a fixed budget, then sleep (OpenMP
                    ``OMP_WAIT_POLICY`` tuning).
* ``prediction``  — the paper's policy (Alg. 2): sleep only when the active
                    count δ exceeds the predicted optimum Δ; wake only while
                    δ < Δ.
"""

from __future__ import annotations

import enum
from abc import ABC, abstractmethod
from typing import Callable, Mapping

from .prediction import CPUPredictor

__all__ = [
    "PollDecision",
    "Policy",
    "BusyPolicy",
    "IdlePolicy",
    "HybridPolicy",
    "PredictionPolicy",
    "HeteroPredictionPolicy",
]


class PollDecision(enum.Enum):
    SPIN = "spin"    # keep burning cycles, poll again
    IDLE = "idle"    # release the CPU until resumed
    LEND = "lend"    # give the CPU to the resource broker (sharing mode)


class Policy(ABC):
    """Decision logic consulted by executors.

    ``active``/``idle`` counts are supplied by the caller (they are owned
    by the worker manager and updated atomically there).
    """

    name: str = "abstract"
    #: whether the executor should drive predictor ticks for this policy
    uses_predictions: bool = False
    #: True ⇔ :meth:`on_poll_empty` ignores ``worker_id`` and
    #: ``spin_count`` — the decision is a pure function of ``active``.
    #: Lets tick-time re-evaluation loops stop at the first SPIN verdict
    #: (every remaining spinner would get the identical answer, and the
    #: skipped spin-count increments are unread by such policies).
    poll_uniform: bool = False
    #: True ⇔ :meth:`on_poll_empty` can never return anything but SPIN.
    #: Real-thread executors may then skip the per-empty-poll manager
    #: round-trip entirely (the spin counts such a policy never reads are
    #: the only state the skipped call would have touched).
    never_idles: bool = False

    @abstractmethod
    def on_poll_empty(self, worker_id: int, active: int, spin_count: int,
                      ) -> PollDecision:
        """Worker ``worker_id`` polled and the ready queue was empty.

        ``active`` is the current number of non-idle workers (δ);
        ``spin_count`` how many consecutive empty polls this worker has
        made since it last executed a task.
        """

    @abstractmethod
    def workers_to_resume(self, active: int, idle: int, ready_tasks: int,
                          ) -> int:
        """How many idle workers to wake after tasks were added.

        ``idle`` is the number of currently-sleeping workers and
        ``ready_tasks`` the number of tasks now ready.
        """

    def on_prediction_tick(self) -> None:  # pragma: no cover - default no-op
        """Called by the executor at the prediction rate (if enabled)."""

    def target(self, queued: int, active: int, n_resources: int) -> int:
        """Desired resource count for pull-style frontends (the serving
        autoscaler / elastic trainer ask this instead of running a worker
        loop).  Default: purely reactive — one resource per unit of load,
        capped at what we own (the idle policy's behaviour)."""
        return min(queued + active, n_resources)


class BusyPolicy(Policy):
    name = "busy"
    poll_uniform = True
    never_idles = True

    def on_poll_empty(self, worker_id: int, active: int, spin_count: int,
                      ) -> PollDecision:
        return PollDecision.SPIN

    def workers_to_resume(self, active: int, idle: int, ready_tasks: int,
                          ) -> int:
        # Nothing ever sleeps under busy, but if the executor started some
        # workers idle, wake everything.
        return idle

    def target(self, queued: int, active: int, n_resources: int) -> int:
        return n_resources  # everything stays hot, load or not


class IdlePolicy(Policy):
    """Sleep on the first empty poll; wake (up to one worker per ready
    task) whenever work is added — OmpSs-2's idle policy is reactive:
    "as tasks are created, threads are resumed so they may poll once
    again"."""

    name = "idle"

    def on_poll_empty(self, worker_id: int, active: int, spin_count: int,
                      ) -> PollDecision:
        return PollDecision.IDLE

    def workers_to_resume(self, active: int, idle: int, ready_tasks: int,
                          ) -> int:
        return min(idle, max(0, ready_tasks - active))


class HybridPolicy(Policy):
    """Spin for ``spin_budget`` consecutive empty polls, then idle.

    The budget is the static user-chosen rate the paper criticizes ("the
    chosen rate is a static value that cannot be changed at run-time").
    """

    name = "hybrid"

    def __init__(self, spin_budget: int = 100) -> None:
        if spin_budget < 1:
            raise ValueError("spin_budget must be >= 1")
        self.spin_budget = spin_budget

    def on_poll_empty(self, worker_id: int, active: int, spin_count: int,
                      ) -> PollDecision:
        if spin_count < self.spin_budget:
            return PollDecision.SPIN
        return PollDecision.IDLE

    def workers_to_resume(self, active: int, idle: int, ready_tasks: int,
                          ) -> int:
        return min(idle, max(0, ready_tasks - active))


class PredictionPolicy(Policy):
    """The paper's policy — Algorithm 2.

    * Poll-empty + ``δ > Δ``  → idle this worker (δ is decremented by the
      manager as part of the idle transition).
    * Poll-empty + ``δ ≤ Δ``  → keep spinning (the prediction says this
      CPU will be needed within the next window).
    * Tasks added + ``δ < Δ`` → resume ``Δ − δ`` workers.

    Δ is refreshed by :meth:`on_prediction_tick` at the prediction rate
    ``f`` and read from the predictor's atomic.
    """

    name = "prediction"
    uses_predictions = True
    poll_uniform = True

    def __init__(self, predictor: CPUPredictor) -> None:
        self.predictor = predictor

    def on_poll_empty(self, worker_id: int, active: int, spin_count: int,
                      ) -> PollDecision:
        if active > self.predictor.delta:
            return PollDecision.IDLE
        return PollDecision.SPIN

    def workers_to_resume(self, active: int, idle: int, ready_tasks: int,
                          ) -> int:
        want = self.predictor.delta - active
        if want <= 0:
            return 0
        return min(idle, want, ready_tasks)

    def on_prediction_tick(self) -> None:
        self.predictor.tick()

    def target(self, queued: int, active: int, n_resources: int) -> int:
        if queued + active <= 0:
            return 0  # no live work ⇒ scale to zero
        # Cap at what the frontend owns: the predictor may be configured
        # with allow_oversubscription (the DLB arrangement), but a
        # non-sharing pull-style frontend (autoscaler / elastic trainer)
        # cannot scale beyond its own resources.
        return min(self.predictor.delta, n_resources)


class HeteroPredictionPolicy(PredictionPolicy):
    """Frequency-aware prediction on heterogeneous cores.

    Like :class:`PredictionPolicy`, but the idle/spin decision is made
    per *core type* against the predictor's Δ_c split (fastest cores are
    filled first by :meth:`~repro.core.prediction.CPUPredictor.compute_plan`),
    so surplus capacity is parked on the right silicon ("park the E-cores
    last" vs "park the P-cores last" is the manager's park order; this
    policy decides *how many* of each type stay hot).  The recommended
    DVFS step per type is applied by the governor on every tick.

    With a single homogeneous core type every decision reduces to the
    parent class — the parity the tests pin down.

    The governor binds :meth:`bind_topology` after the worker manager
    exists; unbound (pull-style frontends), decisions fall back to the
    total-Δ logic.
    """

    name = "hetero-prediction"
    #: decisions depend on the polling worker's core type — NOT uniform
    poll_uniform = False

    def __init__(self, predictor: CPUPredictor) -> None:
        super().__init__(predictor)
        self._type_of: Callable[[int], str] | None = None
        self._active_by_type: Callable[[], Mapping[str, int]] | None = None

    def bind_topology(self, type_of: Callable[[int], str],
                      active_by_type: Callable[[], Mapping[str, int]],
                      ) -> None:
        """Wire worker→core-type mapping and the per-type active counts.

        ``active_by_type`` is called from inside the worker manager's
        lock (poll decisions happen there), so it must be the manager's
        *unlocked* reader.
        """
        self._type_of = type_of
        self._active_by_type = active_by_type

    def on_poll_empty(self, worker_id: int, active: int, spin_count: int,
                      ) -> PollDecision:
        if self._type_of is None or self._active_by_type is None:
            return super().on_poll_empty(worker_id, active, spin_count)
        by_type = self.predictor.delta_by_type
        if not by_type:
            return super().on_poll_empty(worker_id, active, spin_count)
        ct = self._type_of(worker_id)
        if self._active_by_type().get(ct, 0) > by_type.get(ct, 0):
            return PollDecision.IDLE
        return PollDecision.SPIN

    def workers_to_resume(self, active: int, idle: int, ready_tasks: int,
                          ) -> int:
        if self._active_by_type is None:
            return super().workers_to_resume(active, idle, ready_tasks)
        by_type = self.predictor.delta_by_type
        if not by_type:
            return super().workers_to_resume(active, idle, ready_tasks)
        # Per-type deficit, not the total: a stale spinner on a slow
        # type must not mask a missing fast core — critical-path tasks
        # would otherwise land on the slow silicon.  (The manager wakes
        # in reverse park order, so fast types come back first; any
        # over-waking is trimmed at the next prediction tick.)
        counts = self._active_by_type()
        want = sum(max(0, d - counts.get(ct, 0))
                   for ct, d in by_type.items())
        if want <= 0:
            return 0
        return min(idle, want, ready_tasks)
