"""The ``cost`` clause (paper §3.1).

The paper relies on a user-provided *cost clause* per task: a rough,
monotone measure of the computational weight of a task instance (e.g. the
tile size cubed for a GEMM task).  Costs are what let the monitoring
infrastructure *normalize* measured execution times across instances of the
same task type — two instances with different inputs map onto one *unitary
cost* (time per unit of cost), which extrapolates to any future instance.

``CostClause.evaluate`` is evaluated once, at task-creation time, outside the
runtime critical path.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass(frozen=True)
class CostClause:
    """A cost expression attached to a task type.

    Either a callable over the task's arguments (mirrors OmpSs-2's
    ``cost(expr)`` clause, evaluated per instance) or a constant.
    """

    fn: Callable[..., float] | None = None
    constant: float = 1.0

    def evaluate(self, *args: Any, **kwargs: Any) -> float:
        if self.fn is None:
            return float(self.constant)
        value = float(self.fn(*args, **kwargs))
        if value <= 0.0:
            # A non-positive cost would poison the unitary-cost
            # normalization; clamp like the reference runtime does.
            return 1.0
        return value


@dataclass
class TaskTypeInfo:
    """Static registry entry for a task type (label + cost clause)."""

    name: str
    cost: CostClause = field(default_factory=CostClause)

    def instance_cost(self, *args: Any, **kwargs: Any) -> float:
        return self.cost.evaluate(*args, **kwargs)


class TaskTypeRegistry:
    """Process-wide registry of task types.

    Task types are the aggregation key of the whole monitoring
    infrastructure (paper: "aggregation of metrics in a per-thread and
    per-task type basis").
    """

    def __init__(self) -> None:
        self._types: dict[str, TaskTypeInfo] = {}

    def register(self, name: str, cost: CostClause | None = None) -> TaskTypeInfo:
        info = self._types.get(name)
        if info is None:
            info = TaskTypeInfo(name=name, cost=cost or CostClause())
            self._types[name] = info
        elif cost is not None:
            info.cost = cost
        return info

    def get(self, name: str) -> TaskTypeInfo:
        try:
            return self._types[name]
        except KeyError:
            return self.register(name)

    def __contains__(self, name: str) -> bool:
        return name in self._types

    def names(self) -> list[str]:
        return list(self._types)
