"""Dynamic machine conditions: power caps, faults, thermal throttling.

Every layer of the runtime historically assumed a static, failure-free,
power-unconstrained machine.  This module is the single source of truth
for *perturbed* machines:

``Perturbation``
    One timestamped change to the machine — a power cap, a core
    failing or recovering, a core type being thermally throttled, or a
    core turning into a straggler.

``ConditionTimeline``
    An immutable, time-sorted schedule of perturbations.  Like
    :mod:`repro.workloads.arrivals` it is seeded and wall-clock-free:
    the random scenario constructors build a fresh
    ``random.Random(seed)`` on every call, so the same seed always
    yields the same timeline.

``MachineConditions``
    The live view the runtime consults while executing: which cores are
    currently failed, the thermal frequency cap per core type, the
    per-core straggler slowdown, and the active power cap.  The sim
    applies each perturbation exactly once (heap-ordered) by calling
    :meth:`MachineConditions.apply`.

The empty timeline is the degenerate case: no layer changes behaviour
when no conditions object is installed, so unperturbed runs stay
byte-identical to the pre-conditions code.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from enum import Enum
from typing import Iterable, Iterator


class PerturbationKind(Enum):
    """What changed about the machine."""

    POWER_CAP = "power_cap"          # machine-wide power budget (watts)
    CORE_FAIL = "core_fail"          # core drops dead
    CORE_RECOVER = "core_recover"    # failed core comes back
    THERMAL_THROTTLE = "thermal_throttle"  # core type capped at freq
    STRAGGLER = "straggler"          # core silently slows down


@dataclass(frozen=True, slots=True)
class Perturbation:
    """One timestamped machine-condition change.

    Only the fields relevant to ``kind`` are meaningful; the rest stay
    at their defaults (and are omitted from :meth:`to_dict`).
    """

    time: float
    kind: PerturbationKind
    core: int | None = None          # CORE_FAIL / CORE_RECOVER / STRAGGLER
    core_type: str | None = None     # THERMAL_THROTTLE
    watts: float | None = None       # POWER_CAP (None lifts the cap)
    freq: float | None = None        # THERMAL_THROTTLE cap (None lifts)
    slowdown: float | None = None    # STRAGGLER multiplier (None cures;
    #                                  1.0 keeps the suspect marker with
    #                                  no dilation — the replay case)

    def to_dict(self) -> dict:
        d: dict = {"time": self.time, "kind": self.kind.value}
        if self.core is not None:
            d["core"] = self.core
        if self.core_type is not None:
            d["core_type"] = self.core_type
        if self.watts is not None:
            d["watts"] = self.watts
        if self.freq is not None:
            d["freq"] = self.freq
        if self.slowdown is not None:
            d["slowdown"] = self.slowdown
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Perturbation":
        return cls(
            time=float(d["time"]),
            kind=PerturbationKind(d["kind"]),
            core=d.get("core"),
            core_type=d.get("core_type"),
            watts=d.get("watts"),
            freq=d.get("freq"),
            slowdown=d.get("slowdown"),
        )


def power_cap(time: float, watts: float | None) -> Perturbation:
    return Perturbation(time, PerturbationKind.POWER_CAP, watts=watts)


def core_fail(time: float, core: int) -> Perturbation:
    return Perturbation(time, PerturbationKind.CORE_FAIL, core=core)


def core_recover(time: float, core: int) -> Perturbation:
    return Perturbation(time, PerturbationKind.CORE_RECOVER, core=core)


def thermal_throttle(time: float, core_type: str,
                     freq: float | None) -> Perturbation:
    return Perturbation(time, PerturbationKind.THERMAL_THROTTLE,
                        core_type=core_type, freq=freq)


def straggler(time: float, core: int, slowdown: float) -> Perturbation:
    if slowdown < 1.0:
        raise ValueError(f"straggler slowdown must be >= 1.0: {slowdown}")
    return Perturbation(time, PerturbationKind.STRAGGLER, core=core,
                        slowdown=slowdown)


class ConditionTimeline:
    """A time-sorted, immutable schedule of :class:`Perturbation`s.

    Construction sorts by ``(time, insertion order)`` so simultaneous
    perturbations apply in the order they were listed — deterministic
    regardless of the caller's container type.
    """

    def __init__(self, perturbations: Iterable[Perturbation] = ()):
        events = list(perturbations)
        for p in events:
            if p.time < 0.0:
                raise ValueError(f"perturbation time must be >= 0: {p}")
        order = {id(p): i for i, p in enumerate(events)}
        events.sort(key=lambda p: (p.time, order[id(p)]))
        self._events: tuple[Perturbation, ...] = tuple(events)

    def __iter__(self) -> Iterator[Perturbation]:
        return iter(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def __bool__(self) -> bool:
        return bool(self._events)

    @property
    def events(self) -> tuple[Perturbation, ...]:
        return self._events

    def to_dicts(self) -> list[dict]:
        return [p.to_dict() for p in self._events]

    @classmethod
    def from_dicts(cls, rows: Iterable[dict]) -> "ConditionTimeline":
        return cls(Perturbation.from_dict(r) for r in rows)

    def neutralized(self) -> "ConditionTimeline":
        """Timeline for *replay* on a neutral machine.

        Replayed graphs carry the originally *observed* task durations,
        so speed-changing perturbations must not dilate them a second
        time: STRAGGLER keeps its suspect marker but with slowdown 1.0,
        and THERMAL_THROTTLE lifts to full frequency.  Structural
        perturbations (POWER_CAP, CORE_FAIL, CORE_RECOVER) are kept
        verbatim — they drive scheduling decisions, not durations.
        """
        out = []
        for p in self._events:
            if p.kind is PerturbationKind.STRAGGLER:
                out.append(Perturbation(p.time, p.kind, core=p.core,
                                        slowdown=1.0))
            elif p.kind is PerturbationKind.THERMAL_THROTTLE:
                out.append(Perturbation(p.time, p.kind,
                                        core_type=p.core_type, freq=1.0))
            else:
                out.append(p)
        return ConditionTimeline(out)

    # ---- seeded scenario constructors (arrivals.py discipline) ----

    @classmethod
    def random_faults(cls, *, n_cores: int, horizon: float,
                      n_faults: int = 2, mttr: float | None = None,
                      seed: int = 0) -> "ConditionTimeline":
        """``n_faults`` random fail(+recover) pairs inside ``horizon``.

        A fresh ``random.Random(seed)`` is built per call — no hidden
        state, no wall clock.  When ``mttr`` is given each failed core
        recovers after an exponential repair time (clamped inside the
        horizon); otherwise failures are permanent.
        """
        rng = random.Random(seed)
        events: list[Perturbation] = []
        cores = list(range(n_cores))
        for _ in range(n_faults):
            if not cores:
                break
            core = cores.pop(rng.randrange(len(cores)))
            t = rng.uniform(0.0, horizon)
            events.append(core_fail(t, core))
            if mttr is not None:
                dt = rng.expovariate(1.0 / mttr)
                t_rec = t + dt
                if t_rec < horizon:
                    events.append(core_recover(t_rec, core))
        return cls(events)

    @classmethod
    def random_stragglers(cls, *, n_cores: int, horizon: float,
                          n_stragglers: int = 1,
                          slowdown_range: tuple[float, float] = (2.0, 8.0),
                          seed: int = 0) -> "ConditionTimeline":
        """Random cores turn into stragglers at random times."""
        rng = random.Random(seed)
        events: list[Perturbation] = []
        cores = list(range(n_cores))
        lo, hi = slowdown_range
        for _ in range(n_stragglers):
            if not cores:
                break
            core = cores.pop(rng.randrange(len(cores)))
            events.append(straggler(rng.uniform(0.0, horizon), core,
                                    rng.uniform(lo, hi)))
        return cls(events)


class MachineConditions:
    """Live view of the machine's current condition.

    The sim owns one of these per run and calls :meth:`apply` for each
    scheduled perturbation; every other layer only *reads* it.  All
    collections are dicts (never sets) so iteration order is the
    deterministic insertion order.
    """

    def __init__(self, timeline: ConditionTimeline | None = None):
        self.timeline = timeline if timeline is not None \
            else ConditionTimeline()
        self._failed: dict[int, bool] = {}
        self._thermal_caps: dict[str, float] = {}
        self._slowdowns: dict[int, float] = {}
        self.power_cap_w: float | None = None

    # ---- mutation (sim-only) ----

    def apply(self, p: Perturbation) -> None:
        k = p.kind
        if k is PerturbationKind.POWER_CAP:
            self.power_cap_w = p.watts
        elif k is PerturbationKind.CORE_FAIL:
            self._failed[p.core] = True
        elif k is PerturbationKind.CORE_RECOVER:
            self._failed.pop(p.core, None)
        elif k is PerturbationKind.THERMAL_THROTTLE:
            if p.freq is None or p.freq >= 1.0:
                self._thermal_caps.pop(p.core_type, None)
            else:
                self._thermal_caps[p.core_type] = p.freq
        elif k is PerturbationKind.STRAGGLER:
            if p.slowdown is None:
                self._slowdowns.pop(p.core, None)
            else:
                self._slowdowns[p.core] = p.slowdown

    # ---- queries (any layer) ----

    def is_failed(self, core: int) -> bool:
        return core in self._failed

    def failed_cores(self) -> list[int]:
        return list(self._failed)

    def thermal_cap(self, core_type: str) -> float:
        """Frequency ceiling for ``core_type`` (1.0 when unthrottled)."""
        return self._thermal_caps.get(core_type, 1.0)

    def thermal_caps(self) -> dict[str, float]:
        return dict(self._thermal_caps)

    def slowdown_of(self, core: int) -> float:
        """Execution-time multiplier for ``core`` (1.0 when healthy)."""
        return self._slowdowns.get(core, 1.0)

    def is_suspect(self, core: int) -> bool:
        """True when ``core``'s observed timings should not feed the
        monitor's frequency-normalized cost model (straggling cores
        lie about the workload; throttled cores are already corrected
        via the frequency term)."""
        return core in self._slowdowns

    @property
    def any_active(self) -> bool:
        return bool(self._failed or self._thermal_caps
                    or self._slowdowns or self.power_cap_w is not None)
