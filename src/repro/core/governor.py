"""The unified resource governor — one declarative spec + policy registry
driving every monitoring→prediction→policy loop in the repo.

The paper contributes a single control loop (Algorithms 1–2): a
:class:`~repro.core.monitoring.TaskMonitor` aggregates per-type workload, a
:class:`~repro.core.prediction.CPUPredictor` turns it into the optimal
resource count Δ, and a :class:`~repro.core.policies.Policy` applies Δ to
idle/resume (or lend/acquire) decisions.  Four frontends reuse that loop at
different granularities — threads (``runtime.thread_executor``), simulated
cores (``runtime.sim``), DP training replicas (``train.elastic``) and
serving replicas (``serving.autoscale``) — and before this module each
wired the stack by hand with diverging defaults.

:class:`GovernorSpec` is the single declarative description of a stack
(resource count, policy + params, prediction config, power model,
monitoring toggle), :class:`ResourceGovernor` assembles and owns the
``TaskMonitor → CPUPredictor → Policy → WorkerManager → EnergyMeter``
pipeline behind one lifecycle surface, and the string→factory **policy
registry** (:func:`register_policy`) lets new policies plug in without
touching core or any frontend.

Frontends come in two shapes, both served by the same governor:

* **push/worker-loop** (executors): workers call ``on_task_started`` /
  ``on_task_finished`` / ``on_poll_empty`` / ``on_tasks_added``, a ticker
  calls ``tick()``; pass a ``clock`` so the worker-state half
  (:class:`~repro.core.manager.WorkerManager` +
  :class:`~repro.core.energy.EnergyMeter`) is built.
* **pull/target** (autoscaler, elastic trainer): the frontend feeds monitor
  events and periodically asks ``target(queued, active)`` for the desired
  replica count; no clock needed, no worker state is built.
"""

from __future__ import annotations

import time
from dataclasses import asdict, dataclass, field, replace
from typing import Any, Callable, Mapping

from .energy import CoreState, EnergyMeter, PowerModel
from .events import QUIET_INTEREST as _QUIET
from .events import EventBus, EventKind, RuntimeEvent
from .manager import WorkerManager
from .monitoring import DEFAULT_MIN_SAMPLES, AccuracyReport, TaskMonitor
from .policies import (BusyPolicy, HeteroPredictionPolicy, HybridPolicy,
                       IdlePolicy, Policy, PollDecision, PredictionPolicy)
from .prediction import CPUPredictor, PredictionConfig
from .sharing import DLBHybridPolicy, DLBPredictionPolicy, LeWIPolicy
from .topology import CoreTopology, CoreType

__all__ = [
    "GovernorSpec",
    "GovernorReport",
    "ResourceGovernor",
    "PolicyEntry",
    "register_policy",
    "registered_policies",
    "policy_entry",
    "DEFAULT_MIN_SAMPLES",
]


# ---------------------------------------------------------------------------
# Policy registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PolicyEntry:
    """Registry record for one policy name."""

    name: str
    factory: Callable[["GovernorSpec", CPUPredictor | None], Policy]
    #: the governor must build a CPUPredictor and pass it to the factory
    needs_predictor: bool = False
    #: DLB-style resource sharing: empty polls may LEND the CPU away and
    #: the predictor runs with oversubscription allowed (paper §3.3)
    sharing: bool = False
    #: the policy plans per core type: the governor synthesizes a
    #: single-type :class:`CoreTopology` when the spec carries none
    needs_topology: bool = False
    #: for sharing policies: the registered non-sharing policy that
    #: behaves identically when the app runs alone (no co-tenants to
    #: trade CPUs with) — the arbiter runs fairness baselines under it
    solo_equivalent: str | None = None


_REGISTRY: dict[str, PolicyEntry] = {}


def register_policy(name: str, *, needs_predictor: bool = False,
                    sharing: bool = False, needs_topology: bool = False,
                    solo_equivalent: str | None = None):
    """Decorator registering ``factory(spec, predictor) -> Policy``.

    Downstream code adds policies without touching core::

        @register_policy("my-policy", needs_predictor=True)
        def _my_policy(spec, predictor):
            return MyPolicy(predictor, **spec.policy_params)
    """
    def deco(factory):
        _REGISTRY[name] = PolicyEntry(name=name, factory=factory,
                                      needs_predictor=needs_predictor,
                                      sharing=sharing,
                                      needs_topology=needs_topology,
                                      solo_equivalent=solo_equivalent)
        return factory
    return deco


def registered_policies() -> list[str]:
    """All registered policy names (sorted) — includes DLB policies."""
    return sorted(_REGISTRY)


def policy_entry(name: str) -> PolicyEntry:
    entry = _REGISTRY.get(name)
    if entry is None:
        raise ValueError(
            f"unknown policy {name!r}; registered policies: "
            + ", ".join(registered_policies()))
    return entry


# -- built-in policies (paper §2/§3.2 + §3.3) --------------------------------


@register_policy("busy")
def _busy(spec: "GovernorSpec", predictor: CPUPredictor | None) -> Policy:
    return BusyPolicy()


@register_policy("idle")
def _idle(spec: "GovernorSpec", predictor: CPUPredictor | None) -> Policy:
    return IdlePolicy()


@register_policy("hybrid")
def _hybrid(spec: "GovernorSpec", predictor: CPUPredictor | None) -> Policy:
    return HybridPolicy(spin_budget=spec.spin_budget)


@register_policy("prediction", needs_predictor=True)
def _prediction(spec: "GovernorSpec",
                predictor: CPUPredictor | None) -> Policy:
    assert predictor is not None
    return PredictionPolicy(predictor)


@register_policy("hetero-prediction", needs_predictor=True,
                 needs_topology=True)
def _hetero_prediction(spec: "GovernorSpec",
                       predictor: CPUPredictor | None) -> Policy:
    assert predictor is not None
    return HeteroPredictionPolicy(predictor)


@register_policy("dlb-lewi", sharing=True, solo_equivalent="idle")
def _dlb_lewi(spec: "GovernorSpec",
              predictor: CPUPredictor | None) -> Policy:
    return LeWIPolicy()


@register_policy("dlb-hybrid", sharing=True, solo_equivalent="hybrid")
def _dlb_hybrid(spec: "GovernorSpec",
                predictor: CPUPredictor | None) -> Policy:
    return DLBHybridPolicy(spin_budget=spec.spin_budget)


@register_policy("dlb-prediction", needs_predictor=True, sharing=True,
                 solo_equivalent="prediction")
def _dlb_prediction(spec: "GovernorSpec",
                    predictor: CPUPredictor | None) -> Policy:
    assert predictor is not None
    return DLBPredictionPolicy(predictor)


# ---------------------------------------------------------------------------
# Declarative spec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GovernorSpec:
    """Declarative description of one governor stack.

    The same spec drives every frontend: ``resources`` means worker
    threads in the executor, cores in the simulator, and replicas in the
    elastic trainer / serving autoscaler.
    """

    #: number of resources (threads / cores / replicas) owned — required,
    #: so no frontend can silently run on a forgotten default (e.g. a
    #: 1-core simulation of a 48-core machine)
    resources: int
    #: registered policy name (see :func:`registered_policies`)
    policy: str = "busy"
    #: Algorithm 1 configuration (rate f, min_samples, fallbacks).
    #: ``prediction.min_samples`` is the single source of truth for the
    #: sample-count threshold — :data:`DEFAULT_MIN_SAMPLES` (= 4)
    #: everywhere, replacing the old 4-vs-3 split between executors and
    #: the elastic/serving controllers.
    prediction: PredictionConfig = field(default_factory=PredictionConfig)
    #: consecutive empty polls before hybrid-style policies stop spinning
    spin_budget: int = 100
    #: force monitoring on/off; None ⇒ on iff the policy needs predictions
    monitoring: bool | None = None
    #: energy proxy model (None ⇒ default PowerModel)
    power: PowerModel | None = None
    #: floor for ``target()`` while load is present (autoscaler/elastic)
    min_resources: int = 0
    #: heterogeneous-core description; None ⇒ homogeneous resources
    #: (the sim injects the machine's topology for asymmetric presets)
    topology: CoreTopology | None = None
    #: which core types are trimmed first when Δ drops — "slow-first"
    #: parks the slowest types first (matches the predictor filling the
    #: fastest cores first); "fast-first" parks the fast cores first
    #: ("park the P-cores last" vs "park the E-cores last")
    park_order: str = "slow-first"
    #: co-scheduling arbiter: only borrow foreign cores whose type speed
    #: is ≥ this fraction of the app's slowest *owned* core.  The
    #: default 1.0 ("never borrow silicon slower than your own") keeps
    #: barrier-bound apps from diluting their critical path with slow
    #: cores while still letting slow-core owners borrow fast ones; it
    #: is a no-op on homogeneous machines (all speeds equal).  0.0
    #: accepts any core (pure throughput apps).
    min_borrow_speed: float = 1.0
    #: multi-node clusters: never borrow a core whose node is farther
    #: than this from the app's home node (cluster distance units).
    #: None (default) = unlimited — the effective-speed guard (the
    #: remote penalty folded into ``min_borrow_speed``) still applies.
    #: Serialized only when set, so pre-cluster spec dicts round-trip
    #: unchanged.
    max_borrow_distance: float | None = None
    #: extra kwargs for custom registered policy factories
    policy_params: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.resources < 1:
            raise ValueError("resources must be >= 1")
        if self.spin_budget < 1:
            raise ValueError("spin_budget must be >= 1")
        if not 0 <= self.min_resources <= self.resources:
            raise ValueError("min_resources must be in [0, resources]")
        if self.park_order not in ("slow-first", "fast-first"):
            raise ValueError(
                f"park_order must be 'slow-first' or 'fast-first', "
                f"got {self.park_order!r}")
        if self.min_borrow_speed < 0.0:
            raise ValueError(
                f"min_borrow_speed must be >= 0, "
                f"got {self.min_borrow_speed}")
        if (self.max_borrow_distance is not None
                and self.max_borrow_distance < 0.0):
            raise ValueError(
                f"max_borrow_distance must be >= 0, "
                f"got {self.max_borrow_distance}")
        if (self.topology is not None
                and self.topology.n_cores != self.resources):
            raise ValueError(
                f"topology has {self.topology.n_cores} cores, "
                f"but resources is {self.resources}")

    # -- serialization (configs / CLI round-trip) ---------------------------

    def to_dict(self) -> dict[str, Any]:
        d = asdict(self)
        d["policy_params"] = dict(self.policy_params)
        if self.power is None:
            d.pop("power")
        if self.topology is None:
            d.pop("topology")
        else:
            d["topology"] = self.topology.to_dict()
        if self.max_borrow_distance is None:
            d.pop("max_borrow_distance")
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "GovernorSpec":
        d = dict(d)
        if isinstance(d.get("prediction"), Mapping):
            d["prediction"] = PredictionConfig(**d["prediction"])
        if isinstance(d.get("power"), Mapping):
            d["power"] = PowerModel(**d["power"])
        if isinstance(d.get("topology"), Mapping):
            d["topology"] = CoreTopology.from_dict(d["topology"])
        return cls(**d)


# ---------------------------------------------------------------------------
# Unified report schema
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GovernorReport:
    """One metrics schema for every frontend (replaces the divergent
    ``ExecutorReport`` / ``SimReport``): benchmarks and launchers compare
    policies through these fields regardless of which stack produced them.
    Simulator-only fields (``state_seconds``, ``dlb_calls``,
    ``monitor_events``) default to empty/zero elsewhere."""

    policy: str
    makespan: float
    energy: float
    edp: float
    tasks_completed: int
    resumes: int
    idles: int
    predictions: int
    accuracy: AccuracyReport | None
    name: str = ""
    state_seconds: dict[str, float] = field(default_factory=dict)
    dlb_calls: int = 0
    monitor_events: int = 0
    #: per-core-type state seconds ({} on homogeneous stacks)
    state_seconds_by_type: dict[str, dict[str, float]] = field(
        default_factory=dict)
    #: last recommended DVFS step per core type ({} without predictions)
    freq_by_type: dict[str, float] = field(default_factory=dict)
    #: CPU-flow counters from the co-scheduling arbiter
    #: (lends/acquired/returns/reclaims; {} outside arbitrated runs)
    sharing: dict[str, int] = field(default_factory=dict)
    #: multi-node cluster runs: home node, cross-node dependency
    #: transfers charged to this app, and explicit migrations (defaults
    #: — None/0 — everywhere else, keeping single-node reports
    #: bit-identical to the pre-cluster schema)
    node: int | None = None
    transfers: int = 0
    transfer_seconds: float = 0.0
    migrations: int = 0
    #: seconds the run's aggregate power draw sat above the active
    #: power cap (0.0 on cap-free runs; ``repr=False`` keeps reports
    #: from unperturbed runs textually identical to the pre-conditions
    #: schema)
    cap_violation_s: float = field(default=0.0, repr=False)
    #: serving-robustness metrics from the :class:`SimServing` frontend
    #: (latency percentiles, per-class SLO attainment, goodput,
    #: shed/retry/hedge/degrade counts).  ``{}`` everywhere else;
    #: ``repr=False`` keeps non-serving reports textually identical to
    #: the pre-overload schema.
    serving: dict[str, Any] = field(default_factory=dict, repr=False)


# ---------------------------------------------------------------------------
# The governor facade
# ---------------------------------------------------------------------------


class ResourceGovernor:
    """Assembles and owns one monitoring→prediction→policy stack.

    Parameters
    ----------
    spec:
        The declarative description; the policy name is resolved through
        the registry at construction time.
    clock:
        Time source (wall or virtual).  When given, the worker-state half
        of the stack (:class:`WorkerManager` + :class:`EnergyMeter`) is
        built; pull-style frontends (autoscaler, elastic) omit it.
    monitor:
        Use an externally-owned :class:`TaskMonitor` (e.g. the serving
        engine feeds request events into a monitor shared with the
        autoscaler's governor) instead of building one.
    worker_ids:
        Explicit resource ids (the simulator uses global cpu ids);
        defaults to ``range(spec.resources)``.
    t0:
        Epoch for energy integration (virtual ``now`` in the simulator).
    bus:
        Runtime :class:`~repro.core.events.EventBus` shared with the
        frontend.  The governor publishes ``PREDICTION`` events on every
        tick and hands the bus to the :class:`WorkerManager` so worker
        state transitions are observable (trace recorders subscribe to
        the same bus the scheduler publishes task lifecycle events on).
    """

    def __init__(self, spec: GovernorSpec, *,
                 clock: Callable[[], float] | None = None,
                 monitor: TaskMonitor | None = None,
                 worker_ids: list[int] | None = None,
                 t0: float = 0.0,
                 bus: EventBus | None = None) -> None:
        entry = policy_entry(spec.policy)
        self.spec = spec
        self.entry = entry
        self.sharing = entry.sharing
        self.bus = bus
        self._clock = clock
        self.topology: CoreTopology | None = spec.topology
        # Synthesized topologies (hetero policies on a flat resource
        # pool) reduce to the homogeneous algorithms and must not leak
        # a made-up type name into per-type reports; explicit ones do
        # report, even single-type (e.g. a job sliced to the E-cores).
        self._topology_synthesized = (spec.topology is None
                                      and entry.needs_topology)
        if self.topology is None and entry.needs_topology:
            self.topology = CoreTopology.homogeneous(spec.resources)
        needs_monitor = entry.needs_predictor or bool(spec.monitoring)
        if monitor is not None:
            self.monitor: TaskMonitor | None = monitor
        elif needs_monitor:
            self.monitor = TaskMonitor(
                min_samples=spec.prediction.min_samples)
        else:
            self.monitor = None
        self.predictor: CPUPredictor | None = None
        if entry.needs_predictor:
            assert self.monitor is not None
            cfg = spec.prediction
            if entry.sharing and not cfg.allow_oversubscription:
                # paper §3.3: DLB-prediction runs Alg. 1 "slightly
                # modified to allow a superior number of CPUs"
                cfg = replace(cfg, allow_oversubscription=True)
            self.predictor = CPUPredictor(self.monitor,
                                          n_cpus=spec.resources, config=cfg,
                                          topology=self.topology)
        self.policy: Policy = entry.factory(spec, self.predictor)
        # DVFS bookkeeping is only live with a predictor + energy meter
        # + explicit topology; cache the verdict off the tick hot path.
        self._dvfs = (self.predictor is not None and clock is not None
                      and self.topology is not None)
        self.manager: WorkerManager | None = None
        self.energy: EnergyMeter | None = None
        self._type_of_worker: dict[int, str] = {}
        # Last applied type→step map, replaced wholesale at tick time so
        # the per-task-start frequency_of() read is lock-free.
        self._freq_cache: dict[str, float] = {}
        # Thermal frequency ceilings per core type (machine conditions);
        # empty on unperturbed stacks — apply_frequencies() clamps the
        # predictor's recommendation against these.
        self._thermal_caps: dict[str, float] = {}
        #: live machine-condition view (see :meth:`attach_conditions`);
        #: None on unperturbed stacks
        self.conditions = None
        if clock is not None:
            ids = (list(worker_ids) if worker_ids is not None
                   else list(range(spec.resources)))
            topo = self.topology
            core_type_of = None
            park_order = None
            if topo is not None:
                # positional worker→core-type mapping (the i-th owned
                # worker runs on the topology's i-th core)
                self._type_of_worker = {w: topo.type_of(i)
                                        for i, w in enumerate(ids)}
                core_type_of = self._core_type_of
                ordered = sorted(topo.types, key=lambda t: t.speed)
                if spec.park_order == "fast-first":
                    ordered = list(reversed(ordered))
                park_order = [t.name for t in ordered]
                if self.monitor is not None:
                    self.monitor.set_core_type_of(self._core_type_of,
                                                  freq_of=self.frequency_of)
            self.energy = EnergyMeter(0, spec.power, t0=t0)
            for i, w in enumerate(ids):
                ct = topo.core_type_at(i) if topo is not None else None
                self.energy.add_core(
                    w, CoreState.SPIN, t0,
                    power=(ct.power if ct is not None and ct.power
                           is not None else spec.power),
                    core_type=(ct.name if topo is not None
                               and not self._topology_synthesized
                               else ""))
            self.manager = WorkerManager(len(ids), self.policy, clock=clock,
                                         energy=self.energy, worker_ids=ids,
                                         bus=bus,
                                         core_type_of=core_type_of,
                                         park_order=park_order)
            if isinstance(self.policy, HeteroPredictionPolicy):
                self.policy.bind_topology(
                    self._core_type_of,
                    self.manager._active_by_type_locked)

    def _core_type_of(self, worker_id: int) -> str:
        ct = self._type_of_worker.get(worker_id)
        if ct is not None:
            return ct
        # Last resort for foreign CPUs never announced via
        # :meth:`adopt_worker`: map positionally through the topology
        # (global ids wrap per machine; wrong for sliced topologies,
        # which is why executors should adopt borrowed workers).
        if self.topology is not None:
            return self.topology.type_of(worker_id)
        return ""

    def adopt_worker(self, worker_id: int,
                     core_type: "CoreType | None" = None) -> None:
        """Register a foreign (borrowed) CPU with its true identity: the
        executor knows which physical core arrived, the governor does
        not.  Feeds the α_{j,c} mapping, per-type energy billing and
        DVFS-step lookup for the borrowed core."""
        mgr = self._require_manager()
        if core_type is None:
            mgr.add_worker(worker_id)
            return
        self._type_of_worker[worker_id] = core_type.name
        mgr.add_worker(
            worker_id,
            power=(core_type.power if core_type.power is not None
                   else self.spec.power),
            core_type=(core_type.name
                       if not self._topology_synthesized else ""))
        # bill the adopted core at the step its service times will use
        q = self._freq_cache.get(core_type.name)
        if q is not None and self.energy is not None \
                and self._clock is not None:
            self.energy.set_frequency(worker_id, q, self._clock())

    # -- machine conditions --------------------------------------------------

    def attach_conditions(self, conditions) -> None:
        """Install a :class:`~repro.core.conditions.MachineConditions`
        live view.  The monitor learns which workers are suspected
        stragglers (their samples skip the α EMAs); thermal and
        availability changes are pushed by the frontend through
        :meth:`apply_thermal` / :meth:`set_failed_workers` as the
        perturbations fire."""
        self.conditions = conditions
        if self.monitor is not None and conditions is not None:
            self.monitor.set_suspect_of(conditions.is_suspect)

    def apply_thermal(self, caps: Mapping[str, float],
                      now: float | None = None) -> None:
        """Install thermal frequency ceilings per core type (an empty
        mapping lifts all throttles) and rebuild the effective DVFS map:
        for each type, min(predictor's recommended step, thermal cap).
        On homogeneous stacks (no topology) the tightest cap applies to
        every worker under the ``""`` key — :meth:`frequency_of`
        resolves untyped workers through it, and a non-empty map
        disengages the simulator's flat fast path so throttling bites
        even on machines with a single nominal step."""
        self._thermal_caps = dict(caps)
        if self.energy is None or self._clock is None:
            return
        if now is None:
            now = self._clock()
        pred = (self.predictor.freq_by_type
                if self._dvfs and self.predictor is not None else {})
        eff: dict[str, float] = {}
        if self.topology is not None:
            for t in self.topology.types:
                q = min(pred.get(t.name, 1.0), caps.get(t.name, 1.0))
                if q != 1.0:
                    eff[t.name] = q
            for w, ct in self._type_of_worker.items():
                self.energy.set_frequency(w, eff.get(ct, 1.0), now)
        else:
            q = min(caps.values()) if caps else 1.0
            if q != 1.0:
                eff[""] = q
            for w in self.energy.core_ids():
                self.energy.set_frequency(w, q, now)
        self._freq_cache = eff

    def set_failed_workers(self, failed: list[int]) -> None:
        """Tell the predictor which of this governor's workers are dead
        so Δ and the hetero plan stop counting them (an empty list
        restores the all-healthy view)."""
        if self.predictor is None:
            return
        if not failed:
            self.predictor.set_availability(None)
            return
        topo = self.topology
        if topo is None:
            n_alive = max(0, self.spec.resources - len(failed))
            self.predictor.set_availability({"": n_alive})
            return
        alive = {t.name: t.count for t in topo.types}
        for w in failed:
            ct = self._core_type_of(w)
            if ct in alive and alive[ct] > 0:
                alive[ct] -= 1
        self.predictor.set_availability(alive)

    # -- push-style lifecycle (executors: Alg. 2 hooks) ----------------------

    def _require_manager(self) -> WorkerManager:
        if self.manager is None:
            raise RuntimeError(
                "this governor was built without a clock; worker-loop "
                "hooks need ResourceGovernor(spec, clock=...)")
        return self.manager

    def on_task_started(self, worker_id: int) -> None:
        self._require_manager().task_started(worker_id)

    def on_task_finished(self, worker_id: int) -> None:
        self._require_manager().task_finished(worker_id)

    def on_poll_empty(self, worker_id: int,
                      spin_count_override: int | None = None) -> PollDecision:
        return self._require_manager().poll_empty(
            worker_id, spin_count_override=spin_count_override)

    def on_tasks_added(self, ready_tasks: int) -> list[int]:
        """Tasks became ready; returns worker ids to actually wake."""
        return self._require_manager().notify_added(ready_tasks)

    def reevaluate_spinners(self) -> list[int]:
        return self._require_manager().reevaluate_spinners()

    def tick(self) -> int:
        """One prediction-rate tick; returns the fresh Δ (or the full
        resource count for non-predictive policies)."""
        self.policy.on_prediction_tick()
        if self.predictor is None:
            # Non-predictive policies tick for bookkeeping only; they
            # make no predictions, so no PREDICTION event is published
            # (keeps thread-recorded traces consistent with the
            # simulator, which only schedules ticks when the policy
            # uses predictions).
            return self.spec.resources
        if self._dvfs:
            self.apply_frequencies()
        delta = self.predictor.delta
        bus = self.bus
        if bus is not None and bus.interest != _QUIET:
            self._publish_prediction(delta)
        return delta

    def apply_frequencies(self) -> dict[str, float]:
        """Apply the predictor's recommended DVFS step per core type to
        the energy meter (no-op on homogeneous / clock-less stacks).
        Returns the applied type→step map."""
        if (self.predictor is None or self.energy is None
                or self.topology is None or self._clock is None):
            return {}
        freqs = self.predictor.freq_by_type
        if not freqs:
            return {}
        caps = self._thermal_caps
        if caps:
            # thermal ceilings win over the predictor's recommendation
            freqs = {ct: min(q, caps.get(ct, 1.0))
                     for ct, q in freqs.items()}
        now = self._clock()
        for w, ct in self._type_of_worker.items():
            q = freqs.get(ct)
            if q is not None:
                self.energy.set_frequency(w, q, now)
        self._freq_cache = freqs
        return freqs

    def frequency_of(self, worker_id: int) -> float:
        """Current DVFS step of ``worker_id`` (1.0 when un-clocked) —
        the simulator divides service times by this.  Reads the
        tick-time cache, so the per-task hot path takes no lock."""
        freqs = self._freq_cache
        if not freqs:
            return 1.0
        return freqs.get(self._core_type_of(worker_id), 1.0)

    def _publish_prediction(self, delta: int) -> None:
        if self.bus is None or not self.bus.interested(EventKind.PREDICTION):
            return
        now = (self._clock() if self._clock is not None
               else time.perf_counter())
        self.bus.publish(RuntimeEvent(
            kind=EventKind.PREDICTION, time=now, data={"delta": delta}))

    # -- pull-style surface (autoscaler / elastic) ---------------------------

    def target(self, queued: int, active: int) -> int:
        """Desired resource count for the current load, policy-decided.

        Ticks the predictor (if any), asks the policy, then clamps to
        ``[min_resources, resources]`` — the floor applies only while
        load exists, so scale-to-zero policies can return 0.
        """
        self.policy.on_prediction_tick()
        raw = self.policy.target(queued, active, self.spec.resources)
        load = queued + active
        if load <= 0 and raw <= 0:
            target = 0
        else:
            floor = self.spec.min_resources if load > 0 else 0
            target = max(floor, min(raw, self.spec.resources))
        # Pull-style frontends have no tick loop; the target decision IS
        # their prediction sample (published only for predictive
        # policies, matching the executors).
        if self.predictor is not None:
            self._publish_prediction(target)
        return target

    def live_load(self) -> int:
        """Live (ready + executing) instances known to the monitor."""
        if self.monitor is None:
            return 0
        return self.monitor.live_instances()

    # -- reporting -----------------------------------------------------------

    def finish(self, now: float) -> None:
        if self.energy is not None:
            self.energy.finish(now)

    def report(self, *, name: str = "", makespan: float | None = None,
               tasks_fallback: int = 0, dlb_calls: int = 0,
               monitor_events: int = 0,
               sharing: Mapping[str, int] | None = None,
               node: int | None = None, transfers: int = 0,
               transfer_seconds: float = 0.0,
               migrations: int = 0) -> GovernorReport:
        """Assemble the unified report (``finish()`` must have run)."""
        energy_meter = self.energy
        if energy_meter is None:
            raise RuntimeError("report() needs the energy/manager half "
                               "(build the governor with a clock)")
        manager = self._require_manager()
        if makespan is None:
            makespan = energy_meter.elapsed()
        energy = energy_meter.energy()
        return GovernorReport(
            policy=self.spec.policy,
            makespan=makespan,
            energy=energy,
            edp=energy * makespan,
            tasks_completed=(self.monitor.completed_instances()
                            if self.monitor else tasks_fallback),
            resumes=manager.resumes,
            idles=manager.idles,
            predictions=(self.predictor.predictions_made
                         if self.predictor else 0),
            accuracy=(self.monitor.accuracy_report()
                      if self.monitor else None),
            name=name,
            state_seconds={s.value: v for s, v
                           in energy_meter.state_seconds().items()},
            dlb_calls=dlb_calls,
            monitor_events=monitor_events,
            state_seconds_by_type={
                ct: {s.value: v for s, v in acc.items()}
                for ct, acc in
                energy_meter.state_seconds_by_type().items()},
            freq_by_type=(dict(self.predictor.freq_by_type)
                          if self.predictor is not None
                          and not self._topology_synthesized else {}),
            sharing=dict(sharing) if sharing else {},
            node=node,
            transfers=transfers,
            transfer_seconds=transfer_seconds,
            migrations=migrations,
            cap_violation_s=energy_meter.cap_violation_s,
        )
