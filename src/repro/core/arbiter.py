"""Cluster-level co-scheduling arbiter — N applications, one machine.

The paper's resource-sharing story (§2, §3.3, Table 3) is about
co-located runtimes trading CPUs through DLB.  This module promotes that
from a simulator-internal mode into a first-class subsystem: each
application runs its own :class:`~repro.core.governor.ResourceGovernor`
(own policy, own TaskMonitor/CPUPredictor), and the
:class:`ClusterArbiter` turns each app's prediction into an explicit
:class:`AppPlan` — how many CPUs to acquire (per core type on
heterogeneous machines), whether to fall back to a reclaim — and applies
it through the :class:`~repro.core.sharing.ResourceBroker`.

Design split:

* the arbiter *decides and accounts* (plans, per-app share statistics);
* the frontend (the simulator, via :meth:`execute`'s ``hand_cpu``
  callback) *actuates* — it owns hand-over latencies and worker wiring.

With N=2 homogeneous apps the plans reduce exactly to the decisions the
two-job ``SimCluster`` DLB path has always made (pinned by the parity
test in ``tests/test_multiapp.py``); the arbiter's additions only engage
beyond that baseline: typed acquisition on asymmetric topologies, the
broker's least-recently-served fairness with ≥3 claimants, and the
cluster-wide fairness metrics of :class:`MultiAppReport`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable, Mapping

from .governor import GovernorReport, ResourceGovernor
from .sharing import ResourceBroker
from .topology import CoreTopology

if TYPE_CHECKING:  # runtime import would be circular (runtime -> core)
    from ..runtime.cluster import ClusterModel

__all__ = [
    "AppPlan",
    "AppShareStats",
    "ClusterArbiter",
    "MultiAppReport",
    "jain_fairness",
]


@dataclass(frozen=True)
class AppPlan:
    """One arbitration decision for one application.

    ``acquire`` is the total CPU request (the paper's Δ − δ);
    ``acquire_by_type`` optionally splits it per core type, fastest
    first, on heterogeneous machines.  ``eager`` marks LeWI-style
    per-thread acquisition (one broker call per CPU).  When the grant
    comes up short and the app still has CPUs lent out,
    ``reclaim_if_short`` triggers the owner-side reclaim flag.
    """

    app: str
    acquire: int = 0
    acquire_by_type: Mapping[str, int] | None = None
    eager: bool = False
    reclaim_if_short: bool = True


@dataclass
class AppShareStats:
    """Per-app CPU-flow counters maintained by the arbiter."""

    lends: int = 0      # CPUs this app released into the broker
    acquired: int = 0   # CPUs granted to this app by acquire()
    returns: int = 0    # borrowed CPUs handed back on a reclaim flag
    reclaims: int = 0   # reclaim rounds this app initiated
    #: pooled CPUs a short grant could NOT take because the locality
    #: guard (max_borrow_distance / remote-penalty-adjusted
    #: min_borrow_speed) refused them — the borrows the guard avoided
    guard_refusals: int = 0
    migrations: int = 0  # whole-app node migrations
    #: acquires the cluster-wide power budget refused (waking one more
    #: borrowed core would have pushed the joint draw over the cap)
    power_refusals: int = 0

    def as_dict(self) -> dict[str, int]:
        d = {"lends": self.lends, "acquired": self.acquired,
             "returns": self.returns, "reclaims": self.reclaims,
             "guard_refusals": self.guard_refusals,
             "migrations": self.migrations}
        if self.power_refusals:
            # serialized only when the power budget actually refused
            # something, so cap-free reports stay bit-identical
            d["power_refusals"] = self.power_refusals
        return d


class ClusterArbiter:
    """Prediction-driven core redistribution between co-located apps.

    One arbiter per machine/broker; every registered app brings its own
    governor.  All broker verbs issued on behalf of an app go through
    the arbiter so the per-app share statistics stay complete.
    """

    def __init__(self, broker: ResourceBroker,
                 topology: CoreTopology | None = None,
                 cluster: "ClusterModel | None" = None) -> None:
        self.broker = broker
        #: the *machine's* topology (typed brokers only) — apps own
        #: sliced views of it, but the pool can hold any machine type
        self.topology = topology
        #: the locality hierarchy (multi-node runs): enables the
        #: distance/remote-penalty borrow guards and near-first grants
        self.cluster = cluster
        self._governors: dict[str, ResourceGovernor] = {}
        self.stats: dict[str, AppShareStats] = {}
        #: app -> home node (0 on single-node clusters)
        self.homes: dict[str, int] = {}
        #: cluster-wide power budget (None = uncapped; see
        #: :meth:`set_power_cap`)
        self.power_cap_w: float | None = None
        self._current_watts: Callable[[], float] | None = None
        self._core_active_w: float = 1.0

    # -- power budget --------------------------------------------------------

    def set_power_cap(self, watts: float | None,
                      current_watts: Callable[[], float] | None = None,
                      core_active_w: float = 1.0) -> None:
        """Install (or lift) a cluster-wide power budget.

        The budget is a *shared* resource: before granting an acquire,
        :meth:`execute` checks that waking the requested cores — each
        estimated at ``core_active_w`` — still fits under the cap given
        the frontend-supplied ``current_watts()`` (the sum of every
        app's live meter draw).  Requests the budget cannot fit are
        trimmed and counted in the app's
        :attr:`AppShareStats.power_refusals`.
        """
        self.power_cap_w = watts
        if current_watts is not None:
            self._current_watts = current_watts
        if core_active_w > 0.0:
            self._core_active_w = core_active_w

    def _power_allowance(self, n_req: int) -> tuple[int, int]:
        """Clamp an ``n_req``-core acquire to the power headroom;
        returns ``(granted_budget, refused)``."""
        if (self.power_cap_w is None or self._current_watts is None
                or n_req <= 0):
            return n_req, 0
        headroom = self.power_cap_w - self._current_watts()
        allow = max(0, int(headroom / self._core_active_w + 1e-9))
        if allow >= n_req:
            return n_req, 0
        return allow, n_req - allow

    # -- registration --------------------------------------------------------

    def register(self, name: str, governor: ResourceGovernor,
                 node: int = 0) -> None:
        self._governors[name] = governor
        self.stats[name] = AppShareStats()
        self.homes[name] = node

    def note_migration(self, name: str, node: int) -> None:
        """The frontend migrated ``name`` to ``node``: update the home
        used by the locality guards and count the verb."""
        self.homes[name] = node
        self.stats[name].migrations += 1

    def apps(self) -> list[str]:
        return list(self._governors)

    def governor(self, name: str) -> ResourceGovernor:
        return self._governors[name]

    # -- placement -----------------------------------------------------------

    @staticmethod
    def place(demands: Mapping[str, float], capacities: list[float],
              policy: str = "predicted") -> dict[str, int]:
        """Whole-app → node placement.

        ``demands`` maps each app to its predicted CPU demand (each
        app's own predictor's estimate — see
        :func:`~repro.runtime.multiapp.predicted_demand`);
        ``capacities`` is per-node core capacity.

        * ``"round-robin"`` — app *i* (submission order) → node
          ``i % n``, blind to demand;
        * ``"predicted"`` — best-fit decreasing: heaviest app first onto
          the node with the most remaining capacity, so one node is not
          left running two heavy apps while another hosts two light
          ones.  Ties break toward the lower node id (deterministic).
        """
        n = len(capacities)
        if n == 0:
            raise ValueError("need at least one node")
        if policy == "round-robin":
            return {name: i % n for i, name in enumerate(demands)}
        if policy != "predicted":
            raise ValueError(f"unknown placement policy {policy!r}")
        remaining = list(capacities)
        out: dict[str, int] = {}
        order = sorted(demands, key=lambda a: (-demands[a], a))
        for name in order:
            node = max(range(n), key=lambda k: (remaining[k], -k))
            out[name] = node
            remaining[node] -= demands[name]
        return out

    # -- planning ------------------------------------------------------------

    def plan_tick(self, name: str, active: int,
                  ready_tasks: int) -> AppPlan | None:
        """Prediction-tick acquisition plan (centralized policies).

        One broker call per tick requests Δ − δ CPUs (paper §3.3); the
        free-CPU peek is a cheap shared-memory read, not a DLB call, so
        a plan is only emitted when the broker could plausibly deliver.
        Returns ``None`` when this app makes no request this tick.
        """
        gov = self._governors[name]
        policy = gov.policy
        if not gov.sharing or getattr(policy, "eager_acquire", True):
            return None
        target = policy.acquire_target(active, ready_tasks)
        if target <= 0:
            # demand evaporated: drop any stale fairness reservation so
            # pooled CPUs are not parked for an app that no longer asks
            self.broker.register_demand(name, 0)
            return None
        if (self.broker.pool_size() == 0
                and self.broker.lent_out(name) == 0):
            # Nothing to get — but a starved claimant must still record
            # its unmet demand (shared-memory write, not a DLB call), or
            # the least-recently-served reservation could never engage
            # for an app whose tick always fires after the pool drains.
            self.broker.register_demand(name, target)
            return None
        return AppPlan(app=name, acquire=target,
                       acquire_by_type=self._typed_targets(gov, target))

    def plan_work_added(self, name: str, active: int,
                        ready_tasks: int) -> AppPlan | None:
        """Work-arrival plan for eager (LeWI-style) policies: one broker
        call per requested CPU, no peek — the call overhead IS the cost
        the paper's Table 3 measures."""
        gov = self._governors[name]
        policy = gov.policy
        if not gov.sharing or not getattr(policy, "eager_acquire", False):
            return None
        target = policy.acquire_target(active, ready_tasks)
        if target <= 0:
            return None
        return AppPlan(app=name, acquire=target, eager=True)

    def _typed_targets(self, gov: ResourceGovernor,
                       target: int) -> dict[str, int] | None:
        """Per-core-type request split, fastest types first.

        Engages only when both sides speak types (typed broker + a
        predictor with a per-type plan) — homogeneous clusters keep the
        scalar path bit-for-bit.  The split covers the app's own typed
        demand Δ_c − δ_c; :meth:`execute` tops up any remainder with an
        untyped request, because in oversubscription mode surplus from a
        *different* core type is still surplus (a P-only app must be
        able to borrow pooled E-cores).
        """
        if not self.broker.typed or gov.predictor is None:
            return None
        by_type = gov.predictor.delta_by_type
        if not by_type or gov.topology is None:
            return None
        active_by_type = (gov.manager.active_by_type()
                          if gov.manager is not None else {})
        out: dict[str, int] = {}
        for ct in gov.topology.fastest_first():
            want = by_type.get(ct.name, 0) - active_by_type.get(ct.name, 0)
            if want > 0:
                out[ct.name] = want
        return out or None

    # -- locality guard ------------------------------------------------------

    def _locality_filter(self, name: str) -> tuple[
            Callable[[int], bool] | None, Callable[[int], float] | None]:
        """The ``(where, prefer)`` pair for ``name``'s broker acquires
        on a multi-node cluster — ``(None, None)`` on ≤1 node, keeping
        single-node pool order bit-for-bit.

        ``where`` refuses a foreign CPU when its node is farther than
        the spec's ``max_borrow_distance``, or when its *effective*
        speed for this app — own-node speed divided by the remote
        penalty — falls below ``min_borrow_speed`` × the app's slowest
        owned core (the same guard :meth:`_borrowable_types` applies by
        type, extended with the distance dilation: remote silicon that
        looks fast on paper can still be a losing borrow once the
        penalty is charged).  ``prefer`` sorts grants nearest-first.
        """
        cm = self.cluster
        if cm is None or cm.n_nodes <= 1:
            return None, None
        home = self.homes.get(name, 0)
        gov = self._governors[name]
        max_d = gov.spec.max_borrow_distance
        own = gov.topology
        home_m = cm.nodes[home]
        own_slowest = (min(t.speed for t in own.types) * home_m.core_speed
                       if own is not None else home_m.core_speed)
        floor = gov.spec.min_borrow_speed * own_slowest

        def where(cpu: int) -> bool:
            node = cm.node_of(cpu)
            if node == home:
                return True
            if max_d is not None and cm.distance[home][node] > max_d + 1e-12:
                return False
            eff = cm.speed_of(cpu) / cm.penalty(home, node)
            return eff >= floor - 1e-12

        def prefer(cpu: int) -> float:
            return cm.distance[home][cm.node_of(cpu)]

        return where, prefer

    # -- actuation -----------------------------------------------------------

    def execute(self, plan: AppPlan,
                hand_cpu: Callable[[int], None]) -> list[int]:
        """Apply ``plan`` against the broker; every granted CPU is
        delivered through ``hand_cpu`` (the frontend owns hand-over
        latency and worker adoption).  Returns the CPUs acquired (a
        reclaim's immediate returns are handed over but not listed)."""
        name = plan.app
        stats = self.stats[name]
        where, prefer = self._locality_filter(name)
        got: list[int] = []
        #: the classic paths reclaim *after* a short grant; the hetero
        #: path reclaims mid-flight (fast own silicon before slow
        #: foreign) so it opts out of the shared tail reclaim
        tail_reclaim = True
        # Cluster power budget: trim the request to what the joint draw
        # can absorb (no-op while no cap is installed).
        n_want, refused = self._power_allowance(plan.acquire)
        if refused:
            stats.power_refusals += refused
        if plan.eager:
            # LeWI-style: one broker call per CPU (per-thread acquisition).
            for _ in range(n_want):
                batch = self.broker.acquire(name, 1, where=where,
                                            prefer=prefer)
                if not batch:
                    break
                got.extend(batch)
        elif plan.acquire_by_type is None:
            got = self.broker.acquire(name, n_want, where=where,
                                      prefer=prefer) if n_want > 0 else []
        else:
            tail_reclaim = False
            # Heterogeneous path.  1) Own-type deficits first (fastest
            # types first, cheap typed peek gates each DLB call).
            want = n_want
            for ct, n in plan.acquire_by_type.items():
                if want <= 0:
                    break
                if self.broker.pool_size(ct) == 0:
                    continue
                batch = self.broker.acquire(name, min(n, want),
                                            core_type=ct, where=where,
                                            prefer=prefer)
                got.extend(batch)
                want -= len(batch)
            # 2) Reclaim our own (fast) silicon before borrowing foreign
            #    cores — and never re-issue a reclaim while the previous
            #    one still has return flags pending (each re-issue would
            #    be a paid DLB call that sets no new flag).
            if want > 0 and plan.reclaim_if_short:
                lent = self.broker.lent_out(name)
                if lent > 0:
                    if not self.broker.reclaim_pending(name):
                        stats.reclaims += 1
                        for cpu in self.broker.reclaim(name):
                            hand_cpu(cpu)
                    want -= lent   # own cores are on their way back
            # 3) Foreign top-up under the speed guard: never borrow
            #    silicon slower than min_borrow_speed × the app's
            #    slowest owned core (a barrier-bound app on P-cores must
            #    not dilate its critical path with E-core stragglers).
            if want > 0:
                for ct in self._borrowable_types(name):
                    if want <= 0:
                        break
                    if self.broker.pool_size(ct) == 0:
                        continue
                    batch = self.broker.acquire(name, want, core_type=ct,
                                                where=where, prefer=prefer)
                    got.extend(batch)
                    want -= len(batch)
            # typed acquires each overwrote the fairness counter with
            # their own shortfall; record the plan-level one
            self.broker.register_demand(name, want if want > 0 else 0)
        stats.acquired += len(got)
        if where is not None and len(got) < n_want:
            # A short locality-guarded grant: attribute up to the
            # shortfall to pooled CPUs the guard refused (vs. a
            # genuinely empty pool) — the borrows the guard avoided.
            stats.guard_refusals += min(n_want - len(got),
                                        self.broker.pool_rejected(where))
        for cpu in got:
            hand_cpu(cpu)
        if (tail_reclaim and len(got) < n_want
                and plan.reclaim_if_short
                and self.broker.lent_out(name) > 0):
            # Pool exhausted but our own CPUs are borrowed: flag a reclaim.
            stats.reclaims += 1
            for cpu in self.broker.reclaim(name):
                hand_cpu(cpu)
        return got

    def _borrowable_types(self, name: str) -> list[str]:
        """Machine core types ``name`` may borrow, fastest first, under
        its spec's ``min_borrow_speed`` guard (all types when the
        machine topology is unknown).  On a multi-node cluster with no
        single machine topology, the candidate set is the union of the
        node topologies (first occurrence wins per name — mixed-node
        clusters reuse type names only for identical silicon)."""
        gov = self._governors[name]
        if self.topology is not None:
            order = [t for t in self.topology.fastest_first()]
        elif self.cluster is not None:
            seen: dict[str, object] = {}
            for m in self.cluster.nodes:
                for t in m.topology().types:
                    seen.setdefault(t.name, t)
            order = sorted(seen.values(),
                           key=lambda t: (-t.speed, t.socket))
        else:
            return []
        own = gov.topology
        if own is None:
            return [t.name for t in order]
        floor = gov.spec.min_borrow_speed * min(t.speed for t in own.types)
        return [t.name for t in order if t.speed >= floor - 1e-12]

    # -- broker verbs (stat-keeping wrappers) --------------------------------

    def lend(self, name: str, cpu: int) -> str:
        """App releases ``cpu`` into the pool; returns the new holder
        (the owner on a pending reclaim hand-over, else "")."""
        self.stats[name].lends += 1
        return self.broker.lend(name, cpu)

    def return_cpu(self, name: str, cpu: int) -> str:
        """Borrower honors a reclaim flag at a task boundary; returns
        the owner's name."""
        self.stats[name].returns += 1
        return self.broker.return_cpu(name, cpu)

    # -- introspection -------------------------------------------------------

    def snapshot(self) -> dict[str, dict[str, int]]:
        """Cluster-wide view: per-app Δ, held CPUs, broker calls and
        share-flow counters (for dashboards/tests)."""
        out: dict[str, dict[str, int]] = {}
        for name, gov in self._governors.items():
            row = dict(self.stats[name].as_dict())
            row["calls"] = self.broker.job_calls(name)
            row["delta"] = (gov.predictor.delta
                            if gov.predictor is not None else 0)
            row["active"] = (gov.manager.active
                             if gov.manager is not None else 0)
            out[name] = row
        return out


# ---------------------------------------------------------------------------
# Cluster-level reporting
# ---------------------------------------------------------------------------


def jain_fairness(values: Mapping[str, float]) -> float:
    """Jain's fairness index over per-app values (1.0 = perfectly fair,
    1/N = one app gets everything).  Empty input ⇒ 1.0."""
    xs = [v for v in values.values() if v > 0]
    if not xs:
        return 1.0
    s = sum(xs)
    s2 = sum(x * x for x in xs)
    return (s * s) / (len(xs) * s2) if s2 > 0 else 1.0


@dataclass(frozen=True)
class MultiAppReport:
    """Aggregate + fairness metrics for one co-scheduled run.

    ``slowdown[app]`` is co-scheduled makespan / solo makespan on the
    same CPU partition (< 1.0 means the app *gained* from borrowing);
    ``fairness`` is Jain's index over per-app speedups (1/slowdown).
    ``aggregate_edp`` is Σ_app energy × cluster makespan — the
    cluster-operator's single-number cost of the co-schedule.
    """

    apps: dict[str, GovernorReport]
    makespan: float
    aggregate_energy: float
    aggregate_edp: float
    total_dlb_calls: int
    solo: dict[str, GovernorReport] = field(default_factory=dict)
    slowdown: dict[str, float] = field(default_factory=dict)
    fairness: float = 1.0
    #: app -> home node for multi-node runs (empty on one box)
    placement: dict[str, int] = field(default_factory=dict)

    @classmethod
    def build(cls, apps: Mapping[str, GovernorReport],
              total_dlb_calls: int,
              solo: Mapping[str, GovernorReport] | None = None,
              placement: Mapping[str, int] | None = None,
              ) -> "MultiAppReport":
        makespan = max((r.makespan for r in apps.values()), default=0.0)
        energy = sum(r.energy for r in apps.values())
        slowdown: dict[str, float] = {}
        if solo:
            for name, rep in apps.items():
                base = solo.get(name)
                if base is not None and base.makespan > 0:
                    slowdown[name] = rep.makespan / base.makespan
        speedups = {n: 1.0 / s for n, s in slowdown.items() if s > 0}
        return cls(
            apps=dict(apps),
            makespan=makespan,
            aggregate_energy=energy,
            aggregate_edp=energy * makespan,
            total_dlb_calls=total_dlb_calls,
            solo=dict(solo) if solo else {},
            slowdown=slowdown,
            fairness=jain_fairness(speedups),
            placement=dict(placement) if placement else {},
        )
