"""Algorithm 1 — predicting the optimal CPU utilization ``Δ`` (paper §3.1).

Given the per-type live workload ``W_{ready,j} + W_{exec,j}`` (in cost
units), the unitary costs ``α_j`` (seconds per cost unit) and the prediction
rate ``f`` (seconds between predictions), accumulate

    γ ← Σ_j (W_{ready,j} + W_{exec,j}) · α_j / f

over task types, early-exiting once ``γ ≥ N_CPUs`` (the paper's
``while (γ < N_CPUs)`` loop), then

    Δ = min(⌈γ⌉, Σ_j M_j)   with   0 < Δ ≤ N_CPUs.

Types whose ``α_j`` is not yet reliable contribute their *instance count*
instead — the paper's fallback "when task timing predictions are not
available, CPU utilization predictions are based only on the number of
available tasks" (used throughout for coarse-grained Cholesky).

Heterogeneous machines (a :class:`~repro.core.topology.CoreTopology` on
the predictor) generalize Δ to a per-core-type split Δ_c plus a
recommended DVFS step per type (:meth:`CPUPredictor.compute_plan`):

* the live workload is normalized to *unit-speed seconds* through the
  per-(task-type × core-type) costs α_{j,c} (each already bakes in its
  core's speed, so α_base = α_{j,c} · speed_c);
* demand fills the **fastest cores first**; count-based fallback
  instances occupy one core each, also fastest-first;
* per core type, the recommended frequency step minimizes the modeled
  EDP ``P_active(q) / q²`` among steps that still cover the predicted
  utilization (never below ``PredictionConfig.freq_floor``, the
  critical-path dilation guard), falling back to the count-based
  maximum step whenever unknown-duration work is assigned to the type.

With a single core type at speed 1.0 and one frequency step, the plan's
total Δ reproduces the homogeneous Algorithm 1 value exactly.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Mapping

from ..analysis import single_writer
from .energy import CoreState, PowerModel
from .monitoring import DEFAULT_MIN_SAMPLES, TaskMonitor
from .topology import CoreTopology

__all__ = ["PredictionConfig", "CPUPredictor", "HeteroPlan"]

#: Paper §5: "Throughout the whole evaluation we used the same prediction
#: rate — f in Algorithm 1 — of 50 µs."
DEFAULT_PREDICTION_RATE_S = 50e-6


@dataclass(frozen=True)
class PredictionConfig:
    rate_s: float = DEFAULT_PREDICTION_RATE_S
    #: below this many completed samples a type's α_j is not trusted
    #: (one repo-wide default — see monitoring.DEFAULT_MIN_SAMPLES)
    min_samples: int = DEFAULT_MIN_SAMPLES
    #: force the count-based fallback for *all* types (coarse-grained mode)
    count_based_only: bool = False
    #: allow Δ above the locally-owned CPUs (used by the DLB-prediction
    #: sharing policy, which may acquire external CPUs — paper §3.3:
    #: "slightly modified to allow a superior number of CPUs")
    allow_oversubscription: bool = False
    #: cap on Δ in oversubscription mode, as a multiple of owned CPUs
    #: (a DLB deployment cannot hold more than the machine's cores; we
    #: default to the two-NUMA-node arrangement of the paper's Table 3)
    oversubscription_cap: float = 2.0
    #: lowest DVFS step the hetero plan may recommend — bounds worst-case
    #: critical-path dilation to 1/freq_floor; 1.0 disables re-clocking
    freq_floor: float = 0.75
    #: capacity headroom required before a type is stretched to a lower
    #: step (demand may exceed the prediction; 1.0 = no margin)
    freq_margin: float = 1.25

    def __post_init__(self) -> None:
        if self.rate_s <= 0:
            raise ValueError(f"rate_s must be > 0, got {self.rate_s}")
        if self.min_samples < 1:
            raise ValueError(
                f"min_samples must be >= 1, got {self.min_samples}")
        if self.oversubscription_cap < 1.0:
            raise ValueError("oversubscription_cap must be >= 1.0")
        if not 0.0 < self.freq_floor <= 1.0:
            raise ValueError(
                f"freq_floor must be in (0, 1], got {self.freq_floor}")
        if self.freq_margin < 1.0:
            raise ValueError("freq_margin must be >= 1.0")


@dataclass(frozen=True)
class HeteroPlan:
    """One heterogeneous prediction: total Δ, its per-core-type split and
    the recommended DVFS step per type."""

    delta: int
    by_type: Mapping[str, int] = field(default_factory=dict)
    freq: Mapping[str, float] = field(default_factory=dict)


@single_writer("_delta", "_plan", "_memo_version", "_memo_valid",
               "predictions_made", "_alive_by_type", "_n_alive")
class CPUPredictor:
    """Computes and caches ``Δ``; thread-safe.

    The executor (real or simulated) calls :meth:`tick` every ``rate_s``
    seconds; policies read :attr:`delta` (the paper stores Δ in an atomic
    variable read by the CPU manager, Alg. 2).  With a ``topology``,
    :meth:`tick` runs the heterogeneous plan and policies may also read
    :attr:`delta_by_type` / :attr:`freq_by_type`.
    """

    def __init__(self, monitor: TaskMonitor, n_cpus: int,
                 config: PredictionConfig | None = None,
                 topology: CoreTopology | None = None) -> None:
        if n_cpus <= 0:
            raise ValueError("n_cpus must be positive")
        if topology is not None and topology.n_cores != n_cpus:
            raise ValueError(
                f"topology has {topology.n_cores} cores, "
                f"but n_cpus is {n_cpus}")
        self.monitor = monitor
        self.n_cpus = n_cpus
        self.config = config or PredictionConfig()
        self.topology = topology
        self._delta = n_cpus  # optimistic start: all CPUs
        self._plan: HeteroPlan | None = None
        if topology is not None:
            self._plan = HeteroPlan(
                delta=n_cpus,
                by_type={t.name: t.count for t in topology.types},
                freq={t.name: t.max_freq for t in topology.types})
        self.predictions_made = 0
        # tick() memo: last monitor version the delta/plan was computed
        # against (-1 ⇒ never computed).
        self._memo_version = -1
        self._memo_valid = False
        # Core availability under dynamic machine conditions: None ⇒
        # every core healthy (the pre-conditions fast path — zero
        # lookups anywhere below).  Set by the governor when cores fail
        # or recover; dead cores drop out of Δ and the hetero plan.
        self._alive_by_type: dict[str, int] | None = None
        self._n_alive: int | None = None

    def set_availability(self, alive_by_type: dict[str, int] | None,
                         ) -> None:
        """Install the per-core-type count of *alive* cores (None
        restores the all-healthy default).  Invalidates the tick memo:
        the plan is no longer a pure function of the monitor snapshot
        alone."""
        self._alive_by_type = (dict(alive_by_type)
                               if alive_by_type is not None else None)
        self._n_alive = (sum(alive_by_type.values())
                         if alive_by_type is not None else None)
        self._memo_version = -1
        self._memo_valid = False

    def _alive(self, type_name: str, count: int) -> int:
        """Alive cores of ``type_name`` (``count`` when unconditioned)."""
        a = self._alive_by_type
        if a is None:
            return count
        return a.get(type_name, count)

    # -- Algorithm 1 ---------------------------------------------------------

    def compute_delta(self, n_cpus: int | None = None) -> int:
        """One evaluation of Algorithm 1 against the monitor's workload
        aggregates (fused single pass — see
        :meth:`~repro.core.monitoring.TaskMonitor.fold_gamma`; the
        early-exit bound is the paper's ``while (γ < N_CPUs)``)."""
        cfg = self.config
        n = self.n_cpus if n_cpus is None else n_cpus
        if self._n_alive is not None and self._n_alive < n:
            n = max(1, self._n_alive)
        gamma, total_instances = self.monitor.fold_gamma(
            cfg.min_samples, cfg.rate_s, cfg.count_based_only,
            limit=None if cfg.allow_oversubscription else n)
        if total_instances == 0:
            # No live work: keep one CPU awake to pick up new work
            # (Alg. 1 ensures 0 < Δ).
            return 1
        delta = min(math.ceil(gamma), total_instances)
        if cfg.allow_oversubscription:
            delta = min(delta, int(cfg.oversubscription_cap * n))
        else:
            delta = min(delta, n)
        return max(1, delta)

    # -- heterogeneous Algorithm 1 -------------------------------------------

    def compute_plan(self) -> HeteroPlan:
        """Per-core-type Δ_c (fastest cores first) + frequency steps."""
        topo = self.topology
        if topo is None:
            raise RuntimeError("compute_plan() needs a CoreTopology")
        cfg = self.config
        order = topo.fastest_first()
        max_freqs = {t.name: t.max_freq for t in topo.types}
        # Alive cores per type: identical to the nominal counts unless
        # set_availability() installed a failure view (then dead cores
        # vanish from every width/cap below).
        alive = {t.name: self._alive(t.name, t.count) for t in topo.types}

        # 1. Normalize the live workload to unit-speed seconds (γ's
        #    numerator) + the count-based fallback instance pool.
        demand = 0.0          # unit-speed core equivalents over one window
        fallback = 0          # instances predicted by count, one core each
        total_instances = 0
        mean_speed = topo.mean_speed()
        speeds = {t.name: t.speed for t in topo.types}
        for snap in self.monitor.workload_snapshot_hetero(cfg.min_samples):
            total_instances += snap.live_instances
            if cfg.count_based_only:
                fallback += snap.live_instances
                continue
            per_core = [(c, a, n_s) for c, (a, n_s, ok)
                        in snap.alpha_by_core.items()
                        if ok and c in speeds]
            if per_core:
                # α_{j,c} bakes in core speed; normalize each to
                # unit-speed and blend by sample count.
                num = sum(a * speeds[c] * n_s for c, a, n_s in per_core)
                den = sum(n_s for _, _, n_s in per_core)
                alpha_u = num / den
            elif snap.reliable:
                # aggregate α mixes whatever cores ran the samples;
                # first-order correction by the capacity-mean speed
                alpha_u = snap.alpha * mean_speed
            else:
                fallback += snap.live_instances
                continue
            demand += (snap.live_cost * alpha_u) / cfg.rate_s

        # fastest type that still has an alive core (order[0] when all
        # healthy — bit-identical to the pre-conditions choice)
        fastest_alive = order[0].name
        for ct in order:
            if alive[ct.name] > 0:
                fastest_alive = ct.name
                break

        if total_instances == 0:
            # keep one (fastest alive) core awake to pick up new work
            return HeteroPlan(delta=1, by_type={fastest_alive: 1},
                              freq=max_freqs)

        # 2. Fill fastest cores first: fractional per-type allocation for
        #    the timed demand, then one core per count-fallback instance.
        frac: dict[str, float] = {}
        timed_frac: dict[str, float] = {}
        remaining = demand
        fb = float(fallback)
        for ct in order:
            cap_per_core = ct.speed * ct.max_freq
            n_c = alive[ct.name]
            x = 0.0
            if remaining > 1e-12:
                x = min(float(n_c), remaining / cap_per_core)
                remaining -= x * cap_per_core
            timed_frac[ct.name] = x
            if x < n_c and fb > 0:
                y = min(n_c - x, fb)
                x += y
                fb -= y
            frac[ct.name] = x
        if cfg.allow_oversubscription and (remaining > 0 or fb > 0):
            # DLB mode may hold more CPUs than owned (paper §3.3); park
            # the overflow on the slowest type and let the cap clamp it.
            last = order[-1]
            overflow = remaining / (last.speed * last.max_freq) + fb
            timed_frac[last.name] += remaining / (last.speed
                                                  * last.max_freq)
            frac[last.name] += overflow

        # 3. Integerize so Σ Δ_c == ⌈Σ frac_c⌉ (exact homogeneous parity):
        #    cumulative ceiling, fastest types first.
        by_type: dict[str, int] = {}
        cum = 0.0
        alloc_total = 0
        for ct in order:
            cum += frac[ct.name]
            # plain ceil, exactly like the homogeneous ⌈γ⌉ (parity)
            take = max(0, math.ceil(cum) - alloc_total)
            if not cfg.allow_oversubscription:
                take = min(take, alive[ct.name])
            by_type[ct.name] = take
            alloc_total += take

        # 4. Caps (mirrors the homogeneous path): live instances, owned
        #    cores / oversubscription budget, and Δ ≥ 1.
        n_owned = (self._n_alive if self._n_alive is not None
                   else self.n_cpus)
        cap = (int(cfg.oversubscription_cap * self.n_cpus)
               if cfg.allow_oversubscription else max(1, n_owned))
        target = max(1, min(alloc_total, total_instances, cap))
        # trim surplus from the slowest allocated types first
        for ct in reversed(order):
            if alloc_total <= target:
                break
            give = min(by_type[ct.name], alloc_total - target)
            by_type[ct.name] -= give
            alloc_total -= give
        if alloc_total < target:   # all-zero after caps: wake the fastest
            by_type[fastest_alive] += target - alloc_total
            alloc_total = target

        # 4b. Fast-core reserve (speed-asymmetric topologies only): keep
        #     the fastest type fully awake while live work exists.  A
        #     parked P-core loses the instant-dispatch race to a spinning
        #     E-core, putting critical-path tasks on the slow silicon —
        #     the big.LITTLE rule is the opposite: big cores stay
        #     available for latency, little cores carry throughput and
        #     park aggressively.  Spinning ≠ executing, so the reserve
        #     ignores the instance cap; the slow types still deliver the
        #     energy savings.  (A single-speed topology takes this branch
        #     never — exact homogeneous parity.)
        reserved: str | None = None
        fastest = order[0]
        if (fastest.speed > min(t.speed for t in topo.types)
                and alive[fastest.name] > 0):
            reserved = fastest.name
            boost = alive[fastest.name] - by_type[fastest.name]
            if boost > 0:
                by_type[fastest.name] = alive[fastest.name]
                alloc_total += boost

        # 5. Frequency recommendation per type — stretch-to-fit: running
        #    *more* cores at a *lower* step preserves throughput while
        #    cutting the modeled EDP (P_active(q)/q², cubic dynamic
        #    power).  A step is feasible only when the widened core set
        #    (with ``freq_margin`` headroom) fits the type and the spare
        #    instance budget, and is never below ``freq_floor`` — both
        #    are the makespan guards.  Count-based (unknown-duration)
        #    work pins the type at its maximum step.
        budget = min(cap, total_instances) - alloc_total
        freq: dict[str, float] = {}
        for ct in order:
            granted = by_type[ct.name]
            steps = ct.freq_steps
            if (len(steps) == 1 or granted == 0
                    or ct.name == reserved   # reserve = full latency
                    or timed_frac[ct.name] <= 0.0
                    or frac[ct.name] > timed_frac[ct.name] + 1e-12):
                freq[ct.name] = ct.max_freq
                continue
            # demand on this type, in cores-at-max-step
            demand_c = timed_frac[ct.name] * ct.max_freq
            max_width = min(alive[ct.name], granted + budget)
            pm = ct.power or PowerModel()
            best_q = ct.max_freq
            best_width = granted
            best_edp = (pm.power(CoreState.ACTIVE, ct.max_freq)
                        / (ct.max_freq * ct.max_freq))
            for q in steps:
                if q < cfg.freq_floor or q >= ct.max_freq:
                    continue
                width = math.ceil(demand_c * cfg.freq_margin / q)
                if width > max_width:
                    continue   # cannot keep throughput at this step
                edp = pm.power(CoreState.ACTIVE, q) / (q * q)
                if edp < best_edp - 1e-12:
                    best_q, best_width, best_edp = q, width, edp
            freq[ct.name] = best_q
            if best_width > granted:
                budget -= best_width - granted
                alloc_total += best_width - granted
                by_type[ct.name] = best_width
        return HeteroPlan(delta=alloc_total, by_type=by_type, freq=freq)

    # -- atomic Δ (read by Alg. 2) --------------------------------------------

    def tick(self) -> int:
        """Recompute Δ (called at the prediction rate) and publish it.

        Memoized on the monitor's mutation version: Algorithm 1 is a
        pure function of the workload snapshot, so a tick that fires
        with no monitor change since the last one (an idle or spin-only
        window) reuses the previous Δ/plan instead of re-walking the
        snapshot — numerically identical, since recomputing over the
        same inputs returns the same result.
        """
        # Single-writer discipline: tick() is only ever called from one
        # thread (the sim loop / the executor's ticker), and the
        # int/reference stores below are atomic for readers — no lock.
        version = self.monitor.version
        if self.topology is not None:
            plan = self._plan
            if version != self._memo_version or plan is None:
                plan = self.compute_plan()
                self._memo_version = version
                self._plan = plan
                self._delta = plan.delta
            self.predictions_made += 1
            return plan.delta
        delta = self._delta
        if version != self._memo_version or not self._memo_valid:
            delta = self.compute_delta()
            self._memo_version = version
            self._memo_valid = True
            self._delta = delta
        self.predictions_made += 1
        return delta

    @property
    def delta(self) -> int:
        # Lock-free read: Δ is the paper's "atomic" — it is read on
        # every empty poll, and a plain int load is atomic in CPython.
        return self._delta

    @property
    def plan(self) -> HeteroPlan | None:
        return self._plan

    @property
    def delta_by_type(self) -> dict[str, int]:
        """Per-core-type Δ_c split ({} without a topology).  The live
        plan dict — read-only for callers (it is replaced wholesale, not
        mutated, on each tick)."""
        plan = self._plan
        return plan.by_type if plan else {}

    @property
    def freq_by_type(self) -> dict[str, float]:
        """Recommended DVFS step per core type ({} without a topology).
        Read-only view, same contract as :attr:`delta_by_type`."""
        plan = self._plan
        return plan.freq if plan else {}
