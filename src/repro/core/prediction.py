"""Algorithm 1 — predicting the optimal CPU utilization ``Δ`` (paper §3.1).

Given the per-type live workload ``W_{ready,j} + W_{exec,j}`` (in cost
units), the unitary costs ``α_j`` (seconds per cost unit) and the prediction
rate ``f`` (seconds between predictions), accumulate

    γ ← Σ_j (W_{ready,j} + W_{exec,j}) · α_j / f

over task types, early-exiting once ``γ ≥ N_CPUs`` (the paper's
``while (γ < N_CPUs)`` loop), then

    Δ = min(⌈γ⌉, Σ_j M_j)   with   0 < Δ ≤ N_CPUs.

Types whose ``α_j`` is not yet reliable contribute their *instance count*
instead — the paper's fallback "when task timing predictions are not
available, CPU utilization predictions are based only on the number of
available tasks" (used throughout for coarse-grained Cholesky).
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass

from .monitoring import DEFAULT_MIN_SAMPLES, TaskMonitor

__all__ = ["PredictionConfig", "CPUPredictor"]

#: Paper §5: "Throughout the whole evaluation we used the same prediction
#: rate — f in Algorithm 1 — of 50 µs."
DEFAULT_PREDICTION_RATE_S = 50e-6


@dataclass(frozen=True)
class PredictionConfig:
    rate_s: float = DEFAULT_PREDICTION_RATE_S
    #: below this many completed samples a type's α_j is not trusted
    #: (one repo-wide default — see monitoring.DEFAULT_MIN_SAMPLES)
    min_samples: int = DEFAULT_MIN_SAMPLES
    #: force the count-based fallback for *all* types (coarse-grained mode)
    count_based_only: bool = False
    #: allow Δ above the locally-owned CPUs (used by the DLB-prediction
    #: sharing policy, which may acquire external CPUs — paper §3.3:
    #: "slightly modified to allow a superior number of CPUs")
    allow_oversubscription: bool = False
    #: cap on Δ in oversubscription mode, as a multiple of owned CPUs
    #: (a DLB deployment cannot hold more than the machine's cores; we
    #: default to the two-NUMA-node arrangement of the paper's Table 3)
    oversubscription_cap: float = 2.0

    def __post_init__(self) -> None:
        if self.rate_s <= 0:
            raise ValueError(f"rate_s must be > 0, got {self.rate_s}")
        if self.min_samples < 1:
            raise ValueError(
                f"min_samples must be >= 1, got {self.min_samples}")
        if self.oversubscription_cap < 1.0:
            raise ValueError("oversubscription_cap must be >= 1.0")


class CPUPredictor:
    """Computes and caches ``Δ``; thread-safe.

    The executor (real or simulated) calls :meth:`tick` every ``rate_s``
    seconds; policies read :attr:`delta` (the paper stores Δ in an atomic
    variable read by the CPU manager, Alg. 2).
    """

    def __init__(self, monitor: TaskMonitor, n_cpus: int,
                 config: PredictionConfig | None = None) -> None:
        if n_cpus <= 0:
            raise ValueError("n_cpus must be positive")
        self.monitor = monitor
        self.n_cpus = n_cpus
        self.config = config or PredictionConfig()
        self._delta = n_cpus  # optimistic start: all CPUs
        self._lock = threading.Lock()
        self.predictions_made = 0

    # -- Algorithm 1 ---------------------------------------------------------

    def compute_delta(self, n_cpus: int | None = None) -> int:
        """One evaluation of Algorithm 1 against the monitor's snapshot."""
        cfg = self.config
        n = self.n_cpus if n_cpus is None else n_cpus
        gamma = 0.0
        total_instances = 0
        snapshot = self.monitor.workload_snapshot(cfg.min_samples)
        for _name, w_cost, alpha, m_j, reliable in snapshot:
            total_instances += m_j
            if gamma >= n and not cfg.allow_oversubscription:
                # paper's early exit: while (γ < N_CPUs)
                continue
            if cfg.count_based_only or not reliable:
                # count-based fallback: one CPU's worth per ready task
                gamma += m_j
            else:
                gamma += (w_cost * alpha) / cfg.rate_s
        if total_instances == 0:
            # No live work: keep one CPU awake to pick up new work
            # (Alg. 1 ensures 0 < Δ).
            return 1
        delta = min(math.ceil(gamma), total_instances)
        if cfg.allow_oversubscription:
            delta = min(delta, int(cfg.oversubscription_cap * n))
        else:
            delta = min(delta, n)
        return max(1, delta)

    # -- atomic Δ (read by Alg. 2) --------------------------------------------

    def tick(self) -> int:
        """Recompute Δ (called at the prediction rate) and publish it."""
        delta = self.compute_delta()
        with self._lock:
            self._delta = delta
            self.predictions_made += 1
        return delta

    @property
    def delta(self) -> int:
        with self._lock:
            return self._delta
