"""The CPU/worker manager — the mechanics side of Algorithm 2.

:class:`WorkerManager` owns the worker state machine (ACTIVE / SPIN / IDLE /
LENT), the active count ``δ`` and the idle set.  It consults a
:class:`~repro.core.policies.Policy` for every decision, so the same code
drives the real :class:`~repro.runtime.thread_executor.ThreadExecutor`, the
discrete-event :class:`~repro.runtime.sim.SimExecutor` and (with workers
reinterpreted as device replicas) the distributed
:class:`~repro.train.elastic.ElasticController`.

The manager is deliberately *passive*: it mutates state and reports which
workers must be resumed/idled, but the executor owns the actual blocking /
wakeup primitives (condition variables live, event queue simulated).

Heterogeneous machines: the manager may know each worker's core type
(``core_type_of``) and a **park order** over type names.  Parking-order
types are trimmed first when Δ drops and woken last when work arrives
("park the E-cores last" keeps the efficient cores hot; "park the
P-cores last" keeps the fast ones).  Without a topology both orderings
are identity, so homogeneous behaviour is unchanged.

All transitions are guarded by one lock; the paper stores ``Δ`` in an atomic
and updates ``δ`` "in a thread-safe manner" — this lock is that atomicity.
"""

from __future__ import annotations

import enum
import threading
from typing import Callable, Sequence

from ..analysis import guarded_by
from .energy import CoreState, EnergyMeter
from .events import EventBus, EventKind, RuntimeEvent
from .policies import Policy, PollDecision

__all__ = ["WorkerState", "WorkerManager"]


class WorkerState(enum.Enum):
    ACTIVE = "active"   # executing a task
    SPIN = "spin"       # polling for work
    IDLE = "idle"       # released its CPU (paper: idle(thread))
    LENT = "lent"       # CPU lent to another runtime via the broker


_ENERGY_STATE = {
    WorkerState.ACTIVE: CoreState.ACTIVE,
    WorkerState.SPIN: CoreState.SPIN,
    WorkerState.IDLE: CoreState.IDLE,
    WorkerState.LENT: CoreState.OFF,
}

# Per-member attribute mirror of _ENERGY_STATE: `state._energy` is a
# plain attribute load, where a dict lookup pays enum.__hash__ (a
# Python-level call) once per worker transition on the hot path.
for _ws, _cs in _ENERGY_STATE.items():
    _ws._energy = _cs


@guarded_by("_states", "_spin_counts", "_n_active", "_n_idle",
            "_n_active_by_type", "idles", "resumes")
class WorkerManager:
    """Tracks δ (active workers) and applies policy decisions atomically."""

    def __init__(self, n_workers: int, policy: Policy,
                 clock: Callable[[], float],
                 energy: EnergyMeter | None = None,
                 worker_ids: list[int] | None = None,
                 bus: EventBus | None = None,
                 core_type_of: Callable[[int], str] | None = None,
                 park_order: Sequence[str] | None = None) -> None:
        self.policy = policy
        self.clock = clock
        self.energy = energy
        self.bus = bus
        self.core_type_of = core_type_of
        # Lower rank ⇒ parked earlier and woken later.  Unknown types
        # rank last (parked last / woken first).
        self._park_rank = ({name: i for i, name in enumerate(park_order)}
                           if park_order is not None else {})
        ids = worker_ids if worker_ids is not None else list(range(n_workers))
        self._lock = threading.Lock()
        self._states: dict[int, WorkerState] = {
            w: WorkerState.SPIN for w in ids}
        self._spin_counts: dict[int, int] = {w: 0 for w in ids}
        # δ maintained incrementally — poll decisions used to recount the
        # whole state dict on every empty poll (O(workers) per event on
        # the simulator hot path).
        self._n_active = len(ids)
        self._n_idle = 0
        self._n_active_by_type: dict[str, int] = {}
        if core_type_of is not None:
            for w in ids:
                ct = core_type_of(w)
                self._n_active_by_type[ct] = \
                    self._n_active_by_type.get(ct, 0) + 1
        # Transition counters (observability / paper overhead discussion).
        self.idles = 0
        self.resumes = 0
        # Optional per-worker wake callback (see set_waker) — set once
        # before the workers start, then only read.
        self._waker: Callable[[int], None] | None = None

    def set_waker(self, waker: Callable[[int], None] | None) -> None:
        """Register a per-worker wake callback.

        When set, :meth:`notify_added` invokes ``waker(worker_id)`` for
        each worker it transitions IDLE → SPIN, *after* releasing the
        manager lock — targeted wakes (one event set per resumed worker)
        instead of the executor broadcasting ``notify_all`` to every
        parked thread.  The callback runs on the notifying thread and
        must not call back into the manager.
        """
        self._waker = waker

    # -- introspection -------------------------------------------------------

    @property
    def n_workers(self) -> int:
        with self._lock:
            return len(self._states)

    @property
    def active(self) -> int:
        """δ — workers currently holding a CPU (executing or spinning)."""
        return self._n_active

    def _active_locked(self) -> int:
        return self._n_active

    def active_by_type(self) -> dict[str, int]:
        """δ split per core type ({} without a ``core_type_of``;
        zero-count types are pruned)."""
        with self._lock:
            return {ct: n for ct, n in self._n_active_by_type.items()
                    if n > 0}

    def _active_by_type_locked(self) -> dict[str, int]:
        # The live counter dict (may carry zero entries) — read-only for
        # callers; the hetero policy's bound reader uses .get() lookups.
        return self._n_active_by_type

    @property
    def idle_workers(self) -> list[int]:
        with self._lock:
            return [w for w, s in self._states.items()
                    if s is WorkerState.IDLE]

    def state(self, worker_id: int) -> WorkerState:
        with self._lock:
            return self._states[worker_id]

    def state_of(self, worker_id: int) -> WorkerState | None:
        """Current state, or None for unknown workers — a single dict
        probe, unlike :meth:`states` which copies the whole map."""
        return self._states.get(worker_id)

    def states(self) -> dict[int, WorkerState]:
        with self._lock:
            return dict(self._states)

    def spinning(self, exclude: "set[int] | frozenset[int]" = frozenset(),
                 ) -> list[int]:
        """Spinning workers (minus ``exclude``) in wake/dispatch order —
        one pass under the lock instead of a states() copy + filter."""
        with self._lock:
            out = [w for w, s in self._states.items()
                   if s is WorkerState.SPIN and w not in exclude]
        return self.wake_first(out)

    def iter_spinning(self, exclude: "set[int] | frozenset[int]"
                      = frozenset()):
        """Lazy :meth:`spinning` for single-threaded dispatch loops that
        usually consume one or two workers out of dozens.  The caller
        may flip the state of *yielded* workers between yields (value
        mutations keep dict iteration valid) but must not add or remove
        workers.  Falls back to the materialized list on park-ordered
        (heterogeneous) managers, where wake order needs the full sort.
        """
        if self._park_rank:
            yield from self.spinning(exclude)
            return
        spin = WorkerState.SPIN
        for w, s in self._states.items():
            if s is spin and w not in exclude:
                yield w

    def states_items_unlocked(self):
        """Live ``(worker, state)`` view WITHOUT taking the lock.

        Sanctioned for single-threaded drivers only (the sim event loop
        owns every thread that touches its manager): ``_states`` is a
        declared guarded field, and this accessor is the one documented
        escape hatch — external code must not reach into the dict
        directly.  Keys are fixed after construction on this path and
        ``poll_empty`` mutates values only, so iteration is safe.
        """
        return self._states.items()

    @property
    def park_ordered(self) -> bool:
        """True when a heterogeneous park order was configured.  Set
        once at construction and immutable, so the unlocked read is
        safe from any thread."""
        return bool(self._park_rank)

    # -- ordering ------------------------------------------------------------

    def _rank(self, worker_id: int) -> int:
        if self.core_type_of is None or not self._park_rank:
            return 0
        return self._park_rank.get(self.core_type_of(worker_id),
                                   len(self._park_rank))

    def park_first(self, workers: list[int]) -> list[int]:
        """``workers`` sorted for trimming: lowest park rank first
        (stable — identity without a topology)."""
        if not self._park_rank:
            return workers
        return sorted(workers, key=self._rank)

    def wake_first(self, workers: list[int]) -> list[int]:
        """``workers`` sorted for waking/dispatch: highest park rank
        first (stable — identity without a topology)."""
        if not self._park_rank:
            return workers
        return sorted(workers, key=lambda w: -self._rank(w))

    # -- transitions ---------------------------------------------------------

    _HOLDING = (WorkerState.ACTIVE, WorkerState.SPIN)

    # analysis: caller-locks
    def _count(self, worker_id: int, prev: WorkerState | None,
               state: WorkerState | None) -> None:
        """Incrementally maintain δ, the idle count and the per-type
        split across one worker's ``prev → state`` transition (None ⇒
        absent)."""
        if prev is WorkerState.IDLE:
            self._n_idle -= 1
        if state is WorkerState.IDLE:
            self._n_idle += 1
        held = prev in self._HOLDING
        holds = state in self._HOLDING
        if held is holds:
            return
        d = 1 if holds else -1
        self._n_active += d
        if self.core_type_of is not None:
            ct = self.core_type_of(worker_id)
            self._n_active_by_type[ct] = \
                self._n_active_by_type.get(ct, 0) + d

    def _set(self, worker_id: int, state: WorkerState) -> None:  # analysis: caller-locks
        # Hot path (two transitions per simulated task): the counter
        # maintenance is _count() inlined, and the bus pre-check reads
        # the cached interest union directly instead of paying a method
        # call per transition.
        prev = self._states.get(worker_id)
        if prev is state:
            return
        self._states[worker_id] = state
        if prev is WorkerState.IDLE:
            self._n_idle -= 1
        elif state is WorkerState.IDLE:
            self._n_idle += 1
        held = prev in self._HOLDING
        if held is not (state in self._HOLDING):
            d = -1 if held else 1
            self._n_active += d
            if self.core_type_of is not None:
                ct = self.core_type_of(worker_id)
                self._n_active_by_type[ct] = \
                    self._n_active_by_type.get(ct, 0) + d
        if self.energy is not None:
            self.energy.set_state(worker_id, state._energy, self.clock())
        bus = self.bus
        if bus is not None:
            interest = bus.interest
            if interest is None or interest:
                self._publish_state(bus, worker_id, prev, state)

    def _publish_state(self, bus: EventBus, worker_id: int,
                       prev: WorkerState | None,
                       state: WorkerState) -> None:
        if bus.interested(EventKind.WORKER_STATE):
            bus.publish(RuntimeEvent(
                kind=EventKind.WORKER_STATE, time=self.clock(),
                worker_id=worker_id,
                data={"state": state.value,
                      "prev": prev.value if prev else None}))

    def _apply_poll_decision_locked(self, worker_id: int,
                                    decision: PollDecision) -> None:
        """The one IDLE/LEND transition path (poll_empty and
        reevaluate_spinners used to diverge on spin-count resets and
        transition counting)."""
        if decision is PollDecision.IDLE:
            self._set(worker_id, WorkerState.IDLE)
            self._spin_counts[worker_id] = 0
            self.idles += 1
        elif decision is PollDecision.LEND:
            self._set(worker_id, WorkerState.LENT)
            self._spin_counts[worker_id] = 0

    def task_started(self, worker_id: int) -> None:
        with self._lock:
            self._spin_counts[worker_id] = 0
            self._set(worker_id, WorkerState.ACTIVE)

    def task_finished(self, worker_id: int) -> None:
        with self._lock:
            self._set(worker_id, WorkerState.SPIN)

    def poll_empty(self, worker_id: int,
                   spin_count_override: int | None = None) -> PollDecision:
        """Worker polled, queue empty — Alg. 2 lines 2–10.

        Returns the decision; IDLE/LEND transitions are applied (δ
        decremented) before returning, so a concurrent poller sees the
        updated δ.  ``spin_count_override`` lets the discrete-event
        simulator fast-forward a spin budget (N empty polls collapse into
        one event) without emitting N calls.
        """
        with self._lock:
            if spin_count_override is not None:
                count = spin_count_override
                self._spin_counts[worker_id] = count
            else:
                count = self._spin_counts[worker_id] + 1
                self._spin_counts[worker_id] = count
            decision = self.policy.on_poll_empty(
                worker_id, self._n_active, count)
            if decision is not PollDecision.SPIN:
                # SPIN applies no transition; skip the apply call on the
                # (dominant) keep-spinning outcome
                self._apply_poll_decision_locked(worker_id, decision)
            return decision

    def notify_added(self, ready_tasks: int) -> list[int]:
        """Tasks were added — Alg. 2 lines 11–19.

        Returns the worker ids transitioned IDLE → SPIN; the executor must
        actually wake them (condition variable / sim event), unless a
        :meth:`set_waker` callback is registered — then each woken id is
        delivered to it here, after the lock is released, and the caller
        may ignore the return value.  On heterogeneous machines the wake
        order follows the park order in reverse (fastest-to-park woken
        last).
        """
        with self._lock:
            n_idle = self._n_idle
            if n_idle == 0:
                return []
            # Ask the policy first (it only needs the counts — all
            # implementations are pure decision logic) and build the
            # ordered idle list only when somebody actually wakes:
            # prediction-rate ticks with δ ≥ Δ used to pay a full
            # state-map scan just to wake nobody.
            n = self.policy.workers_to_resume(
                self._n_active, n_idle, ready_tasks)
            if n <= 0:
                return []
            idle = self.wake_first([w for w, s in self._states.items()
                                    if s is WorkerState.IDLE])
            woken = idle[:n]
            for w in woken:
                self._set(w, WorkerState.SPIN)
                self._spin_counts[w] = 0
                self.resumes += 1
        # Outside the lock: by now every woken worker's transition is
        # visible, so a worker whose wake event fires re-checks its
        # state and finds SPIN — no missed wakeup, no lock held while
        # signalling.
        waker = self._waker
        if waker is not None:
            for w in woken:
                waker(w)
        return woken

    def reevaluate_spinners(self) -> list[int]:
        """After a prediction tick lowered Δ, ask the policy about every
        spinning worker again (the paper's threads re-check ``δ > Δ`` on
        their next poll; in the simulator this is the equivalent hook).

        Returns workers transitioned out of SPIN (idled or lent), park
        order first.
        """
        parked = []
        with self._lock:
            spinning = self.park_first(
                [w for w, s in self._states.items()
                 if s is WorkerState.SPIN])
            for w in spinning:
                decision = self.policy.on_poll_empty(
                    w, self._active_locked(), self._spin_counts[w])
                self._apply_poll_decision_locked(w, decision)
                if decision in (PollDecision.IDLE, PollDecision.LEND):
                    parked.append(w)
        return parked

    # -- broker hooks (DLB) ---------------------------------------------------

    def add_worker(self, worker_id: int, power=None,
                   core_type: str = "") -> None:
        """A borrowed CPU arrived from the broker; it starts spinning.

        ``power``/``core_type`` carry the borrowed core's identity on
        heterogeneous machines so its energy is billed correctly."""
        with self._lock:
            prev = self._states.get(worker_id)
            self._states[worker_id] = WorkerState.SPIN
            self._count(worker_id, prev, WorkerState.SPIN)
            self._spin_counts[worker_id] = 0
            if self.energy is not None:
                self.energy.add_core(worker_id, CoreState.SPIN,
                                     self.clock(), power=power,
                                     core_type=core_type)

    def remove_worker(self, worker_id: int) -> None:
        """A borrowed CPU was reclaimed by its owner.

        The core's energy timeline is closed with an OFF transition —
        the owner accounts for it from here on; without this, a returned
        CPU kept accruing SPIN power in the borrower's meter until
        ``finish()``.
        """
        with self._lock:
            if worker_id in self._states and self.energy is not None:
                self.energy.set_state(worker_id, CoreState.OFF,
                                      self.clock())
            prev = self._states.pop(worker_id, None)
            if prev is not None:
                self._count(worker_id, prev, None)
            self._spin_counts.pop(worker_id, None)

    def reclaim(self, worker_id: int) -> None:
        """Owner got its lent CPU back (LENT → SPIN)."""
        with self._lock:
            if self._states.get(worker_id) is WorkerState.LENT:
                self._set(worker_id, WorkerState.SPIN)
                self._spin_counts[worker_id] = 0
