"""Resource sharing between co-located runtimes (paper §2 "Resource
Sharing", §3.3, Table 3) — a DLB/LeWI-style broker plus the three sharing
strategies evaluated by the paper:

* **LeWI** — *Lend When Idle*: a worker that polls and finds nothing lends
  its CPU immediately; when tasks are added, threads eagerly call the
  broker to get CPUs back, one call per thread.  Extremely reactive; the
  paper measures ~4M broker calls in a 100 s run.
* **Hybrid** — like LeWI but a worker spins for ``spin_budget`` (paper:
  100) consecutive empty polls before lending.
* **Prediction** — the paper's contribution (§3.3): lend only when the
  predictor says the CPU will not be needed (``δ > Δ``), and make a
  *single* broker call per prediction tick to acquire ``Δ − δ`` CPUs,
  instead of per-thread eager calls.  The predictor runs with
  ``allow_oversubscription=True`` because DLB may provide more CPUs than
  the runtime owns.

Every :meth:`ResourceBroker.lend` / :meth:`ResourceBroker.acquire` /
:meth:`ResourceBroker.reclaim` invocation that actually reaches the broker
increments the per-job *DLB call* counter — the cost metric of paper
Table 3.  An ``acquire`` with ``max_n <= 0`` never leaves the caller (no
DLB library call would be issued), so it is not counted.

Multiprogramming (N ≥ 2 jobs): foreign CPUs are rationed with a
least-recently-served reservation — a claimant whose last acquisition
came up short registers its unmet demand, and better-served claimants
must leave that many foreign CPUs in the pool.  Without it, whichever
borrower's tick happens to fire first drains the pool every round and
can starve a third job indefinitely.

On heterogeneous machines the broker can be taught each CPU's core type
(:meth:`ResourceBroker.set_core_type_of`): the pool is then accountable
per type (:meth:`pool_by_type`) and ``acquire`` accepts a ``core_type``
filter, so a P-core lent is never silently handed back as an E-core
grant.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Callable

from ..analysis import guarded_by
from .policies import Policy, PollDecision
from .prediction import CPUPredictor

__all__ = [
    "ResourceBroker",
    "SharingPolicy",
    "LeWIPolicy",
    "DLBHybridPolicy",
    "DLBPredictionPolicy",
]


@dataclass
class _JobAccount:
    name: str
    owned: set[int] = field(default_factory=set)    # CPUs this job owns
    lent: set[int] = field(default_factory=set)     # owned, now in the pool/borrowed
    borrowed: set[int] = field(default_factory=set)  # others' CPUs we run on
    calls: int = 0                                   # DLB call counter
    reclaim_wanted: bool = False
    #: unmet demand from the last acquire (foreign-claimant fairness):
    #: while > 0, better-served claimants leave this many CPUs in the pool
    waiting: int = 0
    #: monotonic stamp of the last *foreign* CPU grant; 0 = never served
    last_served: int = 0


@guarded_by("_jobs", "_pool", "_owner", "_holder", "_return_flags",
            "total_calls", "_failed")
class ResourceBroker:
    """The DLB stand-in: a pool of lent CPUs shared between jobs.

    Reclaim semantics: an owner may flag a reclaim; borrowed CPUs are
    returned cooperatively at the borrower's next task boundary (the
    executor calls :meth:`cpu_must_return` to learn this).
    """

    def __init__(self, core_type_of: Callable[[int], str] | None = None,
                 ) -> None:
        self._lock = threading.Lock()
        self._jobs: dict[str, _JobAccount] = {}
        self._pool: list[int] = []          # lent, unborrowed CPUs
        self._owner: dict[int, str] = {}    # cpu -> owning job
        self._holder: dict[int, str] = {}   # cpu -> job currently running on it
        self._return_flags: set[int] = set()
        self._type_of = core_type_of
        self._serve_stamp = itertools.count(1)
        self.total_calls = 0
        # Failed cores (machine conditions): a dict used as an ordered
        # set — failed CPUs are pulled from the pool, refused by lend/
        # acquire, and their loan accounting erased until recovery.
        self._failed: dict[int, bool] = {}

    # -- registration --------------------------------------------------------

    def register_job(self, name: str, cpus: list[int]) -> None:
        with self._lock:
            acct = _JobAccount(name=name, owned=set(cpus))
            self._jobs[name] = acct
            for c in cpus:
                self._owner[c] = name
                self._holder[c] = name

    def set_core_type_of(self, fn: Callable[[int], str] | None) -> None:
        """Teach the broker each CPU's core type (heterogeneous machines)
        so pool accounting and ``acquire(core_type=...)`` filters work
        per type.  ``None`` reverts to untyped (homogeneous) mode."""
        with self._lock:
            self._type_of = fn

    @property
    def typed(self) -> bool:
        """True when the broker knows core types (see
        :meth:`set_core_type_of`)."""
        return self._type_of is not None

    def _ct(self, cpu: int) -> str:
        return self._type_of(cpu) if self._type_of is not None else ""

    def job_calls(self, name: str) -> int:
        with self._lock:
            return self._jobs[name].calls

    def pool_size(self, core_type: str | None = None) -> int:
        with self._lock:
            if core_type is None:
                return len(self._pool)
            return sum(1 for c in self._pool if self._ct(c) == core_type)

    def pool_rejected(self, where: Callable[[int], bool]) -> int:
        """How many pooled CPUs fail the ``where`` predicate right now —
        arbiters use it to attribute a short locality-guarded grant to
        the guard (vs. a genuinely empty pool).  Shared-memory peek, not
        a DLB call."""
        with self._lock:
            return sum(1 for c in self._pool if not where(c))

    def reassign_core(self, job: str, old: int, new: int) -> None:
        """Whole-app migration moved ``job`` off owned CPU ``old`` onto
        free CPU ``new``: transfer ownership/holder accounting so later
        lend/acquire verbs see the post-migration layout.  ``old`` must
        be owned, held and unlent by ``job`` (the simulator refuses to
        migrate borrowed or lent cores) and ``new`` unclaimed."""
        with self._lock:
            acct = self._jobs[job]
            if old not in acct.owned or old in acct.lent:
                raise ValueError(
                    f"cannot reassign cpu {old}: not an unlent core "
                    f"owned by {job!r}")
            if self._owner.get(new) is not None:
                raise ValueError(
                    f"cannot reassign onto cpu {new}: owned by "
                    f"{self._owner[new]!r}")
            acct.owned.discard(old)
            acct.owned.add(new)
            del self._owner[old]
            del self._holder[old]
            self._owner[new] = job
            self._holder[new] = job

    def pool_by_type(self) -> dict[str, int]:
        """Pool composition per core type ({""; n} when untyped)."""
        with self._lock:
            out: dict[str, int] = {}
            for c in self._pool:
                ct = self._ct(c)
                out[ct] = out.get(ct, 0) + 1
            return out

    # -- machine conditions ----------------------------------------------------

    def fail_core(self, cpu: int) -> str:
        """``cpu`` died: pull it from the pool, erase any loan
        accounting, and refuse to lend/grant it until
        :meth:`recover_core`.  Returns the job that was holding it
        (``""`` if it sat in the pool) so the caller can tear down the
        right worker.  Shared-memory bookkeeping, not a DLB call —
        hardware does not bill you for breaking."""
        with self._lock:
            self._failed[cpu] = True
            owner = self._owner.get(cpu)
            if owner is None:
                return ""
            held_by = self._holder.get(cpu, owner)
            if cpu in self._pool:
                self._pool.remove(cpu)
            owner_acct = self._jobs[owner]
            owner_acct.lent.discard(cpu)
            self._return_flags.discard(cpu)
            owner_acct.reclaim_wanted = bool(
                self._return_flags & owner_acct.lent)
            if held_by and held_by != owner:
                self._jobs[held_by].borrowed.discard(cpu)
            # Park the dead core on its owner's books so recovery
            # restores the pre-failure ownership layout.
            self._holder[cpu] = owner
            return held_by

    def recover_core(self, cpu: int) -> str:
        """A failed ``cpu`` came back; it rejoins its owner directly
        (never through the pool — the owner decides whether to lend
        it).  Returns the owning job name (``""`` if unregistered)."""
        with self._lock:
            self._failed.pop(cpu, None)
            owner = self._owner.get(cpu)
            if owner is None:
                return ""
            self._holder[cpu] = owner
            return owner

    def is_failed(self, cpu: int) -> bool:
        with self._lock:
            return cpu in self._failed

    # -- the three DLB verbs ---------------------------------------------------

    def lend(self, job: str, cpu: int) -> str:
        """Job ``job`` lends ``cpu`` into the pool (1 DLB call).

        Returns the new holder: the owner's name when a reclaim was
        pending (direct hand-over), else ``""`` (parked in the pool).
        A failed CPU is refused outright (uncounted — the call would
        never reach the library on dead silicon).
        """
        with self._lock:
            if self._failed and cpu in self._failed:
                return ""
            acct = self._jobs[job]
            acct.calls += 1
            self.total_calls += 1
            # Lending is a surplus signal: any outstanding unmet demand
            # this job registered is stale, so stop reserving for it.
            acct.waiting = 0
            if cpu in acct.borrowed:
                # Returning someone else's CPU.
                acct.borrowed.discard(cpu)
                owner = self._owner[cpu]
                owner_acct = self._jobs[owner]
                owner_acct.lent.discard(cpu)
                self._return_flags.discard(cpu)
                if owner_acct.reclaim_wanted:
                    # Owner asked for CPUs back: hand it straight over.
                    self._holder[cpu] = owner
                    owner_acct.reclaim_wanted = bool(
                        self._return_flags & owner_acct.lent)
                    return owner
                owner_acct.lent.add(cpu)
                self._holder[cpu] = ""
                self._pool.append(cpu)
                return ""
            if cpu not in acct.owned or cpu in acct.lent:
                return ""
            acct.lent.add(cpu)
            self._holder[cpu] = ""
            self._pool.append(cpu)
            self._return_flags.discard(cpu)
            return ""

    def acquire(self, job: str, max_n: int,
                core_type: str | None = None,
                where: Callable[[int], bool] | None = None,
                prefer: Callable[[int], float] | None = None) -> list[int]:
        """Job asks the broker for up to ``max_n`` CPUs (1 DLB call).

        ``max_n <= 0`` is a caller-side no-op: it returns immediately and
        is NOT counted as a DLB call (it would never reach the library),
        so Table-3 cost metrics only count real broker traffic.

        ``core_type`` restricts the grant to CPUs of that type (typed
        brokers only — see :meth:`set_core_type_of`).

        ``where``/``prefer`` make the verb locality-aware on multi-node
        clusters (the acquire carries a domain): ``where`` filters
        *foreign* CPUs (own cores always pass — reclaiming your own is
        never a remote borrow) and ``prefer`` sorts the eligible
        foreign CPUs (stable) by a key such as home-node distance, so
        near cores are granted — and far ones left for the fairness
        reservation — first.  Both default to off (single-node runs
        keep pool FIFO order bit-for-bit).

        Preference order: the job's own lent CPUs first (cheap reclaim),
        then foreign CPUs in pool (FIFO) order — minus a reservation for
        less-recently-served claimants with outstanding unmet demand, the
        round-robin discipline that stops one borrower from draining the
        pool ahead of a starving third job every round.
        """
        if max_n <= 0:
            return []
        with self._lock:
            acct = self._jobs[job]
            acct.calls += 1
            self.total_calls += 1
            got: list[int] = []
            own: list[int] = []
            foreign: list[int] = []
            for c in self._pool:
                if self._failed and c in self._failed:
                    continue   # defensive: fail_core() drains the pool
                if core_type is not None and self._ct(c) != core_type:
                    continue
                if self._owner[c] == job:
                    own.append(c)
                elif where is None or where(c):
                    foreign.append(c)
            if prefer is not None:
                foreign.sort(key=prefer)
            # Foreign-claimant fairness: demand registered by claimants
            # served less recently than us stays in the pool.
            reserved = sum(a.waiting for n, a in self._jobs.items()
                           if n != job and a.waiting > 0
                           and a.last_served < acct.last_served)
            foreign = foreign[:max(0, len(foreign) - reserved)]
            for cpu in own + foreign:
                if len(got) >= max_n:
                    break
                self._pool.remove(cpu)
                self._holder[cpu] = job
                if self._owner[cpu] == job:
                    acct.lent.discard(cpu)
                else:
                    acct.borrowed.add(cpu)
                got.append(cpu)
            if any(self._owner[c] != job for c in got):
                acct.last_served = next(self._serve_stamp)
            acct.waiting = max_n - len(got)
            return got

    def reclaim(self, job: str) -> list[int]:
        """Owner wants its lent CPUs back (1 DLB call).

        CPUs sitting in the pool return immediately; borrowed ones are
        flagged and come back at the borrower's next task boundary.
        """
        with self._lock:
            acct = self._jobs[job]
            acct.calls += 1
            self.total_calls += 1
            back: list[int] = []
            for cpu in list(acct.lent):
                if cpu in self._pool:
                    self._pool.remove(cpu)
                    acct.lent.discard(cpu)
                    self._holder[cpu] = job
                    back.append(cpu)
                else:
                    self._return_flags.add(cpu)
            acct.reclaim_wanted = bool(self._return_flags & acct.lent)
            return back

    # -- cooperative return ----------------------------------------------------

    def cpu_must_return(self, cpu: int) -> bool:
        with self._lock:
            return cpu in self._return_flags

    def reclaim_pending(self, job: str) -> bool:
        """True while an earlier :meth:`reclaim` still has return flags
        outstanding — re-issuing the reclaim would set no new flag, so
        arbiters use this to avoid paying for redundant DLB calls."""
        with self._lock:
            return self._jobs[job].reclaim_wanted

    def register_demand(self, job: str, n: int) -> None:
        """Record ``job``'s current unmet CPU demand for the
        foreign-claimant fairness reservation *without* a DLB call — in
        a real DLB deployment this is a shared-memory counter write, not
        a library round-trip.  Arbiters call it when the cheap free-CPU
        peek suppresses an acquisition (a starved app would otherwise
        never register the claim that reserves CPUs for it) and with 0
        when the app's demand evaporates (done, or satisfied through a
        reclaim), so stale reservations cannot park pooled CPUs."""
        with self._lock:
            self._jobs[job].waiting = max(0, n)

    def return_cpu(self, borrower: str, cpu: int) -> str:
        """Borrower hands a flagged CPU back; returns the owner job name."""
        with self._lock:
            owner = self._owner[cpu]
            owner_acct = self._jobs[owner]
            self._jobs[borrower].borrowed.discard(cpu)
            owner_acct.lent.discard(cpu)
            self._holder[cpu] = owner
            self._return_flags.discard(cpu)
            # The reclaim stays wanted while *other* lent CPUs still have
            # pending return flags (same recomputation as lend()) — a
            # blanket False silently dropped multi-CPU reclaims.
            owner_acct.reclaim_wanted = bool(
                self._return_flags & owner_acct.lent)
            return owner

    def holder(self, cpu: int) -> str:
        with self._lock:
            return self._holder[cpu]

    def lent_out(self, job: str, core_type: str | None = None) -> int:
        """How many of ``job``'s owned CPUs another job is running on."""
        with self._lock:
            return sum(1 for c in self._jobs[job].lent
                       if self._holder.get(c) not in ("", job)
                       and (core_type is None or self._ct(c) == core_type))


# ---------------------------------------------------------------------------
# Sharing policies: what a worker does on an empty poll in DLB mode.
# ---------------------------------------------------------------------------


class SharingPolicy(Policy):
    """Base for DLB-mode policies: empty polls may LEND the CPU away.

    ``acquire_on_add``: how many broker CPUs to request when tasks arrive
    (None ⇒ eager per-thread acquisition, the LeWI way).
    """

    eager_acquire = True

    def workers_to_resume(self, active: int, idle: int, ready_tasks: int,
                          ) -> int:
        # DLB mode: nothing sleeps locally — CPUs are lent, not idled.
        return min(idle, max(0, ready_tasks - active))

    def acquire_target(self, active: int, ready_tasks: int) -> int:
        """How many CPUs to request from the broker right now."""
        return max(0, ready_tasks - active)


class LeWIPolicy(SharingPolicy):
    """Lend When Idle — lend on the *first* empty poll."""

    name = "dlb-lewi"

    def on_poll_empty(self, worker_id: int, active: int, spin_count: int,
                      ) -> PollDecision:
        return PollDecision.LEND


class DLBHybridPolicy(SharingPolicy):
    """Spin ``spin_budget`` empty polls (paper: 100) before lending."""

    name = "dlb-hybrid"

    def __init__(self, spin_budget: int = 100) -> None:
        self.spin_budget = spin_budget

    def on_poll_empty(self, worker_id: int, active: int, spin_count: int,
                      ) -> PollDecision:
        if spin_count < self.spin_budget:
            return PollDecision.SPIN
        return PollDecision.LEND


class DLBPredictionPolicy(SharingPolicy):
    """Paper §3.3 — predictions drive both lending and acquisition.

    Lending: only when ``δ > Δ`` (this CPU is predicted surplus).
    Acquisition: *not* eager — a single broker call per prediction tick
    requests ``Δ − δ`` CPUs (``Δ`` may exceed the owned count because the
    predictor allows oversubscription in DLB mode).
    """

    name = "dlb-prediction"
    uses_predictions = True
    eager_acquire = False

    def __init__(self, predictor: CPUPredictor) -> None:
        self.predictor = predictor

    def on_poll_empty(self, worker_id: int, active: int, spin_count: int,
                      ) -> PollDecision:
        if active > self.predictor.delta:
            return PollDecision.LEND
        return PollDecision.SPIN

    def on_prediction_tick(self) -> None:
        self.predictor.tick()

    def acquire_target(self, active: int, ready_tasks: int) -> int:
        return max(0, self.predictor.delta - active)
