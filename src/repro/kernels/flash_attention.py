"""Flash attention (causal GQA, sliding-window, softcap) as a Pallas TPU
kernel.

TPU adaptation of the flash-2 schedule: the grid's trailing dimension
iterates KV blocks *sequentially* (TPU grid semantics), so the online-
softmax state (running max ``m``, denominator ``l``, accumulator ``acc``)
lives in VMEM scratch across KV steps and the scores tile never touches
HBM.  HBM traffic is Q/K/V/O only — vs. the O(S²) score round-trips of
the unfused XLA path (see EXPERIMENTS.md §Perf, iteration 1).

Block sizes default to (128, 512): the q-tile rows map onto the MXU's
128-lane systolic dimension and the 512-deep kv tile amortizes the
softmax renormalization; (Bq · D + Bk · D · 2 + Bq · Bk) fp32 tiles fit
comfortably in ~1 MB of VMEM per program.

Causal / windowed blocks that cannot contribute are skipped via
``pl.when`` (they still occupy grid steps; the index map is dense — a
documented simplification vs. a banded grid).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, softcap: float | None, window: int | None,
            block_q: int, block_k: int, n_kv: int):
    iq = pl.program_id(3)
    ik = pl.program_id(4)

    q_start = iq * block_q
    k_start = ik * block_k

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causality: the block contributes iff its first kv position can be
    # seen by the last q position (and, windowed, iff its last kv position
    # is within reach of the first q position).
    relevant = k_start <= q_start + block_q - 1
    if window is not None:
        relevant &= (k_start + block_k - 1) > (q_start - window)

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0, 0].astype(jnp.float32)       # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (Bq, Bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                          # (Bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # (Bq, Bk)
        corr = jnp.exp(m_prev - m_new)               # (Bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int | None = None,
                    softcap: float | None = None,
                    block_q: int = 128, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, KV, D) → (B, S, H, D).

    Causal; ``window`` enables sliding-window masking; ``softcap``
    applies tanh score capping (gemma2).
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = 1.0 / math.sqrt(D)
    n_q = S // block_q
    n_kv = S // block_k

    # layout: q (B, KV, G, S, D); k/v (B, KV, S, D)
    qt = q.reshape(B, S, KV, G, D).transpose(0, 2, 3, 1, 4)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, KV, G, n_q, n_kv)
    kernel = functools.partial(
        _kernel, scale=scale, softcap=softcap, window=window,
        block_q=block_q, block_k=block_k, n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, block_q, D),
                         lambda b, kh, g, iq, ik: (b, kh, g, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, kh, g, iq, ik: (b, kh, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, kh, g, iq, ik: (b, kh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, block_q, D),
                               lambda b, kh, g, iq, ik: (b, kh, g, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, S, D), q.dtype),
        scratch_shapes=[
            # VMEM accumulators persisting across the sequential kv dim
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)
