"""jit'd wrappers over the Pallas kernels with implementation dispatch.

``impl`` values:
  "xla"       — pure-jnp fallback (works everywhere; used by the CPU
                dry-run and the default model paths)
  "pallas"    — the TPU kernel (requires TPU hardware)
  "interpret" — the kernel body interpreted in Python (CPU correctness
                validation; what the oracle tests run)
"""

from __future__ import annotations


from . import ref
from .flash_attention import flash_attention
from .rglru import rglru_scan_kernel
from .rwkv6 import wkv6

__all__ = ["attention", "wkv", "rglru"]


def attention(q, k, v, *, window=None, softcap=None, impl: str = "xla",
              block_q: int = 128, block_k: int = 512):
    if impl == "xla":
        return ref.attention_ref(q, k, v, window=window, softcap=softcap)
    return flash_attention(q, k, v, window=window, softcap=softcap,
                           block_q=block_q, block_k=block_k,
                           interpret=(impl == "interpret"))


def wkv(r, k, v, w, u, s0=None, *, impl: str = "xla", chunk: int = 32):
    if impl == "xla":
        return ref.wkv6_ref(r, k, v, w, u, s0)
    return wkv6(r, k, v, w, u, s0, chunk=chunk,
                interpret=(impl == "interpret"))


def rglru(a, b, h0=None, *, impl: str = "xla", t_blk: int = 256,
          r_blk: int = 512):
    if impl == "xla":
        h = ref.rglru_ref(a, b, h0)
        return h, h[:, -1]
    return rglru_scan_kernel(a, b, h0, t_blk=t_blk, r_blk=r_blk,
                             interpret=(impl == "interpret"))
