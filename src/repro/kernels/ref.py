"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth for the interpret-mode kernel tests
(``tests/test_kernels.py`` sweeps shapes/dtypes and asserts allclose).
They are deliberately simple — O(S²) attention, step-by-step scans —
and are NOT used on the hot path.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["attention_ref", "wkv6_ref", "rglru_ref"]


def attention_ref(q, k, v, *, window: int | None = None,
                  softcap: float | None = None) -> jax.Array:
    """Causal GQA attention, full materialized scores.

    q: (B, S, H, D); k, v: (B, S, K, D).  fp32 math, returns q.dtype.
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, S, K, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) / math.sqrt(D)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S)
    mask = pos[:, None] >= pos[None, :]
    if window is not None:
        mask &= pos[:, None] - pos[None, :] < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return o.reshape(B, S, H, D).astype(q.dtype)


def wkv6_ref(r, k, v, w, u, s0=None):
    """RWKV-6 WKV, step-by-step.  r,k,v,w: (B,H,S,N); u: (H,N).

    Returns (y (B,H,S,N) f32, s_final (B,H,N,N) f32).
    """
    B, H, S, N = r.shape
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    if s0 is None:
        s0 = jnp.zeros((B, H, N, N), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhn,bhnm->bhm", rt,
                       s + u[None, :, :, None] * kv)
        return wt[..., :, None] * s + kv, y

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (r, k, v, w))
    s_fin, ys = lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 2), s_fin


def rglru_ref(a, b, h0=None):
    """h_t = a_t · h_{t-1} + b_t, step-by-step.  a, b: (B, S, R)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    _, hs = lax.scan(step, h0,
                     (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)
