"""Chunked RWKV-6 WKV as a Pallas TPU kernel.

Grid ``(B, H, n_chunks)`` — the chunk dimension is trailing, hence
sequential on TPU, so the (N, N) fp32 state matrix lives in VMEM scratch
across chunk steps (the cross-chunk recurrence) while each chunk's
intra-block math is two masked matmuls on MXU-aligned (L, N) tiles.

The intra-chunk pairwise decay tensor (L, L, N) stays in VMEM — the
reason the chunk length is 16/32: 32·32·64 fp32 = 256 KB.  Exponent
clamping matches the jnp reference (one-sided, lossless below e⁻⁴⁰).

HBM traffic: r/k/v/w in, y out, once — the state never leaves VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv6"]

_CLAMP = 40.0


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
            s_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    rt = r_ref[0, 0].astype(jnp.float32)          # (L, N)
    kt = k_ref[0, 0].astype(jnp.float32)
    vt = v_ref[0, 0].astype(jnp.float32)
    wt = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)              # (N,)
    s = s_ref[...]                                # (N, N)
    L = chunk

    lw = jnp.log(jnp.clip(wt, 1e-38, None))       # ≤ 0
    cum = jnp.cumsum(lw, axis=0)                  # lc_t   (L, N)
    cum_ex = cum - lw                             # lc_{t-1}

    # Pairwise decay D[t, s] = exp(lc_{t-1} − lc_s), strictly causal.
    diff = cum_ex[:, None, :] - cum[None, :, :]   # (L, L, N)
    decay = jnp.exp(jnp.clip(diff, -_CLAMP, 0.0))
    scores = jnp.einsum("ln,mn,lmn->lm", rt, kt, decay)
    mask = jnp.tril(jnp.ones((L, L), jnp.float32), k=-1)
    scores = scores * mask
    bonus = jnp.sum(rt * (u[None, :] * kt), axis=-1)          # (L,)
    y = jax.lax.dot_general(scores, vt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + bonus[:, None] * vt
    r_dec = rt * jnp.exp(jnp.clip(cum_ex, -_CLAMP, 0.0))
    y = y + jax.lax.dot_general(r_dec, s, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    tail = cum[-1:, :]                            # lc_L   (1, N)
    k_tail = kt * jnp.exp(jnp.clip(tail - cum, -_CLAMP, 0.0))
    s_new = jnp.exp(jnp.clip(tail[0, :, None], -_CLAMP, 0.0)) * s \
        + jax.lax.dot_general(k_tail, vt, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    s_ref[...] = s_new

    @pl.when(ic == n_chunks - 1)
    def _finish():
        sout_ref[0, 0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, s0: jax.Array | None = None, *,
         chunk: int = 32, interpret: bool = False):
    """r,k,v,w: (B, H, S, N); u: (H, N); s0: (B, H, N, N) or None.

    Returns (y (B, H, S, N) f32, s_final (B, H, N, N) f32).
    """
    B, H, S, N = r.shape
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    if s0 is None:
        s0 = jnp.zeros((B, H, N, N), jnp.float32)

    grid = (B, H, n_chunks)
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    seq_spec = pl.BlockSpec((1, 1, chunk, N),
                            lambda b, h, ic: (b, h, ic, 0))
    y, s_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, N), lambda b, h, ic: (h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, N, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, N), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_fin
