"""RG-LRU linear scan (h_t = a_t · h_{t−1} + b_t) as a Pallas TPU kernel.

Grid ``(B, n_R_blocks, n_T_blocks)`` — time blocks trail, so they run
sequentially and the per-channel hidden state persists in VMEM scratch.
Within a time block the recurrence is an in-kernel ``fori_loop`` of
vector FMAs over the (1, R_blk) lanes: this is a bandwidth-bound op (no
MXU work) and the kernel achieves the HBM-optimal traffic of reading
a/b and writing h exactly once — no log-space tricks, no numerical
clamping (contrast with the associative-scan fallback, which pays
O(log S) extra passes).

The R dimension is blocked at 512 lanes so a/b/h time-tiles fit VMEM:
3 tiles · (T_blk=256 × 512) f32 = 1.5 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rglru_scan_kernel"]


def _kernel(a_ref, b_ref, h0_ref, h_ref, hout_ref, state_ref, *,
            t_blk: int, n_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = h0_ref[0].astype(jnp.float32)   # (1, R_blk)

    a = a_ref[0].astype(jnp.float32)                     # (T_blk, R_blk)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t][None, :] * h + b[t][None, :]
        h_ref[0, t, :] = h[0].astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, t_blk, step, state_ref[...])
    state_ref[...] = h

    @pl.when(it == n_t - 1)
    def _finish():
        hout_ref[0] = h


@functools.partial(jax.jit, static_argnames=("t_blk", "r_blk", "interpret"))
def rglru_scan_kernel(a: jax.Array, b: jax.Array,
                      h0: jax.Array | None = None, *,
                      t_blk: int = 256, r_blk: int = 512,
                      interpret: bool = False):
    """a, b: (B, S, R) → h: (B, S, R) f32, h_final: (B, R) f32."""
    B, S, R = a.shape
    t_blk = min(t_blk, S)
    r_blk = min(r_blk, R)
    assert S % t_blk == 0 and R % r_blk == 0, (S, t_blk, R, r_blk)
    if h0 is None:
        h0 = jnp.zeros((B, R), jnp.float32)
    n_t = S // t_blk
    grid = (B, R // r_blk, n_t)
    kernel = functools.partial(_kernel, t_blk=t_blk, n_t=n_t)
    h, h_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t_blk, r_blk), lambda b_, ir, it: (b_, it, ir)),
            pl.BlockSpec((1, t_blk, r_blk), lambda b_, ir, it: (b_, it, ir)),
            pl.BlockSpec((1, 1, r_blk), lambda b_, ir, it: (b_, 0, ir)),
        ],
        out_specs=[
            pl.BlockSpec((1, t_blk, r_blk), lambda b_, ir, it: (b_, it, ir)),
            pl.BlockSpec((1, 1, r_blk), lambda b_, ir, it: (b_, 0, ir)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, R), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, R), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, r_blk), jnp.float32)],
        interpret=interpret,
    )(a, b, h0[:, None, :])
    return h, h_fin[:, 0]
