"""Trace recording: an event-bus subscriber with JSONL + Chrome export.

The recorder is frontend-agnostic by construction — it never touches a
scheduler or a governor, it only subscribes to the
:class:`~repro.core.events.EventBus` every frontend publishes on.  The
JSONL form is the replay input (`repro.trace.replay`); the Chrome form
(``chrome://tracing`` / https://ui.perfetto.dev) is for eyeballs:
per-worker task lanes plus a Δ-prediction counter track.
"""

from __future__ import annotations

import json
import threading
from pathlib import Path
from typing import Iterable

from ..analysis import guarded_by
from ..core.events import EventBus, EventKind, RuntimeEvent

__all__ = ["TraceRecorder", "decision_sequence", "prediction_sequence"]


@guarded_by("events", "_buses")
class TraceRecorder:
    """Records :class:`RuntimeEvent` streams from one or more buses."""

    def __init__(self, bus: EventBus | None = None,
                 kinds: Iterable[EventKind] | None = None) -> None:
        self.events: list[RuntimeEvent] = []
        self._kinds = frozenset(kinds) if kinds is not None else None
        self._lock = threading.Lock()
        self._buses: list[EventBus] = []
        if bus is not None:
            self.attach(bus)

    # -- subscription ------------------------------------------------------

    def attach(self, bus: EventBus) -> "TraceRecorder":
        """Subscribe to ``bus`` (idempotent per bus — double-attaching
        must not double-record every event).

        The membership check and the append happen under the recorder
        lock: two threads racing attach() on the same bus used to both
        pass the unlocked check and double-subscribe.  Holding it across
        ``bus.subscribe`` is fine — TraceRecorder precedes EventBus in
        the declared LOCK_ORDER."""
        with self._lock:
            if any(b is bus for b in self._buses):
                return self
            bus.subscribe(self._record, kinds=self._kinds)
            self._buses.append(bus)
        return self

    def detach(self) -> None:
        with self._lock:
            buses, self._buses = self._buses, []
        for bus in buses:
            bus.unsubscribe(self._record)

    def _record(self, ev: RuntimeEvent) -> None:
        with self._lock:
            self.events.append(ev)

    def clear(self) -> None:
        with self._lock:
            self.events.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self.events)

    # -- canonical ordering ------------------------------------------------

    def merged_events(self) -> list[RuntimeEvent]:
        """Events in canonical (replayable) order.

        Single-threaded producers (the simulator) record an already-
        ordered stream and get it back verbatim — no event carries a
        ``seq`` stamp, and the list (hence the JSONL bytes) is exactly
        what was appended.  Multi-threaded producers (the sharded
        real-thread scheduler) append from N streams in recorder-lock
        order, which is not program order; their events carry per-stream
        monotonic ``seq`` stamps, and this method merge-sorts the
        streams back: stable sort on ``(time, stream, seq)``, where the
        stream is the publishing worker (submit-side events sort as
        stream −1).  Unstamped events (worker states, predictions) keep
        their arrival position among equal-time stamps — replay ignores
        their order.
        """
        with self._lock:
            events = list(self.events)
        if all(ev.seq is None for ev in events):
            return events
        events.sort(key=lambda ev: (
            ev.time,
            -1 if ev.worker_id is None else ev.worker_id,
            -1 if ev.seq is None else ev.seq))
        return events

    # -- JSONL round trip --------------------------------------------------

    def to_jsonl(self, path: str | Path) -> Path:
        path = Path(path)
        events = self.merged_events()
        with path.open("w") as f:
            for ev in events:
                f.write(json.dumps(ev.to_dict()) + "\n")
        return path

    @classmethod
    def from_jsonl(cls, path: str | Path) -> "TraceRecorder":
        rec = cls()
        with Path(path).open() as f:
            for line in f:
                line = line.strip()
                if line:
                    rec.events.append(RuntimeEvent.from_dict(
                        json.loads(line)))
        return rec

    # -- Chrome tracing export ---------------------------------------------

    def to_chrome(self, path: str | Path) -> Path:
        """Write a ``chrome://tracing`` / Perfetto JSON trace.

        Tasks become complete (``ph="X"``) slices on per-worker lanes
        (EXECUTE→COMPLETED pairs; COMPLETED-only events — e.g. serving
        prefill/decode ticks — are reconstructed from their elapsed), and
        every PREDICTION tick becomes a Δ counter sample.
        """
        events = self.merged_events()
        if events:
            t0 = min(ev.time for ev in events)
        else:
            t0 = 0.0
        us = 1e6
        exec_at: dict[int, RuntimeEvent] = {}
        out: list[dict] = []
        for ev in events:
            if ev.kind is EventKind.TASK_EXECUTE and ev.task_id is not None:
                exec_at[ev.task_id] = ev
            elif ev.kind is EventKind.TASK_COMPLETED:
                start = exec_at.pop(ev.task_id, None) \
                    if ev.task_id is not None else None
                if start is not None:
                    ts = (start.time - t0) * us
                    dur = (ev.time - start.time) * us
                    tid = start.worker_id
                elif ev.elapsed is not None:
                    ts = (ev.time - ev.elapsed - t0) * us
                    dur = ev.elapsed * us
                    tid = ev.worker_id
                else:
                    continue
                out.append({
                    "name": ev.type_name or "task", "ph": "X",
                    "ts": ts, "dur": max(dur, 0.0), "pid": 0,
                    "tid": tid if tid is not None else 0,
                    "args": {"task_id": ev.task_id, "cost": ev.cost},
                })
            elif ev.kind is EventKind.PREDICTION:
                out.append({
                    "name": "delta", "ph": "C",
                    "ts": (ev.time - t0) * us, "pid": 0,
                    "args": {"delta": ev.data.get("delta", 0)},
                })
            elif ev.kind is EventKind.TASK_ARRIVED:
                out.append({
                    "name": f"arrive:{ev.type_name}", "ph": "i",
                    "ts": (ev.time - t0) * us, "pid": 0, "tid": 0,
                    "s": "g",
                })
        path = Path(path)
        path.write_text(json.dumps({"traceEvents": out,
                                    "displayTimeUnit": "ms"}))
        return path


def decision_sequence(events: Iterable[RuntimeEvent],
                      ) -> list[tuple[int, str]]:
    """The policy decision sequence of a run: ordered worker state
    transitions ``(worker_id, new_state)`` — the signal the round-trip
    replay property is checked against."""
    return [(ev.worker_id, ev.data["state"]) for ev in events
            if ev.kind is EventKind.WORKER_STATE
            and ev.worker_id is not None]


def prediction_sequence(events: Iterable[RuntimeEvent]) -> list[int]:
    """Ordered Δ values published by the governor's prediction ticks."""
    return [ev.data["delta"] for ev in events
            if ev.kind is EventKind.PREDICTION]
