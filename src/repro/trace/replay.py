"""Trace replay: recorded events → task graph + arrival timeline → sim.

A trace records *what actually happened*: which tasks existed (type,
cost, dependency ids, parent links), when each was released into the
runtime, and how long each really took.  :class:`TraceReplayer` rebuilds
that as a fresh :class:`~repro.runtime.task.TaskGraph` whose
``service_time`` is the measured duration and whose ``release_time`` is
the recorded arrival timeline — so a workload recorded once (on the
threaded executor, the serving engine, or the simulator itself) replays
deterministically in the simulator under any
:class:`~repro.core.governor.GovernorSpec`.

Replays run on a **neutral machine** (``core_speed=1.0``,
``monitor_event_overhead=0``) because recorded durations are already
end-to-end measurements — scaling them again would double-count.  Pass
``machine=TraceReplayer.replay_machine(MN4)`` to keep a specific model's
latency constants (this is what makes a sim→sim round trip reproduce the
original decision sequence exactly).
"""

from __future__ import annotations

from dataclasses import replace
from pathlib import Path
from typing import Iterable

from ..core.conditions import ConditionTimeline
from ..core.events import EventKind, RuntimeEvent
from ..core.governor import GovernorReport, GovernorSpec
from ..runtime.cluster import ClusterModel
from ..runtime.machine import MachineModel
from ..runtime.task import Task, TaskGraph
from ..workloads.arrivals import FixedTimeline
from .recorder import TraceRecorder

__all__ = ["TraceReplayer"]


class TraceReplayer:
    """Builds replayable workloads from a recorded event stream."""

    def __init__(self, events: Iterable[RuntimeEvent] | TraceRecorder
                 | str | Path) -> None:
        if isinstance(events, TraceRecorder):
            # Canonical order: threaded recordings interleave N event
            # streams in lock order; merged_events() restores the
            # per-stream sequence (a no-op copy for sim recordings).
            self.events = events.merged_events()
        elif isinstance(events, (str, Path)):
            self.events = list(TraceRecorder.from_jsonl(events).events)
        else:
            self.events = list(events)

    @staticmethod
    def replay_machine(machine: MachineModel) -> MachineModel:
        """A machine whose latency constants match ``machine`` but which
        does not re-scale (or re-charge overhead on) recorded durations."""
        return replace(machine, name=f"{machine.name}-replay",
                       core_speed=1.0, monitor_event_overhead=0.0)

    # -- multi-app traces --------------------------------------------------

    def apps(self) -> list[str]:
        """Application namespaces present in the trace (sorted; events
        from per-app buses carry ``RuntimeEvent.app``).  Empty for a
        single-app trace recorded from an unnamespaced bus."""
        return sorted({ev.app for ev in self.events if ev.app is not None})

    def for_app(self, app: str) -> "TraceReplayer":
        """A replayer over this app's slice of a multi-app trace — the
        per-app graphs/timelines rebuild independently, so a recorded
        co-schedule can be replayed app-by-app or reassembled into a
        fresh multi-app cluster.

        Raises ``KeyError`` (listing the app ids the trace does contain)
        for an unknown app — an empty replayer would surface much later
        as a confusing zero-task replay."""
        events = [ev for ev in self.events if ev.app == app]
        if not events:
            raise KeyError(
                f"no events for app {app!r}; trace contains {self.apps()}")
        return TraceReplayer(events)

    # -- machine conditions ------------------------------------------------

    def conditions(self) -> ConditionTimeline | None:
        """The machine-condition timeline recorded in the trace
        (``PERTURBATION`` events carry ``Perturbation.to_dict()``
        payloads), or ``None`` for an unperturbed run."""
        rows = [ev.data for ev in self.events
                if ev.kind is EventKind.PERTURBATION]
        if not rows:
            return None
        return ConditionTimeline.from_dicts(rows)

    # -- graph reconstruction ----------------------------------------------

    def build(self) -> tuple[TaskGraph, FixedTimeline | None]:
        """Reconstruct ``(graph, arrivals)`` from the trace.

        Only tasks with a ``TASK_SUBMITTED`` event are materialized
        (orphan completions — e.g. serving prefill/decode-tick samples —
        are instrumentation, not schedulable work).  Each build returns
        *fresh* :class:`Task` objects, so the result can be executed
        repeatedly (once per candidate policy) without state leaking.
        ``arrivals`` is ``None`` for a closed-world trace (everything
        released at t=0); otherwise it is the recorded timeline and the
        graph's tasks carry the matching ``release_time``.
        """
        submitted: list[RuntimeEvent] = []
        elapsed: dict[int, float] = {}
        exec_at: dict[int, float] = {}
        xfer: dict[int, float] = {}
        for ev in self.events:
            if ev.kind is EventKind.TASK_SUBMITTED:
                submitted.append(ev)
            elif ev.kind is EventKind.TASK_EXECUTE and ev.task_id is not None:
                exec_at[ev.task_id] = ev.time
            elif (ev.kind is EventKind.TRANSFER and ev.task_id is not None
                  and ev.elapsed is not None):
                # multi-node trace: the task's EXECUTE→COMPLETED span
                # includes wire time that is not service time — the
                # replay cluster re-derives the transfer itself
                xfer[ev.task_id] = ev.elapsed
            elif (ev.kind is EventKind.TASK_COMPLETED
                  and ev.task_id is not None and ev.elapsed is not None):
                # Prefer the EXECUTE→COMPLETED interval: it is the
                # resource *holding* time on every frontend.  A serving
                # request's published ``elapsed`` is its sojourn
                # (queueing included), which must not be replayed as
                # service time.  When the interval agrees with the
                # published elapsed to within float rounding, keep the
                # published value: the simulator computes the COMPLETED
                # timestamp as start + service, so re-deriving the
                # service as ``time - start`` can be an ulp off — and
                # that ulp would break the byte-exact replay-of-replay
                # round trip.
                start = exec_at.get(ev.task_id)
                if start is None:
                    elapsed[ev.task_id] = ev.elapsed
                else:
                    interval = ev.time - start - xfer.get(ev.task_id, 0.0)
                    if abs(interval - ev.elapsed) <= 1e-9 * abs(interval):
                        elapsed[ev.task_id] = ev.elapsed
                    else:
                        elapsed[ev.task_id] = interval
        if not submitted:
            return TaskGraph(), None
        missing = [ev.task_id for ev in submitted
                   if ev.task_id not in elapsed]
        if missing:
            raise ValueError(
                f"trace is not replayable: {len(missing)} submitted "
                f"task(s) never completed (first: {missing[:5]})")

        t0 = min(ev.time for ev in submitted)
        # Submissions that precede any execution are the closed-world
        # part of the workload: a batch-submitted graph records wall
        # timestamps a few µs apart, and replaying that recording jitter
        # as an arrival timeline would be noise, not workload shape.
        first_exec = min((ev.time for ev in self.events
                          if ev.kind is EventKind.TASK_EXECUTE),
                         default=float("inf"))
        graph = TaskGraph()
        by_old_id: dict[int, Task] = {}
        release: list[float] = []
        for ev in submitted:
            assert ev.task_id is not None
            rt = ev.data.get("release_time")
            if rt is None:
                rt = ev.time - t0 if ev.time > first_exec else 0.0
            task = Task(type_name=ev.type_name or "task",
                        cost=ev.cost if ev.cost is not None else 1.0,
                        service_time=elapsed[ev.task_id])
            by_old_id[ev.task_id] = task
            release.append(rt)
            # Added dep-less and wired below: TaskGraph.add() dedups
            # deps through a set, which would permute the recorded dep
            # order — and the replay's re-recorded TASK_SUBMITTED events
            # must reproduce the original dep lists byte-for-byte.
            graph.add(task)
        # Dependencies/parents are wired in a second pass: open-mode
        # submission order is not topological (a dependent can be
        # submitted before its dependency), so resolving inline would
        # silently drop edges the live run honored.
        for ev in submitted:
            task = by_old_id[ev.task_id]
            unknown = [d for d in ev.data.get("deps", ())
                       if d not in by_old_id]
            parent_id = ev.data.get("parent")
            if parent_id is not None and parent_id not in by_old_id:
                unknown.append(parent_id)   # fail fast like missing deps:
                #                             a dropped parent silently
                #                             skews the monitor's
                #                             parent-child subtraction
            if unknown:
                raise ValueError(
                    f"trace is not replayable: task {ev.task_id} depends "
                    f"on unrecorded task(s) {unknown[:5]}")
            task.deps = [by_old_id[d] for d in ev.data.get("deps", ())]
            task.parent = (by_old_id[parent_id] if parent_id is not None
                           else None)
        if all(rt <= 0.0 for rt in release):
            return graph, None
        for task, rt in zip(graph.tasks, release):
            task.release_time = rt
        # The graph's per-task ``release_time`` is authoritative for
        # replay (and is what replay() uses); the returned FixedTimeline
        # is the canonical sorted sequence of arrival *instants* — do
        # not re-assign() it onto the graph if submission order was not
        # already release-ordered.
        return graph, FixedTimeline(tuple(sorted(release)))

    # -- one-call what-if --------------------------------------------------

    def replay(self, spec: GovernorSpec,
               machine: MachineModel | ClusterModel | None = None,
               bus=None) -> GovernorReport:
        """Replay the trace in the simulator under ``spec``.

        Default machine: a neutral model with ``spec.resources`` cores.
        Pass a :class:`~repro.runtime.cluster.ClusterModel` (use
        :meth:`ClusterModel.replay_model` to neutralize it first) to
        replay onto a multi-node cluster.  Pass ``bus`` (an
        :class:`~repro.core.events.EventBus`) to observe or re-record
        the replay.

        A perturbed trace replays under the *neutralized* form of its
        recorded :meth:`conditions`: structural perturbations (power
        caps, fail/recover) are re-applied verbatim — they drive the
        same scheduling decisions — while speed-changing ones
        (straggler slowdowns, thermal caps) are disarmed, because the
        recorded durations already include their dilation.
        """
        from ..runtime.sim import SimCluster, SimJobSpec

        if machine is None:
            # Neutral by construction: recorded service times are
            # end-to-end measurements, so neither core scaling nor
            # monitoring overhead may be charged a second time.
            machine = MachineModel(name="replay", n_cores=spec.resources,
                                   core_speed=1.0,
                                   monitor_event_overhead=0.0)
        graph, _ = self.build()
        tl = self.conditions()
        cluster = SimCluster(
            machine,
            conditions=tl.neutralized() if tl is not None else None)
        job = SimJobSpec(name="replay", graph=graph, governor=spec,
                         cpus=list(range(spec.resources)), bus=bus)
        cluster.add_job(job)
        return cluster.run()["replay"]
