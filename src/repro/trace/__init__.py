"""Trace record/replay — run a workload once, replay it everywhere.

:class:`TraceRecorder` subscribes to any frontend's
:class:`~repro.core.events.EventBus` (threaded executor, simulator,
serving engine) and records the structured event stream; it exports JSONL
(lossless, reloadable) and Chrome ``chrome://tracing`` / Perfetto JSON.

:class:`TraceReplayer` turns a recorded trace back into a
:class:`~repro.runtime.task.TaskGraph` (types, costs, dependencies,
measured durations as service times) plus an arrival timeline, and runs
it deterministically in the simulator — so one recorded workload becomes
a what-if experiment under every registered policy.
"""

from .recorder import TraceRecorder, decision_sequence, prediction_sequence
from .replay import TraceReplayer

__all__ = ["TraceRecorder", "TraceReplayer", "decision_sequence",
           "prediction_sequence"]
