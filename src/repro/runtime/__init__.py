"""Task-based runtime: task graph + dependences, scheduler, a real
threaded executor and a discrete-event simulator (virtual time) that
reproduces the paper's policy dynamics deterministically on a 1-core host.
"""

from .task import Task, TaskGraph
from .scheduler import Scheduler
from .sharded import ShardedScheduler
from .thread_executor import ThreadExecutor, ExecutorReport
from .machine import MachineModel, MN4, KNL, HYBRID_PE, DVFS2
from .sim import SimExecutor, SimJobSpec, SimReport, SimCluster
from .multiapp import run_multi_app, solo_job_spec

__all__ = [
    "Task", "TaskGraph", "Scheduler", "ShardedScheduler",
    "ThreadExecutor", "ExecutorReport",
    "MachineModel", "MN4", "KNL", "HYBRID_PE", "DVFS2",
    "SimExecutor", "SimJobSpec", "SimReport", "SimCluster",
    "run_multi_app", "solo_job_spec",
]
