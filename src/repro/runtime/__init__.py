"""Task-based runtime: task graph + dependences, scheduler, a real
threaded executor and a discrete-event simulator (virtual time) that
reproduces the paper's policy dynamics deterministically on a 1-core host.
"""

from .task import Task, TaskGraph
from .scheduler import Scheduler
from .sharded import ShardedScheduler
from .thread_executor import ThreadExecutor, ExecutorReport
from .machine import MachineModel, MN4, KNL, HYBRID_PE, DVFS2
from .cluster import ClusterModel
from .sim import SimExecutor, SimJobSpec, SimReport, SimCluster
from .multiapp import (run_multi_app, run_multi_node, solo_job_spec,
                       predicted_demand)

__all__ = [
    "Task", "TaskGraph", "Scheduler", "ShardedScheduler",
    "ThreadExecutor", "ExecutorReport",
    "MachineModel", "MN4", "KNL", "HYBRID_PE", "DVFS2", "ClusterModel",
    "SimExecutor", "SimJobSpec", "SimReport", "SimCluster",
    "run_multi_app", "run_multi_node", "solo_job_spec",
    "predicted_demand",
]
