"""Tasks and task graphs.

A :class:`Task` mirrors an OmpSs-2 task: a unit of work with a *type*
(the monitoring aggregation key), a *cost* (the paper's ``cost`` clause,
evaluated at creation time), explicit *dependencies* (predecessor tasks)
and an optional *parent* (for the paper's parent–child outstanding-time
subtraction).

Payloads are either a Python callable ``fn`` (executed by the real
:class:`~repro.runtime.thread_executor.ThreadExecutor`) or a virtual
``service_time`` in seconds (consumed by the simulator).  Workloads attach
both so the same graph runs everywhere.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

__all__ = ["Task", "TaskGraph"]

_ids = itertools.count()


@dataclass(eq=False, slots=True)
class Task:
    type_name: str
    cost: float = 1.0
    fn: Callable[[], Any] | None = None
    service_time: float | None = None       # virtual seconds (simulator)
    parent: "Task | None" = None
    deps: list["Task"] = field(default_factory=list)
    #: open-workload release time (virtual seconds from run start); None
    #: means the task is part of the closed graph submitted at t=0.  An
    #: :class:`~repro.workloads.arrivals.ArrivalProcess` or a replayed
    #: trace fills it in; dependencies still gate readiness after release.
    release_time: float | None = None
    # -- filled by the scheduler ------------------------------------------
    task_id: int = field(default_factory=lambda: next(_ids))
    unmet: int = 0
    successors: list["Task"] = field(default_factory=list)
    done: bool = False
    #: global core id this task completed on (stamped by the simulator;
    #: successors use it to charge cross-node transfer / remote-socket
    #: penalties on the dependency edge).  None outside the simulator.
    completed_on: int | None = None

    def __hash__(self) -> int:
        return self.task_id

    def depends_on(self, *tasks: "Task") -> "Task":
        self.deps.extend(tasks)
        return self


class TaskGraph:
    """A container that wires dependencies and hands tasks to a scheduler.

    Supports OmpSs-2-style data dependences via :meth:`add` with ``in_``
    /``out`` token sets: a task depends on the last writer of each of its
    ``in_`` tokens and on all readers since the last write for ``out``
    tokens (write-after-read).
    """

    def __init__(self) -> None:
        self.tasks: list[Task] = []
        self._last_writer: dict[Any, Task] = {}
        self._readers_since_write: dict[Any, list[Task]] = {}

    def add(self, task: Task, in_: Iterable[Any] = (),
            out: Iterable[Any] = ()) -> Task:
        deps: set[Task] = set(task.deps)
        for tok in in_:
            w = self._last_writer.get(tok)
            if w is not None:
                deps.add(w)
            self._readers_since_write.setdefault(tok, []).append(task)
        for tok in out:
            w = self._last_writer.get(tok)
            if w is not None:
                deps.add(w)
            for r in self._readers_since_write.get(tok, []):
                if r is not task:
                    deps.add(r)
            self._last_writer[tok] = task
            self._readers_since_write[tok] = []
        task.deps = list(deps)
        self.tasks.append(task)
        return task

    def __len__(self) -> int:
        return len(self.tasks)
