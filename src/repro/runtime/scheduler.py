"""Ready-queue scheduler with dependency tracking.

Emits the monitoring lifecycle events (ready / execute / completed) so the
:class:`~repro.core.monitoring.TaskMonitor` sees exactly the transitions of
paper Fig. 2.  FIFO within a queue; thread-safe.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Iterable

from ..core.monitoring import TaskMonitor
from .task import Task

__all__ = ["Scheduler"]


class Scheduler:
    def __init__(self, monitor: TaskMonitor | None = None) -> None:
        self.monitor = monitor
        self._lock = threading.Lock()
        self._ready: deque[Task] = deque()
        self._pending = 0          # submitted, not yet completed
        self._ready_count = 0

    # -- submission ------------------------------------------------------

    def submit(self, task: Task) -> bool:
        """Register a task; returns True if it became ready immediately."""
        with self._lock:
            self._pending += 1
            task.unmet = 0
            for d in task.deps:
                if not d.done:
                    task.unmet += 1
                    d.successors.append(task)
            if task.unmet == 0:
                self._push_ready_locked(task)
                return True
            return False

    def submit_all(self, tasks: Iterable[Task]) -> int:
        """Submit many tasks; returns how many became ready."""
        n = 0
        for t in tasks:
            if self.submit(t):
                n += 1
        return n

    def _push_ready_locked(self, task: Task) -> None:
        self._ready.append(task)
        self._ready_count += 1
        if self.monitor is not None:
            self.monitor.on_task_ready(task.task_id, task.type_name,
                                       task.cost)

    # -- polling -----------------------------------------------------------

    def poll(self) -> Task | None:
        with self._lock:
            if not self._ready:
                return None
            task = self._ready.popleft()
            self._ready_count -= 1
        if self.monitor is not None:
            self.monitor.on_task_execute(task.task_id, task.type_name,
                                         task.cost)
        return task

    def complete(self, task: Task, elapsed: float) -> list[Task]:
        """Mark done; returns tasks that *became ready* as a result."""
        newly_ready: list[Task] = []
        with self._lock:
            task.done = True
            self._pending -= 1
            for s in task.successors:
                s.unmet -= 1
                if s.unmet == 0:
                    self._push_ready_locked(s)
                    newly_ready.append(s)
        if self.monitor is not None:
            self.monitor.on_task_completed(
                task.task_id, task.type_name, task.cost, elapsed,
                parent_id=task.parent.task_id if task.parent else None)
        return newly_ready

    # -- state ---------------------------------------------------------------

    @property
    def ready_count(self) -> int:
        with self._lock:
            return self._ready_count

    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending

    def drained(self) -> bool:
        with self._lock:
            return self._pending == 0
