"""Ready-queue scheduler with dependency tracking.

Publishes the task lifecycle (submitted / ready / execute / completed /
arrived) as :class:`~repro.core.events.RuntimeEvent`\\ s on an
:class:`~repro.core.events.EventBus` — the
:class:`~repro.core.monitoring.TaskMonitor` is one subscriber (it sees
exactly the transitions of paper Fig. 2), trace recorders are another.
FIFO within a queue; thread-safe.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Iterable

from ..core.events import EventBus, EventKind, RuntimeEvent
from ..core.monitoring import TaskMonitor
from .task import Task

__all__ = ["Scheduler"]


class Scheduler:
    def __init__(self, monitor: TaskMonitor | None = None,
                 bus: EventBus | None = None,
                 clock: Callable[[], float] | None = None) -> None:
        self.bus = bus if bus is not None else EventBus()
        self.clock = clock if clock is not None else (lambda: 0.0)
        self.monitor = monitor
        if monitor is not None:
            monitor.subscribe(self.bus)
        self._lock = threading.Lock()
        self._ready: deque[Task] = deque()
        self._pending = 0          # submitted, not yet completed
        self._ready_count = 0

    def _publish(self, kind: EventKind, task: Task, *,
                 worker_id: int | None = None, elapsed: float | None = None,
                 data: dict | None = None) -> None:
        if not self.bus.interested(kind):
            return
        self.bus.publish(RuntimeEvent(
            kind=kind, time=self.clock(), task_id=task.task_id,
            type_name=task.type_name, cost=task.cost, worker_id=worker_id,
            elapsed=elapsed, data=data or {}))

    # -- submission ------------------------------------------------------

    def submit(self, task: Task) -> bool:
        """Register a task; returns True if it became ready immediately."""
        with self._lock:
            self._pending += 1
            task.unmet = 0
            for d in task.deps:
                if not d.done:
                    task.unmet += 1
                    d.successors.append(task)
            # skip payload build on hot paths (the monitor's kind filter
            # does not cover SUBMITTED, so monitored-but-untraced runs
            # pay nothing here)
            if self.bus.interested(EventKind.TASK_SUBMITTED):
                self._publish(
                    EventKind.TASK_SUBMITTED, task,
                    data={"deps": [d.task_id for d in task.deps],
                          "parent": task.parent.task_id if task.parent
                          else None,
                          "release_time": task.release_time})
            if task.unmet == 0:
                self._push_ready_locked(task)
                return True
            return False

    def submit_all(self, tasks: Iterable[Task]) -> int:
        """Submit many tasks; returns how many became ready."""
        n = 0
        for t in tasks:
            if self.submit(t):
                n += 1
        return n

    def _push_ready_locked(self, task: Task) -> None:
        self._ready.append(task)
        self._ready_count += 1
        self._publish(EventKind.TASK_READY, task)

    # -- polling -----------------------------------------------------------

    def poll(self, worker_id: int | None = None) -> Task | None:
        with self._lock:
            if not self._ready:
                return None
            task = self._ready.popleft()
            self._ready_count -= 1
        self._publish(EventKind.TASK_EXECUTE, task, worker_id=worker_id)
        return task

    def complete(self, task: Task, elapsed: float,
                 worker_id: int | None = None) -> list[Task]:
        """Mark done; returns tasks that *became ready* as a result."""
        newly_ready: list[Task] = []
        with self._lock:
            task.done = True
            self._pending -= 1
            for s in task.successors:
                s.unmet -= 1
                if s.unmet == 0:
                    self._push_ready_locked(s)
                    newly_ready.append(s)
        if self.bus.interested(EventKind.TASK_COMPLETED):
            self._publish(
                EventKind.TASK_COMPLETED, task, worker_id=worker_id,
                elapsed=elapsed,
                data={"parent": task.parent.task_id if task.parent
                      else None})
        return newly_ready

    # -- state ---------------------------------------------------------------

    @property
    def ready_count(self) -> int:
        with self._lock:
            return self._ready_count

    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending

    def drained(self) -> bool:
        with self._lock:
            return self._pending == 0
