"""Ready-queue scheduler with dependency tracking.

Publishes the task lifecycle (submitted / ready / execute / completed /
arrived) as :class:`~repro.core.events.RuntimeEvent`\\ s on an
:class:`~repro.core.events.EventBus` for external observers (trace
recorders, dashboards).  The :class:`~repro.core.monitoring.TaskMonitor`
is **driven directly** — it sees exactly the transitions of paper Fig. 2
through plain method calls (one batched call per completion), so
monitored-but-untraced runs build no event objects at all.  FIFO within a
queue; thread-safe by default.

``threadsafe=False`` returns a :class:`_SeqScheduler` — the same
scheduler minus every lock round-trip, for single-threaded drivers (the
discrete-event simulator owns the only thread that ever touches it).
Both modes run the identical submit/poll/complete logic in the identical
order, which the fast-path parity tests pin bit-for-bit.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Iterable

from ..analysis import guarded_by, lock_free
from ..core.events import QUIET_INTEREST as _QUIET
from ..core.events import EventBus, EventKind, RuntimeEvent
from ..core.monitoring import TaskMonitor
from .task import Task

__all__ = ["Scheduler"]


@guarded_by("_ready", "_pending", "_ready_count")
class Scheduler:
    def __new__(cls, monitor: TaskMonitor | None = None,
                bus: EventBus | None = None,
                clock: Callable[[], float] | None = None,
                threadsafe: bool = True) -> "Scheduler":
        if cls is Scheduler and not threadsafe:
            return super().__new__(_SeqScheduler)
        return super().__new__(cls)

    def __init__(self, monitor: TaskMonitor | None = None,
                 bus: EventBus | None = None,
                 clock: Callable[[], float] | None = None,
                 threadsafe: bool = True) -> None:
        self.bus = bus if bus is not None else EventBus()
        self.clock = clock if clock is not None else (lambda: 0.0)
        self.monitor = monitor
        if monitor is not None:
            # The scheduler feeds its monitor directly (one batched call
            # per completion — no per-event RuntimeEvent construction or
            # bus dispatch).  A monitor subscription on this scheduler's
            # own bus — before or after construction — is absorbed so
            # the pair wired both ways still counts every lifecycle
            # event exactly once.
            monitor.unsubscribe(self.bus)
            monitor.mark_direct_driven(self.bus)
        self._lock = threading.Lock()
        self._ready: deque[Task] = deque()
        self._pending = 0          # submitted, not yet completed
        self._ready_count = 0

    def _publish(self, kind: EventKind, task: Task, *,
                 worker_id: int | None = None,
                 elapsed: float | None = None) -> None:
        """Publish one lifecycle event IF some subscriber wants the kind.

        The single interest check lives here (callers used to pre-check
        and ``_publish`` checked again); kind-specific payloads (dep ids,
        parent links) are built after the check, so hot paths with no
        interested subscriber allocate nothing.
        """
        if not self.bus.interested(kind):
            return
        if kind is EventKind.TASK_SUBMITTED:
            data = {"deps": [d.task_id for d in task.deps],
                    "parent": task.parent.task_id if task.parent else None,
                    "release_time": task.release_time}
        elif kind is EventKind.TASK_COMPLETED:
            data = {"parent": task.parent.task_id if task.parent else None}
        else:
            data = {}
        self.bus.publish(RuntimeEvent(
            kind=kind, time=self.clock(), task_id=task.task_id,
            type_name=task.type_name, cost=task.cost, worker_id=worker_id,
            elapsed=elapsed, data=data))

    # -- submission ------------------------------------------------------

    def submit(self, task: Task) -> bool:
        """Register a task; returns True if it became ready immediately."""
        with self._lock:
            return self._submit_core(task)

    def submit_all(self, tasks: Iterable[Task]) -> int:
        """Submit many tasks; returns how many became ready.

        One lock acquisition for the whole batch (this used to take and
        release the lock once per task — measurable on 10k+-task closed
        graphs)."""
        n = 0
        submit = self._submit_core
        with self._lock:
            for t in tasks:
                if submit(t):
                    n += 1
        return n

    def _submit_core(self, task: Task) -> bool:  # analysis: caller-locks
        """Dependency wiring + ready-queue insert (caller holds the lock
        in threadsafe mode; the sequential scheduler calls it bare)."""
        self._pending += 1
        unmet = 0
        for d in task.deps:
            if not d.done:
                unmet += 1
                d.successors.append(task)
        task.unmet = unmet
        # A quiet bus (no subscriber wants any kind) skips even the
        # _publish calls.
        quiet = self.bus.interest == _QUIET
        if not quiet:
            self._publish(EventKind.TASK_SUBMITTED, task)
        if unmet == 0:
            self._ready.append(task)
            self._ready_count += 1
            monitor = self.monitor
            if monitor is not None:
                monitor.on_task_ready(task.task_id, task.type_name,
                                      task.cost)
            if not quiet:
                self._publish(EventKind.TASK_READY, task)
            return True
        return False

    # -- polling -----------------------------------------------------------

    def poll(self, worker_id: int | None = None) -> Task | None:
        with self._lock:
            if not self._ready:
                return None
            task = self._ready.popleft()
            self._ready_count -= 1
        monitor = self.monitor
        if monitor is not None:
            monitor.on_task_execute(task.task_id, task.type_name, task.cost)
        if self.bus.interest != _QUIET:
            self._publish(EventKind.TASK_EXECUTE, task, worker_id=worker_id)
        return task

    def complete(self, task: Task, elapsed: float,
                 worker_id: int | None = None) -> list[Task]:
        """Mark done; returns tasks that *became ready* as a result."""
        with self._lock:
            newly_ready = self._complete_core(task, elapsed, worker_id)
        if self.bus.interest != _QUIET:
            self._publish(EventKind.TASK_COMPLETED, task,
                          worker_id=worker_id, elapsed=elapsed)
        return newly_ready

    # analysis: caller-locks
    def _complete_core(self, task: Task, elapsed: float,
                       worker_id: int | None) -> list[Task]:
        task.done = True
        self._pending -= 1
        newly_ready: list[Task] = []
        for s in task.successors:
            s.unmet -= 1
            if s.unmet == 0:
                self._ready.append(s)
                newly_ready.append(s)
        self._ready_count += len(newly_ready)
        if newly_ready and self.bus.interested(EventKind.TASK_READY):
            for s in newly_ready:
                self._publish(EventKind.TASK_READY, s)
        monitor = self.monitor
        if monitor is not None:
            # One lock acquisition for the whole completion batch: the
            # newly-ready successors first, then the completion itself —
            # the exact order the per-event path produced.
            monitor.completion_batch(
                task, elapsed, worker_id,
                task.parent.task_id if task.parent else None,
                newly_ready)
        return newly_ready

    def requeue(self, task: Task) -> None:
        """Put an *executing* task back at the head of the ready queue —
        the core running it died (machine conditions).  The inverse of
        :meth:`poll`: ready count grows, the monitor reverses its
        executing → ready accounting, and a fresh ``TASK_READY`` is
        published so recorded traces show the re-queue (the later
        re-execution publishes its own EXECUTE/COMPLETED pair)."""
        with self._lock:
            self._ready.appendleft(task)
            self._ready_count += 1
        self._requeue_tail(task)

    def _requeue_tail(self, task: Task) -> None:  # analysis: caller-locks
        monitor = self.monitor
        if monitor is not None:
            monitor.on_task_abort(task.task_id, task.type_name, task.cost)
        if self.bus.interest != _QUIET:
            self._publish(EventKind.TASK_READY, task)

    # -- state ---------------------------------------------------------------

    @property
    def ready_count(self) -> int:
        with self._lock:
            return self._ready_count

    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending

    def drained(self) -> bool:
        with self._lock:
            return self._pending == 0


@lock_free
class _SeqScheduler(Scheduler):
    """Single-threaded fast path: identical logic, zero lock round-trips.

    Built via ``Scheduler(..., threadsafe=False)``.  Every hot method is
    re-bound to the bare core (no ``with self._lock``), and the state
    accessors read the counters as plain attributes — callers like
    ``SimCluster._dispatch`` stop paying a lock acquire/release per
    ready-count peek.

    Lock-freedom is a contract, not a convenience: exactly one thread
    may ever drive an instance.  In debug builds (``python`` without
    ``-O``) the first mutating call binds the owning thread and any call
    from a different thread raises, so misuse fails loudly instead of
    corrupting counters.
    """

    def __init__(self, *args: object, **kwargs: object) -> None:
        super().__init__(*args, **kwargs)  # type: ignore[arg-type]
        self._owner_ident: int | None = None

    def _assert_owner(self) -> None:
        ident = threading.get_ident()
        owner = self._owner_ident
        if owner is None:
            self._owner_ident = ident
        elif owner != ident:
            raise RuntimeError(
                "Scheduler(threadsafe=False) is single-threaded by "
                f"contract: owned by thread {owner}, called from "
                f"{ident}. Use threadsafe=True for multi-thread access.")

    def submit(self, task: Task) -> bool:
        if __debug__:
            self._assert_owner()
        return self._submit_core(task)

    def submit_all(self, tasks: Iterable[Task]) -> int:
        if __debug__:
            self._assert_owner()
        n = 0
        submit = self._submit_core
        for t in tasks:
            if submit(t):
                n += 1
        return n

    def poll(self, worker_id: int | None = None) -> Task | None:
        if __debug__:
            self._assert_owner()
        if not self._ready:
            return None
        task = self._ready.popleft()
        self._ready_count -= 1
        monitor = self.monitor
        if monitor is not None:
            monitor.on_task_execute(task.task_id, task.type_name, task.cost)
        if self.bus.interest != _QUIET:
            self._publish(EventKind.TASK_EXECUTE, task, worker_id=worker_id)
        return task

    def complete(self, task: Task, elapsed: float,
                 worker_id: int | None = None) -> list[Task]:
        if __debug__:
            self._assert_owner()
        newly_ready = self._complete_core(task, elapsed, worker_id)
        if self.bus.interest != _QUIET:
            self._publish(EventKind.TASK_COMPLETED, task,
                          worker_id=worker_id, elapsed=elapsed)
        return newly_ready

    def requeue(self, task: Task) -> None:
        if __debug__:
            self._assert_owner()
        self._ready.appendleft(task)
        self._ready_count += 1
        self._requeue_tail(task)

    @property
    def ready_count(self) -> int:
        return self._ready_count

    @property
    def pending(self) -> int:
        return self._pending

    def drained(self) -> bool:
        return self._pending == 0
